"""Hypothesis property tests for LayoutMapping — the paper's Table I laws.

For every layout instance we check, over its whole (test-sized) domain:
  LAW 1 (codomain):    0 <= m(i) < required_span_size()
  LAW 2 (uniqueness):  is_unique()  ⇔  |{m(i)}| == |domain|
  LAW 3 (contiguity):  is_contiguous()  ⇔  {m(i)} == [0, required_span_size())
  LAW 4 (strides):     is_strided() ⇒ m(i + e_r) - m(i) == stride(r)  ∀ i, r
  LAW 5 (always-*):    is_always_X() ⇒ is_X() for every generated instance
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Extents,
    LayoutLeft,
    LayoutRight,
    LayoutStride,
    LayoutSymmetricPacked,
    LayoutTiledTPU,
)
from repro.core.distributed import DistributedLayout

sizes = st.lists(st.integers(1, 6), min_size=1, max_size=3)


def domain_offsets(layout):
    return np.array(layout.offsets_dense()).reshape(-1)


def check_laws(layout):
    offs = domain_offsets(layout)
    n = layout.extents.size()
    span = layout.required_span_size()
    assert offs.min() >= 0 and offs.max() < span, "LAW 1"
    unique = len(np.unique(offs)) == n
    # Table I law is one-directional: is_unique() true ONLY IF no aliasing
    # (a conservative False is allowed — LayoutStride's divisibility check).
    if layout.is_unique():
        assert unique, ("LAW 2 (claimed unique but aliases)", layout)
    contiguous = set(offs.tolist()) == set(range(span))
    if layout.is_contiguous():
        assert contiguous, ("LAW 3", layout)
    if layout.is_strided():
        ext = layout.extents
        strides = [layout.stride(r) for r in range(ext.rank)]
        for idx in ext.indices():
            base = layout(*idx)
            for r in range(ext.rank):
                nxt = list(idx)
                nxt[r] += 1
                if nxt[r] < ext.extent(r):
                    assert layout(*nxt) - base == strides[r], ("LAW 4", layout, idx, r)
    if type(layout).is_always_unique():
        assert layout.is_unique(), ("LAW 5 unique", layout)
    if type(layout).is_always_contiguous():
        assert layout.is_contiguous(), ("LAW 5 contiguous", layout)
    if type(layout).is_always_strided():
        assert layout.is_strided(), ("LAW 5 strided", layout)


@settings(max_examples=40, deadline=None)
@given(sizes)
def test_layout_right_laws(sz):
    check_laws(LayoutRight(Extents.fully_dynamic(*sz)))


@settings(max_examples=40, deadline=None)
@given(sizes)
def test_layout_left_laws(sz):
    check_laws(LayoutLeft(Extents.fully_dynamic(*sz)))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8))
def test_symmetric_packed_laws(n):
    lay = LayoutSymmetricPacked(Extents.fully_dynamic(n, n))
    check_laws(lay)
    # aliasing is exactly (i,j)~(j,i)
    for i in range(n):
        for j in range(n):
            assert lay(i, j) == lay(j, i)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(1, 5), min_size=2, max_size=2),
    st.sampled_from([(2, 4), (3, 5), (8, 128)]),
)
def test_tiled_laws(sz, tile):
    lay = LayoutTiledTPU(Extents.fully_dynamic(*sz), tile=tile)
    check_laws(lay)
    # padded iff extents don't divide the tile
    assert lay.is_contiguous() == (sz[0] % tile[0] == 0 and sz[1] % tile[1] == 0)


@settings(max_examples=60, deadline=None)
@given(
    sizes,
    st.integers(0, 3),
    st.data(),
)
def test_layout_stride_laws(sz, offset, data):
    # random strides that keep the mapping affine (may or may not alias)
    strides = tuple(
        data.draw(st.integers(1, 40), label=f"stride{r}") for r in range(len(sz))
    )
    lay = LayoutStride(Extents.fully_dynamic(*sz), strides, offset)
    check_laws(lay)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(1, 8), min_size=1, max_size=3),
    st.data(),
)
def test_distributed_layout_laws(sz, data):
    axes = {"data": 2, "model": 3}
    binding = tuple(
        data.draw(st.sampled_from([None, "data", "model"]), label=f"dim{r}")
        for r in range(len(sz))
    )
    # each axis used at most once
    used = [b for b in binding if b]
    if len(used) != len(set(used)):
        return
    lay = DistributedLayout(Extents.fully_dynamic(*sz), binding, axes)
    check_laws(lay)
    # GSPMD law: block sharding never aliases and each index lands on exactly one
    # (device, local offset) pair
    offs = domain_offsets(lay)
    assert len(np.unique(offs)) == lay.extents.size()


def test_non_strided_layouts_refuse_stride():
    from repro.core import LayoutError

    sp = LayoutSymmetricPacked(Extents.fully_dynamic(3, 3))
    with pytest.raises(LayoutError):
        sp.stride(0)
