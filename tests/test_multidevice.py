"""Multi-device behaviours (shard_map EP MoE, elastic restart) exercised in
SUBPROCESSES with a forced 8-device CPU topology — the main test process keeps
the default single-device view (per the dry-run isolation rule)."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def run_script(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout, env=env
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_shard_map_moe_matches_einsum_path():
    out = run_script(
        """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.models import get_config
from repro.models.moe import moe_specs, apply_moe, apply_moe_ep
from repro.models.layers import Sharder
from repro.launch.sharding import train_rules
from repro.core.distributed import tree_initialize

cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b", smoke=True), dtype="float32",
                          capacity_factor=8.0)  # no drops -> exact equality
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = train_rules(cfg)
p = tree_initialize(moe_specs(cfg), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model))
with mesh:
    y1, _ = jax.jit(lambda p, x: apply_moe(cfg, p, x, Sharder(None, None)))(p, x)
    y2, _ = jax.jit(lambda p, x: apply_moe_ep(cfg, p, x, Sharder(mesh, rules)))(p, x)
    g1 = jax.jit(jax.grad(lambda p, x: apply_moe(cfg, p, x, Sharder(None, None))[0].sum()))(p, x)
    g2 = jax.jit(jax.grad(lambda p, x: apply_moe_ep(cfg, p, x, Sharder(mesh, rules))[0].sum()))(p, x)
np.testing.assert_allclose(np.array(y2), np.array(y1), rtol=2e-4, atol=2e-4)
for k in g1:
    np.testing.assert_allclose(np.array(g2[k]), np.array(g1[k]), rtol=5e-3, atol=5e-3)
print("EP-OK")
"""
    )
    assert "EP-OK" in out


def test_elastic_restart_after_device_loss():
    out = run_script(
        """
import tempfile
from repro.runtime import RunConfig, TrainerLoop, simulate_failure
with tempfile.TemporaryDirectory() as d:
    run = RunConfig(arch="llama3.2-1b", smoke=True, steps=10, batch=8, seq=16,
                    ckpt_dir=d, ckpt_every=2, log_every=100)
    fail = simulate_failure(at_step=5)
    loop = TrainerLoop(run, failure_hook=fail.maybe_fail)
    n0 = len(loop.devices)
    out = loop.run_loop()
    assert len(loop.devices) < n0, "must re-mesh onto fewer devices"
    assert out["final_step"] == 10
    assert any(h["step"] == 9 for h in out["history"])
print("ELASTIC-OK")
"""
    )
    assert "ELASTIC-OK" in out


def test_sharded_train_step_matches_single_device():
    """DP+TP sharded train step computes the same loss as unsharded (exactness of
    the distribution layer, modulo bf16 reduction order)."""
    out = run_script(
        """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.models import get_config, build_model
from repro.models.layers import Sharder
from repro.launch.sharding import train_rules
from repro.optim import AdamWConfig
from repro.train import make_train_step
from repro.core.distributed import tree_initialize, tree_shardings

cfg = dataclasses.replace(get_config("llama3.2-1b", smoke=True), dtype="float32")
model = build_model(cfg)
batch = {"tokens": jax.random.randint(jax.random.key(2), (8, 17), 0, cfg.vocab)}

losses = {}
for shard_it in (False, True):
    mesh = jax.make_mesh((4, 2), ("data", "model")) if shard_it else None
    rules = train_rules(cfg) if shard_it else None
    step, ps, ss = make_train_step(model, AdamWConfig(lr=1e-3), mesh=mesh, rules=rules)
    params = tree_initialize(ps, jax.random.key(0))
    opt = tree_initialize(ss, jax.random.key(1))
    if shard_it:
        params = jax.device_put(params, tree_shardings(ps, mesh, rules))
        opt = jax.device_put(opt, tree_shardings(ss, mesh, rules))
        with mesh:
            _, _, m = jax.jit(step)(params, opt, batch)
    else:
        _, _, m = jax.jit(step)(params, opt, batch)
    losses[shard_it] = float(m["loss"])
assert abs(losses[True] - losses[False]) < 1e-3, losses
print("SHARD-OK", losses)
"""
    )
    assert "SHARD-OK" in out
