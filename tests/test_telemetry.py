"""Serving telemetry: streaming metrics registry, lifecycle trace, per-request
logprobs, and the straggler hook — the observability layer of the engine.

The registry replaces unbounded timing lists with O(1)-memory sketches, so
the tests pin the sketch's accuracy against exact numpy percentiles; the
trace is the host-side log of every engine transition, so the tests replay
runs that exercise each transition (admission, chunked prefill, preemption,
CoW, fused windows, finish) and cross-check the trace's event counts against
the engine's own metrics counters — two independent observers of the same
execution must agree.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import (
    EngineConfig, Request, RequestState, ServeEngine, validate_chrome_trace,
)
from repro.serving.telemetry import (
    SCHED_TRACK, Counter, EngineTrace, Gauge, Histogram, MetricsRegistry,
)


# =====================================================================================
# histogram / registry — O(1)-memory sketches
# =====================================================================================
def test_histogram_percentiles_match_numpy():
    """32 log buckets per decade bound relative error at ~7.5% worst-case;
    lognormal timing-like data lands well inside it."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6.0, sigma=0.7, size=20_000)  # ~ms-scale timings
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    for q in (50, 95, 99):
        exact = float(np.percentile(xs, q))
        assert abs(h.percentile(q) - exact) / exact < 0.075, q
    assert abs(h.mean - float(xs.mean())) / float(xs.mean()) < 1e-6
    snap = h.snapshot()
    assert snap["count"] == xs.size
    assert snap["min"] == pytest.approx(float(xs.min()))
    assert snap["max"] == pytest.approx(float(xs.max()))


def test_histogram_empty_single_and_out_of_range():
    h = Histogram()
    assert h.percentile(50) == 0.0
    assert h.snapshot()["count"] == 0
    h.observe(3.5e-3)
    assert h.percentile(50) == pytest.approx(3.5e-3)  # clamp to [min, max]
    assert h.percentile(99) == pytest.approx(3.5e-3)
    # under/overflow land in the edge buckets but percentiles stay clamped to
    # observed extremes — no fabricated values outside the data
    h2 = Histogram(lo=1e-3, hi=1e0)
    h2.observe(1e-6)
    h2.observe(42.0)
    assert h2.percentile(1) == pytest.approx(1e-6)
    assert h2.percentile(99) == pytest.approx(42.0)
    assert h2.snapshot()["count"] == 2


def test_histogram_sub_resolution_samples():
    """Samples below the default lo=1e-7 (sub-100ns 'timings' — clock jitter,
    zero-work steps) land in the underflow bucket but never corrupt the
    sketch: count/mean/min stay exact and percentiles never fabricate a value
    the data doesn't contain."""
    h = Histogram()
    tiny = (0.0, 1e-12, 9.9e-8)
    for v in tiny:
        h.observe(v)
    assert h.counts[0] == len(tiny)  # all three under lo -> underflow bucket
    assert h.percentile(50) == 0.0  # == observed min, not a bucket edge
    assert h.snapshot()["min"] == 0.0
    assert h.snapshot()["mean"] == pytest.approx(sum(tiny) / len(tiny))
    # a normal sample after the underflow run: p99 tops out at the real max
    h.observe(2e-3)
    assert h.percentile(99) == pytest.approx(2e-3)
    assert h.snapshot()["count"] == 4


def test_histogram_single_sample_every_percentile():
    """With one observation every percentile IS that observation — the
    interpolation path must clamp to [min, max] rather than report an edge of
    the covering bucket."""
    h = Histogram()
    h.observe(7.3e-4)
    for q in (0, 1, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(7.3e-4), q


def test_histogram_reset_then_record():
    """reset() must return the histogram to a pristine state: stale min/max
    or counts surviving a reset would poison the first post-reset snapshot —
    exactly the rehearsal -> reset_metrics -> measure idiom the bench suite
    leans on."""
    h = Histogram()
    for v in (1e-9, 5e-3, 2.0, 5e3):  # underflow, two in-range, overflow
        h.observe(v)
    h.reset()
    assert h.count == 0 and h.total == 0.0
    assert h.percentile(50) == 0.0
    assert all(c == 0 for c in h.counts)
    snap = h.snapshot()
    assert snap["min"] == 0.0 and snap["max"] == 0.0  # not inf / stale
    h.observe(4e-2)
    assert h.percentile(50) == pytest.approx(4e-2)
    assert h.snapshot()["count"] == 1
    assert h.min == pytest.approx(4e-2) and h.max == pytest.approx(4e-2)


def test_registry_create_or_get_and_reset():
    reg = MetricsRegistry()
    c = reg.counter("steps")
    assert reg.counter("steps") is c  # create-or-get: one instrument per name
    c.inc()
    c.inc(4)
    g = reg.gauge("depth")
    g.set(7.0)
    h = reg.histogram("lat")
    h.observe(0.25)
    snap = reg.snapshot()
    assert snap["steps"] == 5
    assert snap["depth"] == 7.0
    assert snap["lat"]["count"] == 1
    reg.reset()  # zero values, keep registrations (cached references stay live)
    assert c.value == 0
    assert g.value == 0.0
    assert h.snapshot()["count"] == 0
    assert reg.counter("steps") is c


def test_counter_gauge_direct():
    c = Counter()
    c.inc()
    assert c.value == 1
    g = Gauge()
    g.set(2.5)
    assert g.value == 2.5


# =====================================================================================
# trace ring + Chrome export invariants
# =====================================================================================
def test_trace_chrome_export_and_tracks():
    tr = EngineTrace()
    tr.instant("enqueue", rid=0)
    tr.begin("prefill", 0, rid=0)
    tr.end("prefill", 0)
    tr.begin("decode", SCHED_TRACK, batch=1)
    tr.end("decode", SCHED_TRACK)
    chrome = tr.to_chrome()
    validate_chrome_trace(chrome)
    evs = chrome["traceEvents"]
    # one thread-name metadata record per track, scheduler tid 0, slot s+1
    names = {e["tid"]: e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "scheduler" in names[0].lower()
    assert {e["tid"] for e in evs if e["ph"] != "M"} == {0, 1}
    ts = [e["ts"] for e in evs if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_trace_ring_wrap_still_validates():
    """Wrapping the ring can orphan B/E pairs at the edges; the export must
    repair them (drop stray Es, close stray Bs) so the file always opens."""
    tr = EngineTrace(capacity=8)
    for i in range(50):
        tr.begin("span", i % 3)
        tr.instant("tick", i % 3, i=i)
        tr.end("span", i % 3)
    assert tr.dropped > 0
    assert len(tr.events) == 8
    validate_chrome_trace(tr.to_chrome())


def test_trace_clear():
    tr = EngineTrace()
    tr.instant("x")
    tr.clear()
    assert len(tr.events) == 0
    assert tr.dropped == 0


def test_validate_chrome_trace_rejects_malformed():
    ok = {"traceEvents": [
        {"ph": "B", "name": "s", "pid": 1, "tid": 0, "ts": 1},
        {"ph": "E", "name": "s", "pid": 1, "tid": 0, "ts": 2},
    ]}
    validate_chrome_trace(ok)
    with pytest.raises(ValueError):
        validate_chrome_trace({})  # no traceEvents
    with pytest.raises(ValueError):  # decreasing ts
        validate_chrome_trace({"traceEvents": [
            {"ph": "i", "name": "a", "pid": 1, "tid": 0, "ts": 5},
            {"ph": "i", "name": "b", "pid": 1, "tid": 0, "ts": 1},
        ]})
    with pytest.raises(ValueError):  # E without B
        validate_chrome_trace({"traceEvents": [
            {"ph": "E", "name": "s", "pid": 1, "tid": 0, "ts": 1},
        ]})
    with pytest.raises(ValueError):  # mismatched span names
        validate_chrome_trace({"traceEvents": [
            {"ph": "B", "name": "a", "pid": 1, "tid": 0, "ts": 1},
            {"ph": "E", "name": "b", "pid": 1, "tid": 0, "ts": 2},
        ]})
    with pytest.raises(ValueError):  # unclosed span
        validate_chrome_trace({"traceEvents": [
            {"ph": "B", "name": "a", "pid": 1, "tid": 0, "ts": 1},
        ]})


# =====================================================================================
# engine integration — the trace and the metrics observe the same run
# =====================================================================================
@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def test_trace_off_by_default(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params, EngineConfig(num_pages=16, page_size=4, max_batch=2)
    )
    assert eng.trace is None
    rng = np.random.default_rng(0)
    eng.run([Request(
            rid=0,
            prompt=rng.integers(0, cfg.vocab, size=5).tolist(),
            params=GenerationParams(max_new_tokens=3),
        )])
    m = eng.metrics()
    assert m["requests"] == 1
    assert "slow_steps" in m


def test_preemption_run_trace_is_valid_and_matches_metrics(small_model, tmp_path):
    """Tight pool forces preemption mid-run; the exported trace must be valid
    Chrome JSON and its event counts must agree with the engine's counters —
    the trace IS the host-side allocator/scheduler log, just timestamped."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6, trace=True,
    ))
    rng = np.random.default_rng(3)
    reqs = [Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=8).tolist(),
            params=GenerationParams(max_new_tokens=10),
        ) for i in range(3)]
    results = eng.run(reqs)
    m = eng.metrics()
    assert m["preemptions"] >= 1  # the pool is sized to make this certain
    tr = eng.trace
    assert tr.count("enqueue") == len(reqs)
    assert tr.count("finish") == m["requests"]
    assert tr.count("preempt") == m["preemptions"]
    assert tr.count("cow") == m["cow_copies"]
    # every admission allocates exactly once (re-admissions after preemption
    # allocate again — both counts include them)
    assert tr.count("admit") == tr.count("alloc")
    assert tr.count("admit") == len(reqs) + m["preemptions"]
    assert tr.count("prefill", ph="B") == tr.count("prefill", ph="E")
    chrome = tr.to_chrome()
    validate_chrome_trace(chrome)
    path = tmp_path / "trace.json"
    tr.export(path)
    reloaded = json.loads(path.read_text())
    validate_chrome_trace(reloaded)
    # tracks: scheduler (tid 0) plus one per slot that saw events
    tids = {e["tid"] for e in reloaded["traceEvents"] if e["ph"] != "M"}
    assert 0 in tids and len(tids) >= 2
    assert all(results[r].error is None for r in results)


def test_chunked_run_traces_chunk_spans(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=32, page_size=4, max_batch=2, max_pages_per_seq=16,
        chunked_prefill=True, chunk_tokens=8, trace=True,
    ))
    rng = np.random.default_rng(5)
    eng.run([
        Request(
                rid=0,
                prompt=rng.integers(0, cfg.vocab, size=30).tolist(),
                params=GenerationParams(max_new_tokens=4),
            ),
        Request(
                rid=1,
                prompt=rng.integers(0, cfg.vocab, size=6).tolist(),
                params=GenerationParams(max_new_tokens=4),
            ),
    ])
    tr = eng.trace
    assert tr.count("chunk", ph="B") >= 2  # the 30-token prompt needs several
    assert tr.count("chunk", ph="B") == tr.count("chunk", ph="E")
    validate_chrome_trace(tr.to_chrome())
    assert eng.metrics()["chunk_ms_p50"] > 0


def test_fused_window_trace_k_sums_to_fused_steps(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig.sized_for(
        8 + 16 + 1, page_size=8, max_batch=2, multi_step=4, trace=True,
    ))
    rng = np.random.default_rng(7)
    eng.run([Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=8).tolist(),
            params=GenerationParams(max_new_tokens=16),
        ) for i in range(2)])
    m = eng.metrics()
    assert m["fused_steps"] > 0
    k_sum = sum(
        ev.args["k"] for ev in eng.trace.events
        if ev.name == "fused_window" and ev.ph == "B"
    )
    assert k_sum == m["fused_steps"]
    assert m["decode_steps"] >= m["fused_steps"]
    validate_chrome_trace(eng.trace.to_chrome())


def test_metrics_degenerate_paths(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=4, page_size=4, max_batch=2, max_pages_per_seq=4),
    )
    assert eng.metrics() == {}  # nothing ran yet
    # a prompt whose floor pages exceed the pool is refused at submit() — the
    # static twin of Scheduler.impossible (which covers preempted requests
    # whose context GREW past the pool at runtime)
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError, match="num_pages"):
        eng.submit(Request(
                rid=0,
                prompt=rng.integers(0, cfg.vocab, size=12).tolist(),
                params=GenerationParams(max_new_tokens=2),
            ))
    # all-failed snapshot: when every recorded request carries .error (the
    # reject_impossible outcome), metrics reports ONLY the failure count —
    # no throughput/latency keys fabricated from an empty sample
    eng.results[0] = RequestState(
        Request(
                rid=0,
                prompt=[1, 2, 3],
                params=GenerationParams(max_new_tokens=2),
            ), error="too big"
    )
    eng.results[1] = RequestState(
        Request(
                rid=1,
                prompt=[4, 5],
                params=GenerationParams(max_new_tokens=2),
            ), error="too big"
    )
    assert eng.metrics() == {"failed": 2}


def test_reset_metrics_zeroes_registry_and_trace(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=2, trace=True,
    ))
    rng = np.random.default_rng(2)
    make = lambda: [Request(
            rid=0,
            prompt=rng.integers(0, cfg.vocab, size=6).tolist(),
            params=GenerationParams(max_new_tokens=4),
        )]
    eng.run(make())
    assert eng.metrics()["decode_steps"] > 0
    assert len(eng.trace.events) > 0
    eng.reset_metrics()
    assert eng.metrics() == {}
    assert eng.registry.counter("decode_steps").value == 0
    assert eng.registry.histogram("step_time_s").snapshot()["count"] == 0
    assert len(eng.trace.events) == 0
    # the engine keeps serving after a reset, repopulating the same instruments
    eng.run(make())
    assert eng.metrics()["decode_steps"] > 0


def test_tokens_per_s_spans_arrival_to_finish(small_model):
    """Offset arrivals: throughput must divide by (max finish - min arrival),
    not by max finish alone — the old baseline under-reported whenever the
    first arrival wasn't at the run epoch."""
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params, EngineConfig(num_pages=16, page_size=4, max_batch=2)
    )
    rng = np.random.default_rng(4)
    offset = 0.2
    eng.run([Request(
            rid=0,
            prompt=rng.integers(0, cfg.vocab, size=5).tolist(),
            params=GenerationParams(max_new_tokens=4),
            arrival_time=offset,
        )])
    m = eng.metrics()
    span = m["wall_s"] - offset
    assert span > 0
    assert m["tokens_per_s"] == pytest.approx(m["generated_tokens"] / span)
    assert m["tokens_per_s"] > m["generated_tokens"] / m["wall_s"]


# =====================================================================================
# per-request top-k logprobs (ride the existing per-token fetch)
# =====================================================================================
def test_logprobs_greedy_top1_is_generated_token(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=3, logprobs_k=3,
    ))
    rng = np.random.default_rng(6)
    reqs = [
        Request(
                rid=0,
                prompt=rng.integers(0, cfg.vocab, size=5).tolist(),
                params=GenerationParams(max_new_tokens=5, logprobs=2),
            ),
        Request(
                rid=1,
                prompt=rng.integers(0, cfg.vocab, size=7).tolist(),
                params=GenerationParams(max_new_tokens=5, logprobs=3),
            ),
        Request(
                rid=2,
                prompt=rng.integers(0, cfg.vocab, size=6).tolist(),
                params=GenerationParams(max_new_tokens=5),
            ),  # no opt-in: no logprobs recorded
    ]
    results = eng.run(reqs)
    assert results[2].logprobs == {}
    for rid, want_k in ((0, 2), (1, 3)):
        s = results[rid]
        assert sorted(s.logprobs) == list(range(len(s.generated)))
        for idx, tok in enumerate(s.generated):
            entries = s.logprobs[idx]
            assert len(entries) == want_k
            ids = [t for t, _ in entries]
            vals = [v for _, v in entries]
            # greedy: the sampled token IS the top-1 logprob id
            assert ids[0] == tok
            assert vals == sorted(vals, reverse=True)
            assert all(v <= 0.0 for v in vals)  # log-probabilities


def test_logprobs_wider_than_engine_rejected(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=2, logprobs_k=3,
    ))
    with pytest.raises(ValueError, match="logprobs"):
        eng.submit(Request(
                rid=0,
                prompt=[1, 2, 3],
                params=GenerationParams(max_new_tokens=2, logprobs=5),
            ))


def test_logprobs_identical_across_fused_horizons(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(2)]
    make = lambda: [Request(
            rid=i,
            prompt=list(p),
            params=GenerationParams(max_new_tokens=12, logprobs=3),
        )
                    for i, p in enumerate(prompts)]
    conf = EngineConfig.sized_for(8 + 12 + 1, page_size=8, max_batch=2,
                                  logprobs_k=3)
    res = {}
    for k in (1, 4):
        res[k] = ServeEngine(
            model, params, dataclasses.replace(conf, multi_step=k)
        ).run(make())
    for rid in res[1]:
        a, b = res[1][rid], res[4][rid]
        assert a.generated == b.generated
        assert sorted(a.logprobs) == sorted(b.logprobs)
        for idx in a.logprobs:
            assert [t for t, _ in a.logprobs[idx]] == [t for t, _ in b.logprobs[idx]]
            np.testing.assert_allclose(
                [v for _, v in a.logprobs[idx]],
                [v for _, v in b.logprobs[idx]], rtol=1e-4, atol=1e-5,
            )


# =====================================================================================
# straggler hook — slow decode steps are counted and traced
# =====================================================================================
def test_straggler_flags_slow_steps(small_model):
    """threshold < 1 makes every post-seed step 'slower than threshold x EMA',
    so the policy must flag steps, the counter must advance, and each flag
    must land in the trace — without perturbing the run."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=2, trace=True,
        slow_step_threshold=0.01,
    ))
    rng = np.random.default_rng(9)
    make = lambda: [Request(
            rid=0,
            prompt=rng.integers(0, cfg.vocab, size=6).tolist(),
            params=GenerationParams(max_new_tokens=8),
        )]
    # rehearse first: the compile-laden first dispatch would otherwise seed
    # the EMA ~1000x above steady state and nothing would ever flag.
    # reset_metrics restarts the EMA along with the counters.
    eng.run(make())
    eng.reset_metrics()
    results = eng.run(make())
    m = eng.metrics()
    assert m["slow_steps"] > 0
    assert eng.trace.count("slow_step") == m["slow_steps"]
    assert results[0].error is None
    ev = next(e for e in eng.trace.events if e.name == "slow_step")
    assert ev.args["verdict"] in ("straggle", "rebalance")
    assert ev.args["step_ms"] > 0
