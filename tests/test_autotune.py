"""Kernel autotuner + decode block-shape knob: the self-tuning layer's laws.

Three bands:

*Knob semantics* — ``effective_block_pages`` snaps any requested block count
to a divisor of the table width (the grid factorization needs exactness), and
the blocked decode paths (Pallas 4D grid and the scanning jnp twin) must be
VALUE-IDENTICAL to the unblocked single-gather reference for every legal
block count — the knob reorders the walk, never the math.

*Tuner selection laws* — the sweep is a measurement, so its selection logic
is tested with measurements faked deterministic: ties break toward the
simplest schedule, and the default schedule is only displaced by a decisive
win (noise-driven regressions are the failure mode the displacement rule
exists for). The disk cache round-trips, ignores foreign schemas, and a warm
``resolve`` is a pure file read (source="cached").

*Engine integration* — ``EngineConfig(autotune=True)`` fills exactly the
fields left at their auto sentinels (page_size=0 via sized_for,
decode_block_pages=0), surfaces the decision in ``metrics()`` and as a
``tuning_selected`` trace instant, and non-autotune engines keep their
metrics snapshot byte-identical to before the feature existed.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.kernels.paged_attention import (
    paged_decode_attention_jnp, paged_decode_attention_quant_jnp,
)
from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.engine.kvquant import KV_DTYPES


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


# =====================================================================================
# effective_block_pages — the divisor-snapping law
# =====================================================================================
def test_effective_block_pages_snaps_to_divisors():
    assert ops.effective_block_pages(None, 6) == 1
    assert ops.effective_block_pages(0, 6) == 1
    assert ops.effective_block_pages(1, 6) == 1
    assert ops.effective_block_pages(4, 6) == 3   # largest divisor <= 4
    assert ops.effective_block_pages(8, 6) == 6   # clamped to max_pages
    assert ops.effective_block_pages(100, 7) == 7
    assert ops.effective_block_pages(5, 7) == 1   # 7 prime: only 1 divides
    assert ops.effective_block_pages(4, 0) == 1   # degenerate table


# =====================================================================================
# blocked decode == unblocked decode (f32 and quantized, jnp twin + dispatch)
# =====================================================================================
def _case(rng, *, b=3, hq=4, hkv=2, d=8, ps=4, max_pages=6):
    num_pages = b * max_pages + 1
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    pool = jnp.asarray(
        rng.standard_normal((2, num_pages, hkv, ps, d)), jnp.float32
    )
    tables = jnp.asarray(
        1 + np.arange(b * max_pages, dtype=np.int32).reshape(b, max_pages)
    )
    lens = jnp.asarray([max_pages * ps, 9, 5], jnp.int32)  # full / partial x2
    return q, pool[0], pool[1], tables, lens


def test_blocked_jnp_twin_matches_unblocked_f32():
    rng = np.random.default_rng(3)
    q, k, v, tables, lens = _case(rng)
    ref = paged_decode_attention_jnp(q, k, v, tables, lens)
    for bp in (2, 3, 6):
        out = paged_decode_attention_jnp(q, k, v, tables, lens, block_pages=bp)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6,
        )


@pytest.mark.parametrize("bits", [8, 4])
def test_blocked_quant_twin_matches_unblocked(bits):
    rng = np.random.default_rng(4)
    q, k, v, tables, lens = _case(rng)
    spec = KV_DTYPES["int8" if bits == 8 else "int4"]
    ek, ev = spec.encode_pages(k), spec.encode_pages(v)
    ref = paged_decode_attention_quant_jnp(
        q, ek["q"], ek["scale"], ev["q"], ev["scale"], tables, lens, bits=bits,
    )
    for bp in (2, 3):
        out = paged_decode_attention_quant_jnp(
            q, ek["q"], ek["scale"], ev["q"], ev["scale"], tables, lens,
            bits=bits, block_pages=bp,
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6,
        )


def test_ops_dispatch_snaps_illegal_block_pages():
    """ops.paged_decode_attention accepts ANY block_pages (it snaps via
    effective_block_pages before dispatching); value equality holds even for
    requests that don't divide the table width."""
    rng = np.random.default_rng(5)
    q, k, v, tables, lens = _case(rng)
    ref = ops.paged_decode_attention(q, k, v, tables, lens)
    for bp in (None, 1, 4, 100):
        out = ops.paged_decode_attention(q, k, v, tables, lens, block_pages=bp)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6,
        )


# =====================================================================================
# tuner selection laws (measurements faked deterministic)
# =====================================================================================
def _sweep_with(monkeypatch, times_us, **kw):
    # candidate walk order is page_sizes outer, block_pages inner — mirror it
    page_sizes = tuple(sorted({ps for ps, _ in times_us}))
    block_pages = tuple(sorted({bp for _, bp in times_us}))
    walk = [
        ((ps, bp), times_us[(ps, bp)])
        for ps in page_sizes for bp in block_pages
    ]
    it = iter(walk)
    monkeypatch.setattr(
        autotune, "_time_decode", lambda fn, args, reps=1: next(it)[1] * 1e-6
    )
    # the decode-grid laws under test are independent of the chunk sweep
    # (schema 2 times it separately); pin it to the page-derived default
    monkeypatch.setattr(
        autotune, "sweep_chunk_tokens",
        lambda cfg, *, page_size, **k: 2 * page_size,
    )
    cfg = get_config("qwen2-0.5b", smoke=True)
    return autotune.sweep(
        cfg, page_sizes=page_sizes, block_pages=block_pages, **kw
    )


def test_chunk_tokens_swept_per_token(monkeypatch):
    """schema 2: chunk_tokens comes from real chunk timings compared PER
    TOKEN, with the tie band breaking to the historical 2*page_size."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    # dispatch-bound host: every width costs the same wall per CALL, so
    # per-token cost decisively favors the widest chunk
    monkeypatch.setattr(autotune, "_time_decode", lambda fn, args, reps=1: 1e-4)
    assert autotune.sweep_chunk_tokens(cfg, page_size=16, batch=2) == 64
    # compute-bound host: wall scales linearly with width, so every
    # candidate ties per-token and the default 2*page_size keeps its seat
    widths = iter((16, 32, 64))
    monkeypatch.setattr(
        autotune, "_time_decode", lambda fn, args, reps=1: 1e-6 * next(widths)
    )
    assert autotune.sweep_chunk_tokens(cfg, page_size=16, batch=2) == 32


def test_sweep_ties_break_to_simplest_schedule(monkeypatch):
    # all candidates within the tie band -> largest page_size, smallest bp
    point = _sweep_with(monkeypatch, {
        (8, 1): 100, (8, 2): 99, (16, 1): 101, (16, 2): 103,
    })
    assert (point.page_size, point.block_pages) == (16, 1)
    assert point.chunk_tokens == 2 * 16
    assert point.source == "swept"


def test_sweep_default_displaced_only_by_decisive_win(monkeypatch):
    # 15% faster is NOT decisive: the (16, 1) anchor keeps its seat
    point = _sweep_with(monkeypatch, {
        (8, 1): 85, (8, 2): 100, (16, 1): 100, (16, 2): 100,
    })
    assert (point.page_size, point.block_pages) == (16, 1)
    # 2x faster IS: the winner displaces the anchor
    point = _sweep_with(monkeypatch, {
        (8, 1): 50, (8, 2): 100, (16, 1): 100, (16, 2): 100,
    })
    assert (point.page_size, point.block_pages) == (8, 1)


def test_cache_roundtrip_and_schema_guard(tmp_path):
    path = tmp_path / "tune.json"
    assert autotune.load_cache(path) == {}  # missing file -> empty, no raise
    entries = {"m/f32/b4": autotune.default_point().as_dict()}
    autotune.save_cache(path, entries)
    assert autotune.load_cache(path) == entries
    path.write_text(json.dumps({"schema": 999, "entries": entries}))
    assert autotune.load_cache(path) == {}  # foreign schema -> ignored
    path.write_text("not json")
    assert autotune.load_cache(path) == {}


def test_resolve_cold_warm_and_projection(tmp_path, monkeypatch):
    cfg = get_config("qwen2-0.5b", smoke=True)
    path = tmp_path / "tune.json"
    # cold + allow_sweep=False: the default point, nothing written
    p = autotune.resolve(cfg, batch=4, cache_path=path, allow_sweep=False)
    assert p.source == "default" and not path.exists()
    # cold + sweep (timings faked): winner lands in the cache
    monkeypatch.setattr(autotune, "_time_decode", lambda fn, args, reps=1: 1e-4)
    p = autotune.resolve(
        cfg, batch=4, seq_len=64, cache_path=path,
    )
    assert p.source == "swept" and path.exists()
    key = autotune.tuning_key(cfg.name, "f32", 4, 64)
    assert key in autotune.load_cache(path)
    # warm: pure file read, source says so
    def boom(*a, **k):
        raise AssertionError("warm resolve must not re-sweep")
    monkeypatch.setattr(autotune, "_time_decode", boom)
    p2 = autotune.resolve(cfg, batch=4, seq_len=64, cache_path=path)
    assert p2.source == "cached"
    assert (p2.page_size, p2.block_pages) == (p.page_size, p.block_pages)
    # pinned page_size projects the cached entry onto the pinned extent
    p3 = autotune.resolve(cfg, batch=4, seq_len=64, cache_path=path, page_size=8)
    assert p3.page_size == 8 and p3.chunk_tokens == 16
    # batch buckets: 3 and 4 share the pow2 bucket, 5 does not
    assert autotune.tuning_key("m", "f32", 3) == autotune.tuning_key("m", "f32", 4)
    assert autotune.tuning_key("m", "f32", 5) != autotune.tuning_key("m", "f32", 4)
    assert autotune.tuning_key("m", "f32", 4, 33) == autotune.tuning_key("m", "f32", 4, 64)


# =====================================================================================
# engine integration: sentinels filled, decision surfaced, opt-out untouched
# =====================================================================================
def _seed_cache(path, cfg, kv_dtype, batch, seq_len, point):
    autotune.save_cache(
        path,
        {autotune.tuning_key(cfg.name, kv_dtype, batch, seq_len):
         point.as_dict()},
    )


def test_engine_autotune_fills_sentinels_and_surfaces(small_model, tmp_path,
                                                      monkeypatch):
    cfg, model, params = small_model
    path = tmp_path / "tune.json"
    monkeypatch.setattr(autotune, "DEFAULT_CACHE_PATH", path)
    tuned = autotune.TunedPoint(
        page_size=8, block_pages=2, chunk_tokens=16, source="swept",
        us_per_step=1.0,
    )
    _seed_cache(path, cfg, "f32", 2, 40, tuned)
    conf = EngineConfig.sized_for(
        40, page_size=0, max_batch=2, autotune=True, trace=True,
    )
    eng = ServeEngine(model, params, conf)
    # page_size=0 materialized from the cache at init: pool sized at ps=8
    assert eng.config.page_size == 8
    assert eng.config.decode_block_pages == 2
    pps = -(-40 // 8) + 1
    assert eng.config.max_pages_per_seq == pps
    assert eng.config.num_pages == 2 * pps + 1
    assert eng.tuned is not None and eng.tuned.source == "cached"
    # the engine actually RUNS with the tuned shapes (not just reports them)
    eng.run([Request(rid=0, prompt=[1, 2, 3],
                     params=GenerationParams(max_new_tokens=4))])
    m = eng.metrics()
    assert m["tuned_page_size"] == 8
    assert m["tuned_block_pages"] == 2
    assert m["tuned_source"] == "cached"
    names = [ev.name for ev in eng.trace.events]
    assert "tuning_selected" in names


def test_engine_autotune_respects_pinned_fields(small_model, tmp_path,
                                                monkeypatch):
    cfg, model, params = small_model
    path = tmp_path / "tune.json"
    monkeypatch.setattr(autotune, "DEFAULT_CACHE_PATH", path)
    tuned = autotune.TunedPoint(
        page_size=16, block_pages=4, chunk_tokens=32, source="swept",
        us_per_step=1.0,
    )
    _seed_cache(path, cfg, "f32", 2, 40, tuned)
    # page_size pinned by the user: the tuner only fills decode_block_pages
    # (the cached entry is projected onto the pinned extent)
    conf = EngineConfig.sized_for(
        40, page_size=4, max_batch=2, autotune=True,
    )
    eng = ServeEngine(model, params, conf)
    assert eng.config.page_size == 4
    assert eng.config.decode_block_pages == 4
    # ...and a pinned decode_block_pages survives tuning untouched
    conf2 = EngineConfig.sized_for(
        40, page_size=4, max_batch=2, autotune=True, decode_block_pages=1,
    )
    eng2 = ServeEngine(model, params, conf2)
    assert eng2.config.decode_block_pages == 1


def test_engine_without_autotune_unchanged(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params, EngineConfig(num_pages=16, page_size=4, max_batch=2),
    )
    assert eng.tuned is None
    m = eng.metrics()
    assert m == {}  # the pre-feature empty snapshot, no tuned_* keys
    with pytest.raises(ValueError):
        EngineConfig.sized_for(40, page_size=0, max_batch=2)  # needs autotune


def test_engine_blocked_decode_matches_unblocked(small_model):
    """The knob end to end: the same greedy trace through decode_block_pages
    pinned at 2 and the unblocked default must be token-exact."""
    cfg, model, params = small_model
    make = lambda: [
        Request(rid=i,
                prompt=np.random.default_rng(30 + i).integers(
                    1, cfg.vocab, size=6).tolist(),
                params=GenerationParams(max_new_tokens=8))
        for i in range(2)
    ]
    outs = {}
    for bp in (0, 2):
        conf = EngineConfig.sized_for(
            16, page_size=4, max_batch=2, decode_block_pages=bp,
        )
        eng = ServeEngine(model, params, conf)
        results = eng.run(make())
        outs[bp] = {rid: s.generated for rid, s in results.items()}
    assert outs[0] == outs[2]
