"""Paged serving subsystem: LayoutPaged laws, paged-attention kernel vs the dense
reference, and the continuous-batching engine vs the unbatched decode path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Extents, LayoutError, LayoutPaged, LayoutRight
from repro.kernels import ref
from repro.kernels.paged_attention import (
    paged_decode_attention_jnp,
    paged_flash_decode,
    paged_flash_prefill_chunk,
    paged_prefill_chunk_jnp,
)
from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import (
    PREFILLING, EngineConfig, Request, ServeEngine, aligned_max_logit_err,
)


# =====================================================================================
# LayoutPaged — Table I observer protocol
# =====================================================================================
def test_layout_paged_dense_table_matches_layout_right():
    """Identity block table == LayoutRight over the page-factored domain."""
    S, H, MP, D, ps = 2, 3, 8, 4, 4
    lp = LayoutPaged.dense(S, H, MP, D, ps)
    lr = LayoutRight(Extents.fully_dynamic(S, MP // ps, H, ps, D))
    for s in range(S):
        for h in range(H):
            for p in range(MP):
                for d in range(D):
                    assert lp(s, h, p, d) == lr(s, p // ps, h, p % ps, d)
    assert lp.is_unique()
    assert lp.is_contiguous()  # table is a bijection onto the pool
    assert not lp.is_strided()


def test_layout_paged_observers_on_scattered_table():
    H, D, ps = 2, 4, 4
    lp = LayoutPaged(Extents.fully_dynamic(2, H, 8, D), ((5, 2), (7, 0)), ps, 9)
    assert lp.is_unique()
    assert not lp.is_contiguous()  # pool over-provisioned: 4 of 9 pages used
    assert not lp.is_strided()
    assert lp.required_span_size() == 9 * H * ps * D
    assert lp.pool_shape() == (9, H, ps, D)
    with pytest.raises(LayoutError):
        lp.stride(0)
    # full-domain image: injective, inside the codomain
    offs = np.array(lp.offsets_dense()).reshape(-1)
    assert len(set(offs.tolist())) == offs.size
    assert 0 <= offs.min() and offs.max() < lp.required_span_size()


def test_layout_paged_aliasing_table_not_unique():
    lp = LayoutPaged(Extents.fully_dynamic(2, 2, 8, 4), ((1, 2), (2, 3)), 4, 5)
    assert not lp.is_unique()


def test_layout_paged_traced_indices_match_python_ints():
    lp = LayoutPaged(Extents.fully_dynamic(2, 2, 8, 4), ((5, 2), (7, 0)), 4, 9)
    for idx in [(0, 1, 3, 2), (1, 0, 5, 3), (1, 1, 7, 0)]:
        traced = lp(*(jnp.int32(i) for i in idx))
        assert int(traced) == lp(*idx)


def test_layout_paged_validation():
    with pytest.raises(TypeError):
        LayoutPaged(Extents.fully_dynamic(2, 2, 7, 4), ((0,), (1,)), 4, 2)  # 7 % 4
    with pytest.raises(TypeError):
        LayoutPaged(Extents.fully_dynamic(2, 2, 8, 4), ((0, 1),), 4, 2)  # 1 row for 2 seqs
    with pytest.raises(ValueError):
        LayoutPaged(Extents.fully_dynamic(1, 2, 8, 4), ((0, 9),), 4, 2)  # page id oob


# =====================================================================================
# paged-attention kernel vs dense reference
# =====================================================================================
@pytest.mark.parametrize(
    "batch,page_size,lens",
    [
        (2, 8, (5, 20)),      # mixed lengths, partial last pages
        (3, 16, (1, 16, 31)), # page-exact and one-token edge cases
        (1, 4, (13,)),        # many small pages
    ],
)
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_paged_decode_matches_dense_reference(batch, page_size, lens, impl):
    hq, hkv, d = 4, 2, 16
    max_pages = -(-max(lens) // page_size)
    num_pages = batch * max_pages + 1  # + null page 0
    rng = np.random.default_rng(batch * 100 + page_size)
    q = jnp.asarray(rng.standard_normal((batch, hq, 1, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((num_pages, hkv, page_size, d)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((num_pages, hkv, page_size, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, num_pages)).reshape(batch, max_pages)
    bt = jnp.asarray(perm, jnp.int32)
    cl = jnp.asarray(lens, jnp.int32)
    if impl == "pallas":
        out = paged_flash_decode(q, k_pool, v_pool, bt, cl, interpret=True)
    else:
        out = paged_decode_attention_jnp(q, k_pool, v_pool, bt, cl)
    # densify through the block table, then the plain attention oracle
    k_dense = jnp.moveaxis(k_pool[bt], 2, 1).reshape(batch, hkv, max_pages * page_size, d)
    v_dense = jnp.moveaxis(v_pool[bt], 2, 1).reshape(batch, hkv, max_pages * page_size, d)
    for b, L in enumerate(lens):
        want = ref.attention(
            q[b : b + 1], k_dense[b : b + 1, :, :L], v_dense[b : b + 1, :, :L],
            causal=True, q_offset=L - 1,
        )
        np.testing.assert_allclose(
            np.array(out[b], np.float32), np.array(want[0], np.float32),
            rtol=2e-5, atol=2e-5,
        )


# =====================================================================================
# chunked-prefill attention kernel vs dense reference
# =====================================================================================
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_chunk_prefill_attention_matches_dense_reference(impl):
    """Two-part chunk attention (past from the pool, present from f32) equals
    full causal attention over [past | chunk] densified through the table."""
    hq, hkv, d, ps, C, max_pages = 4, 2, 16, 4, 8, 6
    num_pages = 2 * max_pages + 1
    rng = np.random.default_rng(0)
    cursors = np.array([4, 8], np.int32)  # page-aligned resident counts
    valid = (8, 5)                        # row 1: a partial final chunk
    q = jnp.asarray(rng.standard_normal((2, hq, C, d)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((2, hkv, C, d)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((2, hkv, C, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((num_pages, hkv, ps, d)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((num_pages, hkv, ps, d)), jnp.float32)
    bt = jnp.asarray(
        rng.permutation(np.arange(1, num_pages)).reshape(2, max_pages), jnp.int32
    )
    cur = jnp.asarray(cursors)
    if impl == "pallas":
        out = paged_flash_prefill_chunk(
            q, ck, cv, k_pool, v_pool, bt, cur, interpret=True
        )
    else:
        out = paged_prefill_chunk_jnp(q, ck, cv, k_pool, v_pool, bt, cur)
    k_dense = jnp.moveaxis(k_pool[bt], 2, 1).reshape(2, hkv, max_pages * ps, d)
    v_dense = jnp.moveaxis(v_pool[bt], 2, 1).reshape(2, hkv, max_pages * ps, d)
    for b in range(2):
        kk = jnp.concatenate([k_dense[b : b + 1, :, : int(cursors[b])], ck[b : b + 1]], axis=2)
        vv = jnp.concatenate([v_dense[b : b + 1, :, : int(cursors[b])], cv[b : b + 1]], axis=2)
        for t in range(valid[b]):
            L = int(cursors[b]) + t + 1
            want = ref.attention(
                q[b : b + 1, :, t : t + 1], kk[:, :, :L], vv[:, :, :L],
                causal=True, q_offset=L - 1,
            )
            np.testing.assert_allclose(
                np.array(out[b, :, t], np.float32), np.array(want[0, :, 0], np.float32),
                rtol=2e-5, atol=2e-5,
            )


# =====================================================================================
# engine — continuous batching vs the unbatched path
# =====================================================================================
@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def unbatched_greedy(cfg, model, params, prompt, n):
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = model.prefill(params, toks, max_len=len(prompt) + n + 1)
    out = [int(jnp.argmax(logits[0, 0, : cfg.vocab]))]
    for g in range(n - 1):
        l, caches = model.decode_step(
            params, caches, jnp.asarray([out[-1]], jnp.int32), len(prompt) + g
        )
        out.append(int(jnp.argmax(l[0, : cfg.vocab])))
    return out


def test_engine_streams_mixed_lengths_matches_unbatched(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    lengths = (5, 9, 16, 3, 12)
    prompts = [rng.integers(0, cfg.vocab, size=L).tolist() for L in lengths]
    n_gen = 6
    reqs = [Request(
            rid=i,
            prompt=p,
            params=GenerationParams(max_new_tokens=n_gen),
        ) for i, p in enumerate(prompts)]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=32, page_size=4, max_batch=4, max_pages_per_seq=8),
    )
    results = eng.run(reqs)
    assert set(results) == set(range(len(prompts)))
    for i, p in enumerate(prompts):
        assert results[i].generated == unbatched_greedy(cfg, model, params, p, n_gen)
    m = eng.metrics()
    assert m["requests"] == len(prompts)
    assert m["generated_tokens"] == len(prompts) * n_gen


def test_engine_preempts_under_page_pressure_and_stays_exact(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    n_gen = 10
    reqs = [Request(
            rid=i,
            prompt=p,
            params=GenerationParams(max_new_tokens=n_gen),
        ) for i, p in enumerate(prompts)]
    # 9 usable pages; each sequence grows to ceil(18/4) = 5 pages -> contention
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6),
    )
    results = eng.run(reqs)
    assert eng.metrics()["preemptions"] >= 1
    for i, p in enumerate(prompts):
        assert results[i].generated == unbatched_greedy(cfg, model, params, p, n_gen)


def test_engine_prefix_sharing_exact_and_saves_pages(small_model):
    """Shared-prefix burst: outputs are token-exact vs. sharing disabled, and
    the shared pool peaks far lower (capacity O(unique tokens))."""
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, size=16).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab, size=4).tolist() for _ in range(4)]
    n_gen = 5
    make_reqs = lambda: [
        Request(
                rid=i,
                prompt=p,
                params=GenerationParams(max_new_tokens=n_gen),
            ) for i, p in enumerate(prompts)
    ]
    econf = EngineConfig(num_pages=48, page_size=4, max_batch=4, max_pages_per_seq=8)
    eng_on = ServeEngine(model, params, econf)
    eng_off = ServeEngine(model, params, dataclasses.replace(econf, prefix_sharing=False))
    res_on = eng_on.run(make_reqs())
    res_off = eng_off.run(make_reqs())
    for i in range(len(prompts)):
        assert res_on[i].generated == res_off[i].generated
        assert res_on[i].generated == unbatched_greedy(cfg, model, params, prompts[i], n_gen)
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_on["pages_shared"] > 0 and m_off["pages_shared"] == 0
    # 4 sequences share 4 prefix pages: 12 of the pool's pages never needed
    assert m_on["peak_pages_in_use"] <= m_off["peak_pages_in_use"] - 12


def test_engine_forced_cow_identical_prompts_exact(small_model):
    """Identical prompts whose length is NOT page-aligned share even the partial
    last page; the first decode append of each sequence scatters into it, so
    copy-on-write MUST fire — and outputs still match the unbatched oracle."""
    cfg, model, params = small_model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=10).tolist()  # 10 % 4 != 0
    n_gen = 6
    reqs = [Request(
            rid=i,
            prompt=list(prompt),
            params=GenerationParams(max_new_tokens=n_gen),
        ) for i in range(3)]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=32, page_size=4, max_batch=3, max_pages_per_seq=8),
    )
    results = eng.run(reqs)
    m = eng.metrics()
    assert m["cow_copies"] >= 2  # every co-tenant of the partial page but one
    assert m["pages_shared"] >= 6  # 3 pages adopted by each of requests 1, 2
    want = unbatched_greedy(cfg, model, params, prompt, n_gen)
    for i in range(3):
        assert results[i].generated == want


def test_engine_sharing_stays_exact_under_preemption(small_model):
    """Tiny pool + shared prefixes: preemption frees only refcount-zero pages
    and re-admission re-shares what survived; greedy outputs stay exact."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab, size=8).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab, size=2).tolist() for _ in range(3)]
    n_gen = 10
    reqs = [Request(
            rid=i,
            prompt=p,
            params=GenerationParams(max_new_tokens=n_gen),
        ) for i, p in enumerate(prompts)]
    # 10 usable pages; the full batch peaks at 2 shared + 3x3 own = 11 -> contention
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=11, page_size=4, max_batch=3, max_pages_per_seq=6),
    )
    results = eng.run(reqs)
    m = eng.metrics()
    assert m["preemptions"] >= 1
    assert m["pages_shared"] > 0
    for i, p in enumerate(prompts):
        assert results[i].generated == unbatched_greedy(cfg, model, params, p, n_gen)


@pytest.mark.parametrize("kv_dtype,bound", [("int8", 0.75), ("int4", 2.0)])
def test_engine_quantized_kv_bounded_error_and_smaller_pool(small_model, kv_dtype, bound):
    """The whole serving stack over intN pages: same shared-prefix burst
    (adoption + forced CoW on the partial last page) through an f32 and a
    quantized engine. All requests complete, prefix sharing and CoW fire
    identically (allocator is representation-blind), the pool holds the same
    tokens in far fewer bytes, and logits on identical contexts stay within a
    calibrated bound of f32."""
    cfg, model, params = small_model
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab, size=10).tolist()  # 10 % 4 != 0 -> CoW
    prompts = [list(prefix) for _ in range(2)]
    prompts += [prefix + rng.integers(0, cfg.vocab, size=3).tolist()]
    n_gen = 5
    make_reqs = lambda: [
        Request(rid=i, prompt=list(p), params=GenerationParams(max_new_tokens=n_gen))
        for i, p in enumerate(prompts)
    ]
    econf = EngineConfig(num_pages=32, page_size=4, max_batch=3, max_pages_per_seq=8,
                         record_logits=True)
    eng_f32 = ServeEngine(model, params, econf)
    eng_q = ServeEngine(model, params, dataclasses.replace(econf, kv_dtype=kv_dtype))
    res_f32 = eng_f32.run(make_reqs())
    res_q = eng_q.run(make_reqs())
    assert set(res_q) == set(range(len(prompts)))
    assert all(len(res_q[r].generated) == n_gen for r in res_q)
    m_f32, m_q = eng_f32.metrics(), eng_q.metrics()
    # allocator behavior identical across representations
    assert m_q["pages_shared"] == m_f32["pages_shared"] > 0
    assert m_q["cow_copies"] == m_f32["cow_copies"] >= 1
    assert m_q["peak_pages_in_use"] == m_f32["peak_pages_in_use"]
    # capacity: same pages, a fraction of the bytes
    assert m_f32["kv_pool_bytes"] / m_q["kv_pool_bytes"] >= 1.9
    err = aligned_max_logit_err(eng_f32, eng_q, res_f32, res_q)
    assert 0 < err < bound, f"{kv_dtype} max logit err {err} outside (0, {bound})"


def test_engine_quant_dense_view_matches_prefill_within_scale_bound(small_model):
    """The quantized scatter path implements the layout map: reading the int8
    pool back through LayoutPaged offsets reproduces the dense prefill cache
    elementwise within half a quantization step of each (page, head) scale."""
    cfg, model, params = small_model
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=10).tolist()
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=16, page_size=4, max_batch=2, max_pages_per_seq=8,
                     kv_dtype="int8"),
    )
    eng.submit(Request(rid=0, prompt=prompt, params=GenerationParams(max_new_tokens=1)))
    eng._t0 = 0.0
    eng.queue.push(eng._pending.pop())
    eng._admit_and_prefill(0.0)
    layout = eng.cache.layout_for(0)
    assert layout.is_unique() and not layout.is_strided()
    k_paged, _ = eng.cache.dense_view(0)  # decoded through the accessor
    _, caches = model.prefill(params, jnp.asarray([prompt], jnp.int32), max_len=12)
    k_dense = np.array(caches[0]["k"][0, 0, :, : len(prompt)], np.float32)
    # per-(page, head) half-step bound, gathered to each token's page
    scales = np.array(eng.cache.pools[0]["k"]["scale"][0])  # (num_pages, Hkv)
    pages = np.array(eng.cache.pages_of[0])[
        np.arange(len(prompt)) // eng.cache.page_size
    ]
    bound = 0.5 * scales[pages].T[:, :, None] + 1e-6  # (Hkv, len, 1)
    assert np.all(np.abs(np.array(k_paged, np.float32) - k_dense) <= bound)


def test_engine_cache_dense_view_matches_layout(small_model):
    """The pool contents read back through LayoutPaged offsets equal the dense
    prefill cache — the scatter writes implement exactly the layout's map."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=10).tolist()
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=16, page_size=4, max_batch=2, max_pages_per_seq=8),
    )
    eng.submit(Request(rid=0, prompt=prompt, params=GenerationParams(max_new_tokens=1)))
    eng._t0 = 0.0
    eng.queue.push(eng._pending.pop())
    eng._admit_and_prefill(0.0)
    layout = eng.cache.layout_for(0)
    assert layout.is_unique() and not layout.is_contiguous() and not layout.is_strided()
    k_paged, _ = eng.cache.dense_view(0)
    _, caches = model.prefill(params, jnp.asarray([prompt], jnp.int32), max_len=12)
    k_dense = caches[0]["k"][0, 0, :, : len(prompt)]  # layer 0: (Hkv, len, Dh)
    np.testing.assert_allclose(
        np.array(k_paged, np.float32), np.array(k_dense, np.float32), rtol=1e-6, atol=1e-6
    )


# =====================================================================================
# chunked prefill (mixed steps) — token-exact vs the monolithic engine
# =====================================================================================
def _staggered_shared_requests(cfg, rng):
    """Donor (long decode keeps it resident) + filler (frees its slot) +
    followers (admitted MID-donor, adopt its published prefix pages and skip
    their compute) — deterministic, no wall-clock staging."""
    prefix = rng.integers(0, cfg.vocab, size=16).tolist()
    return [
        (prefix + rng.integers(0, cfg.vocab, size=4).tolist(), 11),
        (rng.integers(0, cfg.vocab, size=5).tolist(), 2),
        (prefix + rng.integers(0, cfg.vocab, size=3).tolist(), 5),
        (list(prefix), 5),  # whole-prompt adoption incl. the partial page
    ]


def _run_pair(model, params, econf, reqs_spec):
    mk = lambda: [
        Request(rid=i, prompt=list(p), params=GenerationParams(max_new_tokens=n))
        for i, (p, n) in enumerate(reqs_spec)
    ]
    eng_m = ServeEngine(model, params, econf)
    eng_c = ServeEngine(
        model, params,
        dataclasses.replace(econf, chunked_prefill=True, chunk_tokens=8),
    )
    return eng_m.run(mk()), eng_c.run(mk()), eng_m, eng_c


@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
def test_engine_chunked_exact_with_compute_skip(small_model, kv_dtype):
    """Chunked-on vs chunked-off token-exact on a shared-prefix workload where
    the followers' first chunk starts PAST the adopted pages (compute skip),
    across multi-chunk prompts. int4 is exercised separately: its cross-chunk
    reads go through 4-bit pages where monolithic prefill attends f32, so
    multi-chunk exactness is not a structural guarantee at that width."""
    cfg, model, params = small_model
    reqs_spec = _staggered_shared_requests(cfg, np.random.default_rng(3))
    econf = EngineConfig(num_pages=48, page_size=4, max_batch=2,
                         max_pages_per_seq=9, kv_dtype=kv_dtype)
    res_m, res_c, eng_m, eng_c = _run_pair(model, params, econf, reqs_spec)
    for i in range(len(reqs_spec)):
        assert res_m[i].generated == res_c[i].generated, i
    m = eng_c.metrics()
    assert m["prefill_tokens_skipped"] > 0  # followers skipped the prefix
    assert m["pages_shared"] > 0
    assert eng_m.metrics()["prefill_tokens_skipped"] == 0  # monolithic never skips


def test_engine_chunked_skip_matches_cold_request(small_model):
    """A skipped-prefix request produces the same tokens as a cold request of
    the same prompt (sharing off): the adopted pages hold exactly what its own
    prefill would have computed."""
    cfg, model, params = small_model
    reqs_spec = _staggered_shared_requests(cfg, np.random.default_rng(3))
    econf = EngineConfig(num_pages=48, page_size=4, max_batch=2, max_pages_per_seq=9)
    mk = lambda: [
        Request(rid=i, prompt=list(p), params=GenerationParams(max_new_tokens=n))
        for i, (p, n) in enumerate(reqs_spec)
    ]
    warm = ServeEngine(
        model, params, dataclasses.replace(econf, chunked_prefill=True, chunk_tokens=8)
    )
    cold = ServeEngine(
        model, params,
        dataclasses.replace(econf, chunked_prefill=True, chunk_tokens=8,
                            prefix_sharing=False),
    )
    res_w, res_c = warm.run(mk()), cold.run(mk())
    assert warm.metrics()["prefill_tokens_skipped"] > 0
    assert cold.metrics()["prefill_tokens_skipped"] == 0
    for i in range(len(reqs_spec)):
        assert res_w[i].generated == res_c[i].generated, i


def test_engine_chunked_int4_exact_single_chunk_sharing_and_cow(small_model):
    """int4 pages stay token-exact wherever attention never crosses a chunk
    boundary: single-page prompts with partial-page adoption + forced CoW —
    the whole sharing machinery over 4-bit pages, chunked vs monolithic."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=6).tolist()
    filler = rng.integers(0, cfg.vocab, size=5).tolist()
    reqs_spec = [(prompt, 12), (filler, 2), (prompt, 5), (prompt, 5)]
    econf = EngineConfig(num_pages=24, page_size=8, max_batch=2,
                         max_pages_per_seq=4, kv_dtype="int4")
    res_m, res_c, eng_m, eng_c = _run_pair(model, params, econf, reqs_spec)
    for i in range(len(reqs_spec)):
        assert res_m[i].generated == res_c[i].generated, i
    m = eng_c.metrics()
    assert m["pages_shared"] >= 1 and m["cow_copies"] >= 1


def test_engine_chunked_preemption_mid_prefill_stays_exact(small_model):
    """A decoding sequence's page append exhausts the pool while a long prompt
    is mid-prefill: the PREFILLING slot is preempted (cursor reset, deferred
    index entries discarded), re-admitted, and the final tokens still match the
    monolithic engine."""
    cfg, model, params = small_model
    rng = np.random.default_rng(7)
    long_p = rng.integers(0, cfg.vocab, size=44).tolist()
    short_p = rng.integers(0, cfg.vocab, size=4).tolist()
    reqs_spec = [(long_p, 4), (short_p, 10)]
    econf = EngineConfig(num_pages=16, page_size=4, max_batch=2, max_pages_per_seq=12)
    mk = lambda: [
        Request(rid=i, prompt=list(p), params=GenerationParams(max_new_tokens=n))
        for i, (p, n) in enumerate(reqs_spec)
    ]
    eng_m = ServeEngine(model, params, econf)
    eng_c = ServeEngine(
        model, params,
        dataclasses.replace(econf, chunked_prefill=True, chunk_tokens=4),
    )
    victim_phases = []
    orig = eng_c.scheduler._preempt_one

    def spying_preempt(queue, keep_slot):
        victims = [s for s in eng_c.scheduler.running if s != keep_slot]
        if victims:
            victim_phases.append(eng_c.scheduler.running[victims[-1]].phase)
        return orig(queue, keep_slot)

    eng_c.scheduler._preempt_one = spying_preempt
    res_m, res_c = eng_m.run(mk()), eng_c.run(mk())
    assert PREFILLING in victim_phases  # the long prompt was evicted mid-prefill
    assert eng_c.metrics()["preemptions"] >= 1
    for i in range(len(reqs_spec)):
        assert res_m[i].generated == res_c[i].generated, i


def test_engine_chunked_mixed_lengths_exact_and_single_compile_family(small_model):
    """Mixed prompt lengths through the chunked engine match the unbatched
    oracle, and the engine compiles NO per-prompt-length prefill functions —
    the traced-cursor chunk step is the only prefill compile family."""
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    lengths = (5, 9, 16, 3, 12)
    prompts = [rng.integers(0, cfg.vocab, size=L).tolist() for L in lengths]
    n_gen = 6
    reqs = [Request(
            rid=i,
            prompt=p,
            params=GenerationParams(max_new_tokens=n_gen),
        ) for i, p in enumerate(prompts)]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=32, page_size=4, max_batch=4, max_pages_per_seq=8,
                     chunked_prefill=True, chunk_tokens=8),
    )
    results = eng.run(reqs)
    for i, p in enumerate(prompts):
        assert results[i].generated == unbatched_greedy(cfg, model, params, p, n_gen)
    assert not eng._prefill_fns  # monolithic path never compiled


# =====================================================================================
# impossible requests fail loudly instead of wedging the queue
# =====================================================================================
def test_submit_rejects_prompt_larger_than_pool(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=4, page_size=4, max_batch=2, max_pages_per_seq=16),
    )
    with pytest.raises(ValueError, match="usable pages"):
        eng.submit(Request(
                rid=0,
                prompt=list(range(1, 40)),
                params=GenerationParams(max_new_tokens=2),
            ))


def test_grown_context_fails_request_and_serves_the_rest(small_model):
    """A request whose context GROWS past the whole pool (legal at submit
    time) is failed with .error set — the engine keeps serving everything
    else instead of spinning on an unadmittable queue head."""
    cfg, model, params = small_model
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=6, page_size=4, max_batch=2, max_pages_per_seq=8),
    )
    ok = Request(rid=0, prompt=[5, 6, 7], params=GenerationParams(max_new_tokens=3))
    # 18-token prompt fits 5 of 5 usable pages at submit; +8 new tokens can
    # never fit — the scheduler must fail it at (re-)admission, not spin
    doomed = Request(
            rid=1,
            prompt=list(range(1, 19)),
            params=GenerationParams(max_new_tokens=8),
        )
    eng.submit_all([ok, doomed])
    # simulate the grown-context state preemption would produce
    eng._pending[1].generated.extend([9, 9, 9])
    results = eng.run()
    assert results[0].error is None and len(results[0].generated) == 3
    assert results[1].error is not None and "pool" in results[1].error
    assert eng.metrics()["failed"] == 1
