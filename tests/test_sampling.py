"""On-device sampling + the device-resident decode hot path.

Pins the serving sampling contract (serving/sampling.py): greedy on device is
bit-identical to host argmax, seeded sampling is a pure function of
(seed, rid, position) — reproducible across runs and invariant under
preemption-recompute — and the multi-step fused decode loop (K > 1) is
token-exact against the single-step engine. Plus the device-mirror law: the
persistent device tables/lens stay consistent with the host allocator state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import (
    EngineConfig, Request, SamplingParams, ServeEngine,
)
from repro.serving.sampling import stream_seed


# =====================================================================================
# ops.sample_tokens — the device-side selection op
# =====================================================================================
def _logits(rng, b=3, vp=40):
    return jnp.asarray(rng.standard_normal((b, vp)), jnp.float32)


def _call(x, vocab=32, temperature=0.0, top_k=0, top_p=1.0, seed=0, pos=0):
    b = x.shape[0]
    full = lambda v, dt: jnp.full((b,), v, dt)
    return np.asarray(ops.sample_tokens(
        x, full(temperature, jnp.float32), full(top_k, jnp.int32),
        full(top_p, jnp.float32), full(seed, jnp.uint32), full(pos, jnp.int32),
        vocab=vocab,
    ))


def test_sample_tokens_greedy_matches_host_argmax():
    x = _logits(np.random.default_rng(0))
    got = _call(x)
    want = np.argmax(np.asarray(x)[:, :32], axis=-1)
    np.testing.assert_array_equal(got, want)


def test_sample_tokens_greedy_ignores_vocab_pad():
    x = np.full((2, 8), -5.0, np.float32)
    x[:, 6:] = 100.0  # pad columns must never be selected
    got = _call(jnp.asarray(x), vocab=6)
    assert (got < 6).all()


def test_sample_tokens_top_k_restricts_support():
    rng = np.random.default_rng(1)
    x = _logits(rng)
    top3 = np.argsort(np.asarray(x)[:, :32], axis=-1)[:, -3:]
    for pos in range(40):  # many draws at distinct positions
        got = _call(x, temperature=1.5, top_k=3, seed=9, pos=pos)
        for b in range(x.shape[0]):
            assert got[b] in top3[b]


def test_sample_tokens_tiny_top_p_is_argmax():
    x = _logits(np.random.default_rng(2))
    got = _call(x, temperature=1.0, top_p=1e-6, seed=3, pos=5)
    want = np.argmax(np.asarray(x)[:, :32], axis=-1)
    np.testing.assert_array_equal(got, want)  # head-of-mass keeps only top-1


def test_sample_tokens_deterministic_in_seed_and_pos():
    x = _logits(np.random.default_rng(3))
    a = _call(x, temperature=1.0, seed=11, pos=7)
    b = _call(x, temperature=1.0, seed=11, pos=7)
    np.testing.assert_array_equal(a, b)
    # ... and actually random across positions / seeds
    draws = {tuple(_call(x, temperature=1.0, seed=11, pos=p)) for p in range(16)}
    assert len(draws) > 1


def test_sample_tokens_mixed_greedy_and_sampled_slots():
    x = _logits(np.random.default_rng(4))
    t = jnp.asarray([0.0, 1.0, 0.0], jnp.float32)
    z = lambda v, dt: jnp.full((3,), v, dt)
    got = np.asarray(ops.sample_tokens(
        x, t, z(0, jnp.int32), z(1.0, jnp.float32), z(5, jnp.uint32),
        z(3, jnp.int32), vocab=32,
    ))
    want = np.argmax(np.asarray(x)[:, :32], axis=-1)
    assert got[0] == want[0] and got[2] == want[2]  # greedy slots exact


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    assert SamplingParams().is_greedy
    assert stream_seed(0, 1) != stream_seed(0, 2)
    assert stream_seed(3, 7) == stream_seed(3, 7)


# =====================================================================================
# engine — on-device selection vs host oracles, fused multi-step, mirrors
# =====================================================================================
@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _mk(prompts, n, **kw):
    return [
        Request(rid=i, prompt=list(p), params=GenerationParams.from_legacy(
            max_new_tokens=n, **kw))
        for i, p in enumerate(prompts)
    ]


@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
def test_engine_greedy_on_device_matches_host_argmax(small_model, kv_dtype):
    """Every generated token equals host np.argmax over the logits row the
    recording slow path captured for that step — the on-device greedy path is
    bit-identical to the host oracle, over f32 AND quantized pools."""
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=L).tolist() for L in (5, 9, 12)]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=32, page_size=4, max_batch=3, max_pages_per_seq=8,
                     record_logits=True, kv_dtype=kv_dtype),
    )
    results = eng.run(_mk(prompts, 6))
    for rid, state in results.items():
        rows = eng.logits_of[rid]
        assert len(rows) == len(state.generated) == 6
        for n, tok in enumerate(state.generated):
            assert tok == int(np.argmax(rows[n])), (rid, n)


def test_engine_sampled_reproducible_across_runs(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.95, seed=123)
    econf = EngineConfig(num_pages=32, page_size=4, max_batch=3, max_pages_per_seq=8)
    res_a = ServeEngine(model, params, econf).run(_mk(prompts, 6, sampling=sp))
    res_b = ServeEngine(model, params, econf).run(_mk(prompts, 6, sampling=sp))
    for i in range(len(prompts)):
        assert res_a[i].generated == res_b[i].generated, i
    # a different seed actually changes something
    sp2 = dataclasses.replace(sp, seed=124)
    res_c = ServeEngine(model, params, econf).run(_mk(prompts, 6, sampling=sp2))
    assert any(res_c[i].generated != res_a[i].generated for i in res_c)


def test_engine_sampled_invariant_under_preemption_recompute(small_model):
    """Sampling folds (seed, rid, absolute position) — never steps or slots —
    so a preempted-and-recomputed request re-samples its identical
    continuation: a page-starved engine matches an uncontended one."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    sp = SamplingParams(temperature=0.9, top_k=10, top_p=0.9, seed=7)
    big = ServeEngine(
        model, params,
        EngineConfig(num_pages=64, page_size=4, max_batch=3, max_pages_per_seq=8),
    )
    starved = ServeEngine(
        model, params,
        EngineConfig(num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6),
    )
    res_big = big.run(_mk(prompts, 10, sampling=sp))
    res_starved = starved.run(_mk(prompts, 10, sampling=sp))
    assert starved.metrics()["preemptions"] >= 1
    for i in range(len(prompts)):
        assert res_big[i].generated == res_starved[i].generated, i


@pytest.mark.parametrize("sampling", [None, SamplingParams(temperature=0.7, top_k=20, seed=5)])
def test_engine_multi_step_fused_token_exact(small_model, sampling):
    """K=4 fused windows produce the same tokens as K=1, greedy and sampled;
    the fused loop must actually fire."""
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    kw = {} if sampling is None else {"sampling": sampling}
    econf = EngineConfig(num_pages=48, page_size=16, max_batch=3, max_pages_per_seq=4)
    eng1 = ServeEngine(model, params, econf)
    eng4 = ServeEngine(model, params, dataclasses.replace(econf, multi_step=4))
    res1 = eng1.run(_mk(prompts, 24, **kw))
    res4 = eng4.run(_mk(prompts, 24, **kw))
    assert eng4.metrics()["fused_steps"] > 0
    assert eng1.metrics()["fused_steps"] == 0
    for i in range(len(prompts)):
        assert res1[i].generated == res4[i].generated, i


def test_engine_multi_step_eos_mid_window_truncates_exact(small_model):
    """An EOS landing inside a fused window finishes the request at the EOS
    token; the window's overrun iterations are discarded and outputs match the
    single-step engine exactly."""
    cfg, model, params = small_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(2)]
    econf = EngineConfig(num_pages=32, page_size=16, max_batch=2, max_pages_per_seq=4)
    probe = ServeEngine(model, params, econf).run(_mk(prompts, 12))
    # an eos the greedy trajectory is known to hit mid-sequence (and mid-window)
    eos = probe[0].generated[5]
    mk = lambda: _mk(prompts, 12, eos_id=eos)
    res1 = ServeEngine(model, params, econf).run(mk())
    eng4 = ServeEngine(model, params, dataclasses.replace(econf, multi_step=4))
    res4 = eng4.run(mk())
    assert res1[0].generated[-1] == eos and len(res1[0].generated) <= 12
    for i in res1:
        assert res1[i].generated == res4[i].generated, i


def test_engine_device_mirrors_match_host_state(small_model):
    """The persistent device tables/lens mirrors (patched by allocator-event
    deltas, advanced on device by the fused step) agree with the host
    allocator arrays whenever the engine is quiescent."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6,
                     multi_step=2),
    )
    eng.run(_mk(prompts, 10))  # page pressure: appends, CoW-free preemptions
    tables_dev, lens_dev = eng.cache.device_state()
    np.testing.assert_array_equal(np.asarray(tables_dev), eng.cache.tables)
    np.testing.assert_array_equal(np.asarray(lens_dev), eng.cache.lens)


def test_engine_record_logits_disables_fusion(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist()]
    eng = ServeEngine(
        model, params,
        EngineConfig(num_pages=16, page_size=16, max_batch=1, max_pages_per_seq=4,
                     multi_step=4, record_logits=True),
    )
    res = eng.run(_mk(prompts, 8))
    assert eng.metrics()["fused_steps"] == 0  # slow path: per-step rows on host
    assert len(eng.logits_of[0]) == len(res[0].generated) == 8
