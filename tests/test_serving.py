"""Serving correctness: prefill/decode == full forward; quantized serving path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.models import ARCH_IDS, build_model, get_config
from tests.test_models_smoke import make_batch

EXACT = {a for a in ARCH_IDS if a not in ("kimi-k2-1t-a32b", "recurrentgemma-2b", "mamba2-780m")}
# kimi: capacity-based MoE token dropping differs between prefill (T=B*S) and
# decode (T=B) — expected; rg/mamba: bf16 accumulation-order noise in scans
# (f32 exactness is asserted separately below).


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    ctx = model.encode_ctx(params, batch)
    logits_full, _ = model.forward(params, batch["tokens"], ctx=ctx, remat=False)
    logits_pre, caches = model.prefill(params, batch["tokens"][:, :S], ctx=ctx, max_len=S + 4)
    logits_dec, _ = model.decode_step(params, caches, batch["tokens"][:, S], S)
    tol = 3e-2 if arch in EXACT else 2e-1
    np.testing.assert_allclose(
        np.array(logits_dec, np.float32), np.array(logits_full[:, -1], np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-780m"])
def test_scan_archs_exact_in_f32(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits_full, _ = model.forward(params, batch["tokens"], remat=False)
    _, caches = model.prefill(params, batch["tokens"][:, :S], max_len=S + 4)
    logits_dec, _ = model.decode_step(params, caches, batch["tokens"][:, S], S)
    np.testing.assert_allclose(
        np.array(logits_dec), np.array(logits_full[:, -1]), rtol=1e-4, atol=1e-4
    )


def test_multi_token_greedy_decode_consistency():
    """Decode 4 tokens autoregressively == forward over the same sequence (f32)."""
    cfg = dataclasses.replace(get_config("llama3.2-1b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S, G = 2, 12, 4
    tokens = jax.random.randint(jax.random.key(1), (B, S + G), 0, cfg.vocab)
    _, caches = model.prefill(params, tokens[:, :S], max_len=S + G)
    outs = []
    for g in range(G):
        logits, caches = model.decode_step(params, caches, tokens[:, S + g], S + g)
        outs.append(logits)
    logits_full, _ = model.forward(params, tokens, remat=False)
    for g in range(G - 1):
        np.testing.assert_allclose(
            np.array(outs[g]), np.array(logits_full[:, S + g]), rtol=1e-4, atol=1e-4
        )


def test_windowed_ring_cache_equals_full_attention_within_window():
    """rg local attention: ring-buffer decode == full causal within the window."""
    cfg = dataclasses.replace(get_config("recurrentgemma-2b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 1, 24  # > window (8): ring wraps during prefill
    tokens = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    logits_full, _ = model.forward(params, tokens, remat=False)
    _, caches = model.prefill(params, tokens[:, :S], max_len=S + 1)
    logits_dec, _ = model.decode_step(params, caches, tokens[:, S], S)
    np.testing.assert_allclose(
        np.array(logits_dec), np.array(logits_full[:, -1]), rtol=1e-4, atol=1e-4
    )


def test_quantized_serving_path():
    """int8-weight model (QuantizedAccessor specs) serves and stays close to the
    bf16 model's logits — the paper's accessor concept end-to-end."""
    cfg = get_config("llama3.2-1b", smoke=True)
    quant = build_model(cfg, quantized=True)
    # quantized model has {"q","scale"} leaves for big matmuls
    qs = quant.param_specs()
    from repro.core.distributed import is_spec
    import jax.tree_util as jtu

    n_quant = sum(
        1 for s in jtu.tree_leaves(qs, is_leaf=is_spec) if getattr(s, "accessor", None) is not None and s.is_quantized()
    )
    assert n_quant > 0
    qparams = quant.init_params(jax.random.key(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits, _ = quant.forward(qparams, tokens, remat=False)
    assert np.isfinite(np.array(logits, np.float32)).all()
    _, caches = quant.prefill(qparams, tokens, max_len=S + 2)
    dec, _ = quant.decode_step(qparams, caches, tokens[:, -1], S)
    assert np.isfinite(np.array(dec, np.float32)).all()
