"""TensorSpec / ShardingRules / quantize-dequantize / sharding fallbacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import (
    ShardingRules,
    TensorSpec,
    dequantize_array,
    quantize_array,
    tree_initialize,
    tree_param_bytes,
    tree_param_count,
)


@pytest.fixture(scope="module")
def mesh():
    devs = jax.devices()
    return jax.sharding.Mesh(np.array(devs).reshape(len(devs), 1), ("data", "model"))


def test_divisibility_fallback_replicates(mesh):
    rules = ShardingRules({"kv_heads": "model", "ffn": "model"})
    # model axis size 1 -> divisible; simulate bigger axis via a fake mesh dict
    b = rules.binding_for(("kv_heads", None), (8, 64), mesh)
    assert b[0] in ("model", None)
    # axis reuse within one tensor is dropped
    rules2 = ShardingRules({"a": "data", "b": "data"})
    b2 = rules2.binding_for(("a", "b"), (4, 4), mesh)
    assert b2[1] is None  # second use of "data" dropped


def test_unknown_axis_replicated(mesh):
    rules = ShardingRules({})
    ps = rules.pspec(("whatever", None), (4, 4), mesh)
    assert ps == jax.sharding.PartitionSpec(None, None)


def test_quantize_dequantize_nd():
    qa = QuantizedAccessor(jnp.bfloat16, bits=8, block=32)
    x = jax.random.normal(jax.random.key(0), (3, 4, 64))
    bufs = quantize_array(x, qa)
    assert bufs["q"].shape == (3, 4, 64) and bufs["scale"].shape == (3, 4, 2)
    err = np.abs(np.array(dequantize_array(bufs, qa), np.float32) - np.array(x))
    step = np.abs(np.array(x)).reshape(3, 4, 2, 32).max(-1) / 127
    assert (err <= np.repeat(step, 32, axis=-1).reshape(err.shape) * 0.5 + 0.01).all()


def test_tensor_spec_struct_and_init(mesh):
    rules = ShardingRules({"embed": None, "vocab": None})
    spec = TensorSpec((16, 32), ("vocab", "embed"), dtype=jnp.bfloat16, init="embed")
    st = spec.shape_struct(mesh, rules)
    assert st.shape == (16, 32) and st.dtype == jnp.bfloat16
    arr = spec.initialize(jax.random.key(0))
    assert arr.shape == (16, 32) and np.isfinite(np.array(arr, np.float32)).all()


def test_quantized_spec_struct_tree(mesh):
    qa = QuantizedAccessor(jnp.bfloat16, bits=8, block=16)
    spec = TensorSpec((8, 64), (None, None), accessor=qa)
    tree = spec.shape_struct(mesh, ShardingRules({}))
    assert tree["q"].shape == (8, 64) and tree["q"].dtype == jnp.int8
    assert tree["scale"].shape == (8, 4)
    bufs = spec.initialize(jax.random.key(0))
    assert bufs["q"].dtype == jnp.int8


def test_param_accounting():
    specs = {
        "w": TensorSpec((8, 64), (None, None), dtype=jnp.bfloat16),
        "q": TensorSpec((8, 64), (None, None), accessor=QuantizedAccessor(jnp.bfloat16, bits=8, block=16)),
    }
    assert tree_param_count(specs) == 2 * 8 * 64
    # bf16 w: 1024B; quantized: 512 q bytes + 32 scales * 4B
    assert tree_param_bytes(specs) == 8 * 64 * 2 + 8 * 64 + 8 * 4 * 4


def test_tree_initialize_distinct_keys():
    specs = {
        "a": TensorSpec((4, 4), (None, None), dtype=jnp.float32, init="normal"),
        "b": TensorSpec((4, 4), (None, None), dtype=jnp.float32, init="normal"),
    }
    t = tree_initialize(specs, jax.random.key(0))
    assert not np.array_equal(np.array(t["a"]), np.array(t["b"]))
