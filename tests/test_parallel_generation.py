"""Parallel generation as layout forks: n-best sampling, beam search,
constrained decoding, and the GenerationParams API.

Three layers, matching where each law lives:
  - core/layouts.py: fork_group / permute_rows are pure layout algebra
    (property-based where hypothesis is installed, example-based everywhere);
  - engine/cache.py: fork_slot / reorder_rows are the allocator's physical
    counterparts — refcount conservation, zero-copy reorders, device-mirror
    agreement (FakeModel pools, no transformer);
  - engine/engine.py: the end-to-end laws — branch b of an n-branch request is
    token-exact with a serial request at seed+b, forked branches share prompt
    pages, one branch's EOS never stalls its siblings, beam search is
    deterministic and ranked, every grammar-constrained output parses.
"""
import dataclasses
import json
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.extents import Extents
from repro.core.layouts import LayoutPaged
from repro.models import build_model, get_config
from repro.serving import (
    JSON_ARRAY_CHARS,
    GenerationParams,
    RequestHandle,
    TokenDFA,
    fixed_json_array_dfa,
    json_array_dfa,
)
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.engine.cache import PagedKVCache
from repro.serving.sampling import SamplingParams


# =====================================================================================
# layout algebra — fork_group / permute_rows (core/layouts.py)
# =====================================================================================
def paged(rows, page_size=4, num_pages=32, shared=()):
    n_seq = len(rows)
    return LayoutPaged(
        Extents.fully_dynamic(n_seq, 2, max(len(r) for r in rows) * page_size, 3),
        tuple(tuple(r) for r in rows),
        page_size,
        num_pages,
        tuple(shared),
    )


def test_fork_group_shares_leading_pages_and_flips_uniqueness():
    lay = paged([[1, 2, 3]])
    assert lay.is_unique()
    forked = lay.fork_group(0, 3, fresh_pages=[(4,), (5,), (6,)])
    assert forked.extents.sizes[0] == 4
    for b, tail in enumerate([4, 5, 6]):
        row = forked.block_table[1 + b]
        assert row[:2] == (1, 2)  # leading pages aliased, not copied
        assert row[2] == tail
    assert not forked.is_unique()  # internal aliasing until CoW resolves it


def test_fork_group_equals_successive_forks():
    lay = paged([[1, 2]])
    grouped = lay.fork_group(0, 2, fresh_pages=[(7,), (8,)])
    serial = lay.fork(0, (7,)).fork(0, (8,))
    assert grouped.block_table == serial.block_table


def test_fork_group_validates():
    lay = paged([[1, 2]])
    with pytest.raises(ValueError, match="n >= 1"):
        lay.fork_group(0, 0)
    with pytest.raises(ValueError, match="fresh-page tails"):
        lay.fork_group(0, 2, fresh_pages=[(3,)])


def test_permute_rows_identity_and_roundtrip():
    lay = paged([[1, 2], [3, 4], [5, 6]])
    assert lay.permute_rows([0, 1, 2]).block_table == lay.block_table
    perm = [2, 0, 1]
    inv = [perm.index(i) for i in range(3)]
    assert lay.permute_rows(perm).permute_rows(inv).block_table == lay.block_table


def test_permute_rows_preserves_offset_image():
    lay = paged([[1, 2], [3, 4]])
    before = sorted(np.asarray(lay.offsets_dense()).reshape(-1).tolist())
    after = sorted(
        np.asarray(lay.permute_rows([1, 0]).offsets_dense()).reshape(-1).tolist()
    )
    assert before == after  # no page copied, no entry rewritten


def test_permute_rows_rejects_non_permutations():
    lay = paged([[1], [2]])
    with pytest.raises(ValueError, match="not a permutation"):
        lay.permute_rows([0, 0])
    with pytest.raises(ValueError, match="not a permutation"):
        lay.permute_rows([0])


# hypothesis leg: the same laws over random tables (skipped without hypothesis,
# mirroring test_layouts.py)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @st.composite
    def paged_layouts(draw):
        n_seq = draw(st.integers(1, 4))
        n_pages = draw(st.integers(1, 4))
        rows = [
            draw(
                st.lists(
                    st.integers(1, 31), min_size=n_pages, max_size=n_pages
                )
            )
            for _ in range(n_seq)
        ]
        return paged(rows)

    @settings(max_examples=50, deadline=None)
    @given(paged_layouts(), st.randoms(use_true_random=False))
    def test_permute_rows_is_a_group_action(lay, rnd):
        n = len(lay.block_table)
        perm = list(range(n))
        rnd.shuffle(perm)
        permuted = lay.permute_rows(perm)
        # row i of the result is row perm[i] of the source
        for i in range(n):
            assert permuted.block_table[i] == lay.block_table[perm[i]]
        inv = [perm.index(i) for i in range(n)]
        assert permuted.permute_rows(inv).block_table == lay.block_table

    @settings(max_examples=50, deadline=None)
    @given(paged_layouts(), st.integers(1, 3), st.integers(0, 100))
    def test_fork_group_only_appends_aliased_rows(lay, n, seed):
        rnd = np.random.default_rng(seed)
        src = int(rnd.integers(0, len(lay.block_table)))
        width = len(lay.block_table[src])
        tails = [
            tuple(int(p) for p in rnd.integers(1, 31, size=min(1, width)))
            for _ in range(n)
        ]
        out = lay.fork_group(src, n, fresh_pages=tails)
        assert out.block_table[: len(lay.block_table)] == lay.block_table
        for b in range(n):
            row = out.block_table[len(lay.block_table) + b]
            upto = width - len(tails[b])
            assert row[:upto] == lay.block_table[src][:upto]
            assert row[upto:] == tails[b]

except ImportError:  # pragma: no cover - hypothesis not installed
    pass


# =====================================================================================
# allocator — fork_slot / reorder_rows (engine/cache.py, FakeModel pools)
# =====================================================================================
@dataclasses.dataclass
class FakeCfg:
    n_kv_heads: int = 2
    head_dim: int = 4


class FakeModel:
    cfg = FakeCfg()

    def init_paged_cache(self, num_pages, page_size):
        shape = (1, num_pages, self.cfg.n_kv_heads, page_size, self.cfg.head_dim)
        return [{"k": jnp.zeros(shape), "v": jnp.zeros(shape)}]


def make_cache(num_pages=16, page_size=4, max_pages_per_seq=8, max_batch=4):
    return PagedKVCache(
        FakeModel(), num_pages=num_pages, page_size=page_size,
        max_batch=max_batch, max_pages_per_seq=max_pages_per_seq,
    )


def ref_invariant(cache):
    """Every page's refcount equals the number of block-table rows holding it
    (plus prefix-index pins counted by the allocator the same way)."""
    counts = {}
    for pages in cache.pages_of.values():
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
    for p, n in counts.items():
        assert cache.ref[p] == n, (p, cache.ref[p], n)


def test_fork_slot_aliases_and_conserves_refcounts():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(7)))  # 7 tokens: page 2 is partial
    free_before = c.num_free
    pages = c.fork_slot(0, 1, 7)
    assert pages[:2] == c.pages_of[0][:2]  # both pages aliased
    assert int(c.lens[1]) == 7
    assert all(c.ref[p] == 2 for p in c.pages_of[0])
    assert c.num_free == free_before  # pages_for(8) == 2: no fresh page needed
    ref_invariant(c)
    assert c.stats()["branch_forks"] == 1


def test_fork_slot_adds_headroom_page_on_aligned_prompts():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(8)))  # page-aligned: +1 headroom page
    free_before = c.num_free
    pages = c.fork_slot(0, 1, 8)
    assert len(pages) == 3 and pages[:2] == c.pages_of[0][:2]
    assert c.ref[pages[2]] == 1  # the private decode tail
    assert c.num_free == free_before - 1
    ref_invariant(c)


def test_fork_slot_sibling_free_leaves_primary_intact():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(7)))
    c.fork_slot(0, 1, 7)
    c.free_slot(1)
    assert all(c.ref[p] == 1 for p in c.pages_of[0])
    assert c.pages_of[0] == [int(x) for x in c.tables[0][: len(c.pages_of[0])]]
    ref_invariant(c)


def test_fork_slot_exhaustion_raises():
    c = make_cache(num_pages=4)  # 3 usable
    c.allocate(0, 3, tokens=list(range(12)))
    with pytest.raises(RuntimeError, match="pool exhausted"):
        c.fork_slot(0, 1, 12)  # aligned fork needs a headroom page; none free


def test_reorder_rows_is_zero_copy_and_conserves_refcounts():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(8)))
    c.fork_slot(0, 1, 8)
    c.fork_slot(0, 2, 8)
    copies_before = c.cow_copies
    free_before = c.num_free
    # beam step: slot 1 and 2 both rebind to slot 0's hypothesis, 0 keeps its own
    c.reorder_rows({1: 0, 2: 0})
    assert c.cow_copies == copies_before  # table surgery only
    # each child's private headroom tail is released (no other holder), but the
    # shared pages never transit refcount zero — only frees, never copies
    assert c.num_free == free_before + 2
    assert c.pages_of[1][:2] == c.pages_of[0][:2] == c.pages_of[2][:2]
    assert int(c.lens[1]) == int(c.lens[0])
    ref_invariant(c)
    assert c.stats()["beam_reorders"] == 1


def test_reorder_rows_swap_never_transits_refcount_zero():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(8)))
    c.allocate(1, 2, tokens=list(range(100, 108)))
    a, b = list(c.pages_of[0]), list(c.pages_of[1])
    free_before = c.num_free
    c.reorder_rows({0: 1, 1: 0})  # full swap: every page released AND re-held
    assert c.pages_of[0] == b and c.pages_of[1] == a
    assert c.num_free == free_before  # no page ever hit the free list
    ref_invariant(c)


def test_reorder_rows_identity_is_free():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(8)))
    c.fork_slot(0, 1, 8)
    n = c.stats()["beam_reorders"]
    c.reorder_rows({0: 0, 1: 1})
    assert c.stats()["beam_reorders"] == n  # skipped entirely, no dirty rows


def test_reorder_rows_device_mirror_matches_host():
    c = make_cache()
    c.allocate(0, 2, tokens=list(range(8)))
    c.fork_slot(0, 1, 8)
    c.fork_slot(0, 2, 8)
    c.reorder_rows({1: 2, 2: 1})
    tables_dev, lens_dev = c.device_state()
    np.testing.assert_array_equal(np.asarray(tables_dev), c.tables)
    np.testing.assert_array_equal(np.asarray(lens_dev), c.lens)


# =====================================================================================
# GenerationParams — validation at construction, legacy shims
# =====================================================================================
def test_params_validation():
    with pytest.raises(ValueError, match="beam_width=1"):
        GenerationParams(beam_width=1)
    with pytest.raises(ValueError, match="deterministic"):
        GenerationParams(beam_width=2, temperature=0.7)
    with pytest.raises(ValueError, match="n must be <= beam_width"):
        GenerationParams(beam_width=2, n=3)
    with pytest.raises(ValueError, match="identical greedy"):
        GenerationParams(n=2)  # n>1 needs temperature > 0
    with pytest.raises(ValueError, match="not supported"):
        GenerationParams(
            beam_width=2, grammar=TokenDFA(4, [{0: 0}])
        )
    with pytest.raises(ValueError, match="cumulative_logprob"):
        GenerationParams(beam_width=2, logprobs=3)
    assert GenerationParams(n=4, temperature=0.5).n_branches == 4
    assert GenerationParams(beam_width=4, n=2).n_branches == 4


def test_request_legacy_kwargs_warn_and_delegate():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = Request(
            1, [1, 2], max_new_tokens=7, eos_id=3,
            sampling=SamplingParams(temperature=0.5, seed=9), logprobs=0,
        )
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert r.params.max_new_tokens == 7 and r.params.eos_id == 3
    assert r.max_new_tokens == 7 and r.eos_id == 3  # delegating properties
    assert r.sampling == SamplingParams(temperature=0.5, seed=9)


def test_request_rejects_mixing_params_and_legacy_kwargs():
    with pytest.raises(ValueError, match="either"):
        Request(1, [1, 2], GenerationParams(max_new_tokens=4), max_new_tokens=8)


# =====================================================================================
# engine — end-to-end laws (real model)
# =====================================================================================
@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def fresh_engine(model, params, **kw):
    base = dict(num_pages=64, page_size=4, max_batch=8, max_pages_per_seq=8)
    base.update(kw)
    return ServeEngine(model, params, EngineConfig(**base))


def serial_tokens(model, params, prompt, seed, rid, n_gen=6, **cfg_kw):
    eng = fresh_engine(model, params, **cfg_kw)
    h = eng.submit(
        prompt,
        GenerationParams(
            max_new_tokens=n_gen, temperature=0.8, top_k=8, seed=seed
        ),
        rid=rid,
    )
    eng.run()
    return h.sequences[0].tokens


@pytest.mark.parametrize("prompt_len", [7, 8])  # partial AND aligned last page
def test_best_of_n_token_exact_vs_serial(small_model, prompt_len):
    """Branch b of an n-branch request == a serial n=1 request at seed+b with
    the SAME rid — the branch-seed law, on both page geometries (the partial-
    page case exercises fork + CoW of the shared last prompt page)."""
    cfg, model, params = small_model
    prompt = np.random.default_rng(3).integers(0, cfg.vocab, prompt_len).tolist()
    eng = fresh_engine(model, params)
    h = eng.submit(
        prompt,
        GenerationParams(max_new_tokens=6, temperature=0.8, top_k=8, seed=123, n=4),
        rid=7,
    )
    eng.run()
    group = [s.tokens for s in h.sequences]
    assert len(group) == 4
    for b in range(4):
        assert group[b] == serial_tokens(model, params, prompt, 123 + b, rid=7), b
    assert eng.cache.stats()["branch_forks"] == 3


def test_best_of_n_shares_prompt_pages(small_model):
    """n=8 branches of one prompt cost ~1x its KV pages: peak page usage stays
    under prompt_pages * 1.25 + n * decode_tail — far below n full copies."""
    cfg, model, params = small_model
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, 24).tolist()
    n, gen, ps = 8, 4, 4
    eng = fresh_engine(model, params, num_pages=128, max_pages_per_seq=16)
    eng.submit(
        prompt,
        GenerationParams(max_new_tokens=gen, temperature=0.7, top_k=8, seed=5, n=n),
        rid=3,
    )
    eng.run()
    st = eng.cache.stats()
    prompt_pages = eng.cache.pages_for(len(prompt))
    tail_pages = eng.cache.pages_for(gen + ps)  # decode growth + partial slack
    assert st["branch_forks"] == n - 1
    assert st["peak_pages_in_use"] <= prompt_pages * 1.25 + n * tail_pages
    # the naive footprint (every branch re-prefilled) would be n * prompt_pages
    assert st["peak_pages_in_use"] < n * prompt_pages


def test_branch_eos_does_not_stall_or_corrupt_siblings(small_model):
    """Stop branch 0 early via eos and check branch 1 still exactly matches its
    serial twin — per-branch finish must neither stall the group nor free the
    shared pages under the survivor."""
    cfg, model, params = small_model
    prompt = np.random.default_rng(5).integers(0, cfg.vocab, 7).tolist()
    base = serial_tokens(model, params, prompt, seed=50, rid=9)
    eos = base[2]  # force branch 0 (seed 50) to finish after 3 tokens
    sib = serial_tokens(model, params, prompt, seed=51, rid=9)
    if eos in sib:
        sib = sib[: sib.index(eos) + 1]
    eng = fresh_engine(model, params)
    h = eng.submit(
        prompt,
        GenerationParams(
            max_new_tokens=6, temperature=0.8, top_k=8, seed=50, n=2, eos_id=eos
        ),
        rid=9,
    )
    eng.run()
    seqs = h.sequences
    assert seqs[0].tokens == base[:3] and seqs[0].finish_reason == "eos"
    assert seqs[1].tokens == sib  # survivor unaffected, token-exact
    assert seqs[1].finish_reason == ("eos" if sib and sib[-1] == eos else "length")


def test_impossible_group_rejected_at_enqueue(small_model):
    """A branch group the pool can never hold fails at submit() with a clear
    error — enqueue-time validation, never a mid-step scheduler discovery."""
    cfg, model, params = small_model
    prompt = np.random.default_rng(6).integers(0, cfg.vocab, 40).tolist()
    eng = fresh_engine(model, params, num_pages=8, max_pages_per_seq=16)
    with pytest.raises(ValueError, match="across 2 branches"):
        eng.submit(
            prompt,
            GenerationParams(max_new_tokens=4, temperature=0.5, seed=0, n=2),
            rid=1,
        )


def test_beam_search_deterministic_ranked_and_reorders_in_place(small_model):
    cfg, model, params = small_model
    prompt = np.random.default_rng(7).integers(0, cfg.vocab, 6).tolist()

    def run():
        eng = fresh_engine(model, params, max_beam_width=4)
        h = eng.submit(
            prompt, GenerationParams(max_new_tokens=5, beam_width=4, n=2), rid=11
        )
        eng.run()
        return eng, h.sequences

    eng, seqs = run()
    assert len(seqs) == 2
    assert seqs[0].cumulative_logprob >= seqs[1].cumulative_logprob
    assert all(len(s.tokens) <= 5 for s in seqs)
    assert eng.cache.stats()["beam_reorders"] >= 1
    _, again = run()
    assert [s.tokens for s in again] == [s.tokens for s in seqs]
    assert [s.cumulative_logprob for s in again] == pytest.approx(
        [s.cumulative_logprob for s in seqs]
    )


def test_beam_rejects_width_above_engine_cap(small_model):
    cfg, model, params = small_model
    eng = fresh_engine(model, params, max_beam_width=2)
    with pytest.raises(ValueError, match="beam"):
        eng.submit([1, 2, 3], GenerationParams(beam_width=4, max_new_tokens=2))


def grammar_setup(vocab, n_items=3):
    charmap = {ch: i for i, ch in enumerate(JSON_ARRAY_CHARS)}
    eos = len(JSON_ARRAY_CHARS)
    return charmap, eos, fixed_json_array_dfa(charmap, eos, vocab, n_items=n_items)


def test_constrained_decoding_always_parses(small_model):
    """The 100%-valid law: every generation under fixed_json_array_dfa with
    enough budget terminates at eos and json-parses, at ANY temperature/seed —
    the mask, not luck, guarantees it."""
    cfg, model, params = small_model
    charmap, eos, dfa = grammar_setup(cfg.vocab)
    inv = {i: ch for ch, i in charmap.items()}
    eng = fresh_engine(model, params, grammar_states=dfa.n_states)
    rng = np.random.default_rng(8)
    handles = [
        eng.submit(
            rng.integers(0, cfg.vocab, 5).tolist(),
            GenerationParams(
                max_new_tokens=12, temperature=0.9, seed=i, eos_id=eos, grammar=dfa
            ),
            rid=20 + i,
        )
        for i in range(4)
    ]
    eng.run()
    for h in handles:
        seq = h.sequences[0]
        assert seq.finish_reason == "eos"
        assert dfa.valid_prefix(seq.tokens)
        parsed = json.loads("".join(inv[t] for t in seq.tokens if t != eos))
        assert isinstance(parsed, list) and len(parsed) == 3


def test_constrained_decoding_multistep_exact(small_model):
    """Grammar state rides the fused lax.scan carry: multi_step=4 outputs are
    bit-identical to single-step outputs."""
    cfg, model, params = small_model
    charmap, eos, dfa = grammar_setup(cfg.vocab)
    prompt = np.random.default_rng(9).integers(0, cfg.vocab, 5).tolist()

    def run(k):
        eng = fresh_engine(
            model, params, grammar_states=dfa.n_states, multi_step=k
        )
        h = eng.submit(
            prompt,
            GenerationParams(
                max_new_tokens=12, temperature=0.9, seed=2, eos_id=eos, grammar=dfa
            ),
            rid=5,
        )
        eng.run()
        return h.sequences[0].tokens

    assert run(1) == run(4)


def test_unbounded_grammar_yields_valid_prefixes(small_model):
    """json_array_dfa is unbounded: a walk may hit the length cap mid-array,
    but every emitted token was allowed by the state it left — the invariant a
    masked sampler can never violate."""
    cfg, model, params = small_model
    charmap = {ch: i for i, ch in enumerate(JSON_ARRAY_CHARS)}
    eos = len(JSON_ARRAY_CHARS)
    dfa = json_array_dfa(charmap, eos, cfg.vocab)
    eng = fresh_engine(model, params, grammar_states=dfa.n_states)
    h = eng.submit(
        np.random.default_rng(10).integers(0, cfg.vocab, 5).tolist(),
        GenerationParams(
            max_new_tokens=8, temperature=1.0, seed=3, eos_id=eos, grammar=dfa
        ),
        rid=2,
    )
    eng.run()
    assert dfa.valid_prefix(h.sequences[0].tokens)


def test_submit_legacy_kwargs_warn_and_run(small_model):
    cfg, model, params = small_model
    prompt = [1, 2, 3, 4]
    eng = fresh_engine(model, params)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        h = eng.submit(Request(0, prompt, max_new_tokens=3))
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert isinstance(h, RequestHandle)
    eng.run()
    assert len(h.sequences) == 1 and len(h.sequences[0].tokens) == 3
    assert h.sequences[0].finish_reason == "length"


def test_handle_raises_before_run_and_resolves_after(small_model):
    cfg, model, params = small_model
    eng = fresh_engine(model, params)
    h = eng.submit([1, 2, 3], GenerationParams(max_new_tokens=2))
    assert not h.done
    with pytest.raises(RuntimeError, match="not finished"):
        h.result()
    eng.run()
    assert h.done and h.sequences[0].finish_reason == "length"
