"""Hierarchical KV: the host-memory page tier behind the accessor axis.

Core laws first — HostTierAccessor / LayoutPaged residency are the formal
model (space routing is total, migration never moves an offset) — then the
serving realization: TierManager demotion/promotion through the engine
(preemption as swap, session resume as prefetch), the tier edge cases the
satellite list names, and the same-step twin prefill sharing protocol.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BasicAccessor, Extents, HostTierAccessor, LayoutPaged, MemorySpace,
)
from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.engine.request import page_hash_chain


# =====================================================================================
# core laws — the accessor/layout model of residency
# =====================================================================================
def test_host_tier_accessor_routes_by_page_and_decays_merged():
    acc = HostTierAccessor(BasicAccessor(), page_elems=4, host_pages=(1, 3))
    span = 16  # 4 pages of 4 elements
    assert acc.space_for_offset(0) == MemorySpace.HBM
    assert acc.space_for_offset(5) == MemorySpace.HOST
    assert acc.space_for_offset(15) == MemorySpace.HOST
    dense = jnp.arange(span, dtype=jnp.float32)
    buffers = acc.from_codomain(dense)
    # from_codomain encodes into HBM; host pages read cold zeros until stores
    # route values there
    idx = jnp.arange(span)
    got = acc.access(buffers, idx)
    host_mask = np.isin(np.arange(span) // 4, [1, 3])
    np.testing.assert_array_equal(np.asarray(got)[~host_mask],
                                  np.asarray(dense)[~host_mask])
    np.testing.assert_array_equal(np.asarray(got)[host_mask], 0.0)
    # a full-span store lands every element in its page's space; decay merges
    buffers = acc.store(buffers, idx, dense * 2)
    np.testing.assert_array_equal(np.asarray(acc.decay(buffers)),
                                  np.asarray(dense) * 2)


def test_host_tier_accessor_migrate_is_pure_copy_plus_residency_flip():
    acc = HostTierAccessor(BasicAccessor(), page_elems=4, host_pages=())
    dense = jnp.arange(8, dtype=jnp.float32)  # 2 pages
    buffers = acc.from_codomain(dense)
    buffers, acc2 = acc.migrate(buffers, 1, MemorySpace.HOST)
    assert acc2.host_pages == (1,)
    assert acc2.space_for_offset(4) == MemorySpace.HOST
    # offsets unchanged: the merged view still reads the same codomain
    np.testing.assert_array_equal(np.asarray(acc2.decay(buffers)),
                                  np.asarray(dense))
    # round-trip back to HBM restores the original accessor's routing
    buffers, acc3 = acc2.migrate(buffers, 1, MemorySpace.HBM)
    assert acc3.host_pages == ()
    np.testing.assert_array_equal(np.asarray(acc3.decay(buffers)),
                                  np.asarray(dense))


def test_layout_paged_space_queries_total_and_migration_invariant():
    H, D, ps = 2, 4, 4
    lp = LayoutPaged(
        Extents.fully_dynamic(2, H, 8, D), ((5, 2), (7, 1)), ps, 9,
        host_pages=(2, 7),
    )
    # total over the domain: every index answers a space
    assert lp.space_for(0, 0, 0, 0) == MemorySpace.HBM   # page 5
    assert lp.space_for(0, 1, 5, 3) == MemorySpace.HOST  # page 2
    assert lp.space_for(1, 0, 1, 0) == MemorySpace.HOST  # page 7
    # the offset query agrees with the index query through __call__
    for idx in [(0, 0, 0, 0), (0, 1, 5, 3), (1, 0, 1, 0), (1, 1, 6, 2)]:
        assert lp.space_for_offset(lp(*idx)) == lp.space_for(*idx)
    with pytest.raises(ValueError):
        lp.space_for_offset(lp.required_span_size())
    # residency threads through the layout algebra without touching offsets
    forked = lp.fork(0, ())
    assert forked.host_pages == lp.host_pages
    assert [forked(0, 0, p, 0) for p in range(8)] == [
        lp(0, 0, p, 0) for p in range(8)
    ]


# =====================================================================================
# serving — the tier through the engine
# =====================================================================================
@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _reqs(prompts, n_gen):
    return [
        Request(rid=i, prompt=list(p), params=GenerationParams(max_new_tokens=n_gen))
        for i, p in enumerate(prompts)
    ]


def test_preemption_swaps_and_resume_prefetches_token_exact(small_model):
    """Tight pool + host tier: preemption demotes, re-admission promotes, and
    outputs match an unconstrained tier-less engine exactly."""
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    big = ServeEngine(model, params, EngineConfig(
        num_pages=64, page_size=4, max_batch=3, max_pages_per_seq=6))
    ref = big.run(_reqs(prompts, 10))
    tiered = ServeEngine(model, params, EngineConfig(
        num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6,
        host_pool_pages=32))
    res = tiered.run(_reqs(prompts, 10))
    m = tiered.metrics()
    assert m["preemptions"] >= 1
    assert m["swap_out_pages"] > 0
    assert m["prefetch_hits"] > 0
    assert m["swap_in_pages"] == m["prefetch_hits"]
    for i in range(len(prompts)):
        assert res[i].generated == ref[i].generated


def test_zero_host_headroom_falls_back_to_recompute_token_exact(small_model):
    """A starved tier (or none) degrades to the seed behaviour — free and
    recompute — with identical tokens. host_pool_pages=1 forces constant
    eviction; every promotion miss recomputes."""
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    base = ServeEngine(model, params, EngineConfig(
        num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6))
    ref = base.run(_reqs(prompts, 10))
    starved = ServeEngine(model, params, EngineConfig(
        num_pages=10, page_size=4, max_batch=3, max_pages_per_seq=6,
        host_pool_pages=1))
    res = starved.run(_reqs(prompts, 10))
    m = starved.metrics()
    assert m["preemptions"] >= 1
    assert m["host_pages_resident"] <= 1
    for i in range(len(prompts)):
        assert res[i].generated == ref[i].generated


def test_prefetch_preempt_resume_deterministic_and_mirror_matches(small_model):
    """Churn loop — retention, resume-prefetch, preemption mid-flight — run
    twice end to end: identical outputs both times, and the device-resident
    table/len mirrors equal the host allocator state afterwards."""
    cfg, model, params = small_model
    rng = np.random.default_rng(9)
    session = rng.integers(0, cfg.vocab, size=16).tolist()
    prompts = [session + rng.integers(0, cfg.vocab, size=k).tolist()
               for k in (2, 3, 4)]

    def run_once():
        eng = ServeEngine(model, params, EngineConfig(
            num_pages=14, page_size=4, max_batch=3, max_pages_per_seq=8,
            host_pool_pages=32, retain_finished_s=300.0))
        first = eng.run(_reqs([session], 4))
        resumed = eng.run(_reqs(prompts, 6))
        return eng, first, resumed

    eng_a, first_a, res_a = run_once()
    eng_b, first_b, res_b = run_once()
    assert first_a[0].generated == first_b[0].generated
    for i in range(len(prompts)):
        assert res_a[i].generated == res_b[i].generated
    m = eng_a.metrics()
    assert m["prefetch_hits"] > 0
    # mirror == allocator: the patched device tables/lens equal host state
    tables_dev, lens_dev = eng_a.cache.device_state()
    np.testing.assert_array_equal(np.asarray(tables_dev), eng_a.cache.tables)
    np.testing.assert_array_equal(np.asarray(lens_dev), eng_a.cache.lens)


def test_cow_on_host_promoted_shared_page(small_model):
    """Resume twice from one retained session (unaligned extensions): both
    resumers share the promoted pages plus a partial page, so decode appends
    must CoW — and the host copies stay valid for a third resume after the
    churn."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    session = rng.integers(0, cfg.vocab, size=12).tolist()  # 3 aligned pages
    ext = session + [7, 8]  # partial 4th page -> CoW on first decode append
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=48, page_size=4, max_batch=3, max_pages_per_seq=8,
        host_pool_pages=32, retain_finished_s=300.0))
    eng.run(_reqs([session], 3))
    assert eng.metrics()["host_pages_resident"] >= 3
    res = eng.run([
        Request(rid=10, prompt=list(ext), params=GenerationParams(max_new_tokens=5)),
        Request(rid=11, prompt=list(ext), params=GenerationParams(max_new_tokens=5)),
    ])
    m = eng.metrics()
    assert m["prefetch_hits"] >= 3
    assert m["cow_copies"] >= 1
    assert res[10].generated == res[11].generated
    # third resume after CoW churn: the host tier still answers, exactly
    res2 = eng.run([
        Request(rid=12, prompt=list(ext), params=GenerationParams(max_new_tokens=5)),
    ])
    assert res2[12].generated == res[10].generated
    oracle = ServeEngine(model, params, EngineConfig(
        num_pages=48, page_size=4, max_batch=3, max_pages_per_seq=8))
    ref = oracle.run([
        Request(rid=10, prompt=list(ext), params=GenerationParams(max_new_tokens=5)),
    ])
    assert res[10].generated == ref[10].generated


def test_int4_pages_round_trip_hbm_host_bit_identical(small_model):
    """Demote -> free -> promote of int4 pages preserves every stored byte —
    packed q AND per-(page, head) scales — because migration moves whole
    page-major pytrees, never re-encoding."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=2, max_pages_per_seq=6,
        kv_dtype="int4", host_pool_pages=8))
    cache = eng.cache
    rng = np.random.default_rng(3)
    tokens = rng.integers(0, cfg.vocab, size=12).tolist()
    chain = page_hash_chain(tokens, cache.page_size)
    pages = cache.allocate(0, 4, tokens=tokens)
    # fill the slot's pages with distinctive bytes via the pool arrays
    seed = [3]

    def scribble(leaf):
        arr = np.asarray(leaf).copy()
        seed[0] += 1
        r = np.random.default_rng(seed[0])
        arr[:, pages] = r.integers(0, 100, size=arr[:, pages].shape).astype(arr.dtype)
        return jnp.asarray(arr)

    cache.pools = [jax.tree.map(scribble, pool) for pool in cache.pools]
    snapshot = [
        jax.tree.map(lambda l: np.asarray(l)[:, pages[:3]].copy(), pool)
        for pool in cache.pools
    ]
    cache.set_len(0, 12)
    assert cache.demote_slot(0, chain) == 3  # full pages only
    cache.free_slot(0)
    # wipe the freed device pages so the comparison can only pass via the tier
    cache.pools = [
        jax.tree.map(lambda l: l.at[:, pages[:3]].set(0), pool)
        for pool in cache.pools
    ]
    new_pages = cache.allocate(1, 4, tokens=tokens, chain=chain)
    assert cache.tier.prefetch_hits == 3
    for pool, snap in zip(cache.pools, snapshot):
        got = jax.tree.map(lambda l: np.asarray(l)[:, new_pages[:3]], pool)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(snap)):
            np.testing.assert_array_equal(a, b)
    cache.free_slot(1)
    cache.check_conservation()


def test_reject_impossible_releases_host_residency(small_model):
    """A rejected request's context drops its host-tier residency (no
    orphaned host pages), and the conservation invariant holds throughout."""
    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    session = rng.integers(0, cfg.vocab, size=12).tolist()
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=8, page_size=4, max_batch=2, max_pages_per_seq=11,
        host_pool_pages=16, retain_finished_s=300.0))
    eng.run(_reqs([session], 3))
    assert eng.metrics()["host_pages_resident"] >= 3
    # the preemption-growth failure mode: a request servable at submit time
    # whose context (prompt + generated) outgrew the pool while requeued —
    # reject_impossible condemns it, and its host residency must go with it
    doomed = session + rng.integers(0, cfg.vocab, size=12).tolist()  # 24 toks
    eng.submit(Request(rid=99, prompt=doomed,
                       params=GenerationParams(max_new_tokens=16)))
    eng._pending[0].generated.extend(int(t) for t in
                                     rng.integers(0, cfg.vocab, size=8))
    res = eng.run()
    assert res[99].error is not None
    assert len(res[99].generated) == 8  # nothing generated past the requeue
    assert eng.metrics()["host_pages_resident"] == 0
    eng.cache.check_conservation()


def test_conservation_check_catches_refcount_leak(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=8, page_size=4, max_batch=2, max_pages_per_seq=4))
    cache = eng.cache
    cache.allocate(0, 2, tokens=list(range(5)))
    cache.check_conservation()  # clean state passes
    cache.ref[cache.pages_of[0][0]] += 1  # simulate a leak
    with pytest.raises(AssertionError):
        cache.check_conservation()
    cache.ref[cache.pages_of[0][0]] -= 1
    cache.free_slot(0)
    cache.check_conservation()


# =====================================================================================
# same-step twins — prefill sharing via the written frontier
# =====================================================================================
def test_same_step_twins_share_prefill_compute(small_model):
    """Two identical prompts co-admitted in one step under chunked prefill:
    the second adopts the first's in-flight pages (per-page written frontier)
    instead of recomputing, and both outputs match the solo oracle."""
    cfg, model, params = small_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab, size=24).tolist()
    conf = EngineConfig(
        num_pages=32, page_size=4, max_batch=2, max_pages_per_seq=9,
        chunked_prefill=True, chunk_tokens=8)
    solo = ServeEngine(model, params, conf)
    ref = solo.run(_reqs([prompt], 5))
    twin = ServeEngine(model, params, conf)
    res = twin.run(_reqs([prompt, prompt], 5))
    m = twin.metrics()
    assert res[0].generated == res[1].generated == ref[0].generated
    # the adopter skipped (almost) the whole prompt: computed tokens stay far
    # below 2x the solo engine's
    assert m["prefill_tokens_computed"] < 2 * solo.metrics()["prefill_tokens_computed"]
    assert m["prefill_tokens_skipped"] >= 16


def test_twin_donor_death_breaks_adopter_for_clean_readmit(small_model):
    """Cache-level protocol: when the donor frees before its frontier covers
    the adopter's run, the adopter lands in take_broken() and its garbage
    pages never demote; a fresh allocation then proceeds normally."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=3, max_pages_per_seq=6,
        chunked_prefill=True, chunk_tokens=8, host_pool_pages=8))
    cache = eng.cache
    tokens = list(range(100, 112))  # 3 content pages
    chain = page_hash_chain(tokens, cache.page_size)
    cache.allocate(0, 4, tokens=tokens, chain=chain, publish=False)  # donor
    cache.allocate(1, 4, tokens=tokens, chain=chain, publish=False)  # twin
    assert not cache.frontier_ready(1)
    cache.set_len(1, 12)
    assert cache.demote_slot(1, chain) == 0  # gated twin never demotes
    cache.free_slot(0)  # donor dies mid-prefill
    assert cache.take_broken() == [1]
    assert cache.frontier_ready(1)  # dependency cleared with the break
    cache.free_slot(1)
    cache.check_conservation()
    # after the wreck, a clean allocation of the same chain works
    pages = cache.allocate(2, 4, tokens=tokens, chain=chain)
    assert len(pages) == 4
    cache.free_slot(2)
    cache.check_conservation()


def test_twin_frontier_clears_as_donor_publishes(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=16, page_size=4, max_batch=3, max_pages_per_seq=6,
        chunked_prefill=True, chunk_tokens=8))
    cache = eng.cache
    tokens = list(range(200, 212))
    chain = page_hash_chain(tokens, cache.page_size)
    donor_pages = cache.allocate(0, 4, tokens=tokens, chain=chain, publish=False)
    twin_pages = cache.allocate(1, 4, tokens=tokens, chain=chain, publish=False)
    # the twin increfed the donor's content pages instead of popping free ones
    assert twin_pages[:3] == donor_pages[:3]
    assert all(cache.ref[p] == 2 for p in donor_pages[:3])
    cache.publish_prefix(0, 2)  # frontier at 2 of 3 pages: still gated
    assert not cache.frontier_ready(1)
    cache.publish_prefix(0)  # complete
    assert cache.frontier_ready(1)
    cache.free_slot(0)
    cache.free_slot(1)
    cache.check_conservation()
