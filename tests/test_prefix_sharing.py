"""Prefix-sharing copy-on-write: LayoutPaged aliased-regime laws and the
PagedKVCache allocator edges (refcounts, prefix index, CoW, exhaustion).

Engine-level exactness under sharing lives in test_serving_engine.py (it needs
the real model); everything here runs on a fake model so the allocator and
layout algebra are exercised in milliseconds.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Extents, LayoutPaged
from repro.serving.engine.cache import PagedKVCache
from repro.serving.engine.request import page_hash_chain


# =====================================================================================
# LayoutPaged — shared-page-aware observers, fork(), cow_slice()
# =====================================================================================
def _layout(table, num_pages=8, shared=()):
    rows = len(table)
    pages_per = len(table[0])
    return LayoutPaged(
        Extents.fully_dynamic(rows, 2, pages_per * 4, 4), table, 4, num_pages, shared
    )


def test_shared_pages_break_uniqueness_exactly_when_referenced():
    base = _layout(((1, 2), (3, 4)))
    assert base.is_unique()
    # a shared page the table references -> not unique
    assert not _layout(((1, 2), (3, 4)), shared=(2,)).is_unique()
    # a shared page the table does NOT reference leaves the view unique
    assert _layout(((1, 2), (3, 4)), shared=(7,)).is_unique()


def test_shared_pages_normalized_and_validated():
    lp = _layout(((1, 2),), shared=(2, 1, 2))
    assert lp.shared_pages == (1, 2)
    with pytest.raises(ValueError):
        _layout(((1, 2),), shared=(9,))  # outside the pool


def test_fork_aliases_prefix_and_cow_slice_restores_uniqueness():
    base = _layout(((1, 2, 3),))
    forked = base.fork(0, fresh_pages=(4,))
    assert forked.extents.extent(0) == 2
    assert forked.block_table == ((1, 2, 3), (1, 2, 4))
    assert not forked.is_unique()  # pages 1, 2 appear in both rows
    # the two rows agree on every offset of the shared prefix (true aliasing)
    for h in range(2):
        for p in range(8):  # first two logical pages are shared
            for d in range(4):
                assert forked(0, h, p, d) == forked(1, h, p, d)
    # and diverge on the private tail
    assert forked(0, 0, 8, 0) != forked(1, 0, 8, 0)
    # CoW each shared logical page of the forked row -> unique again
    cow1 = forked.cow_slice(1, 0, 5)
    assert not cow1.is_unique()  # page 2 still aliased
    cow2 = cow1.cow_slice(1, 1, 6)
    assert cow2.block_table == ((1, 2, 3), (5, 6, 4))
    assert cow2.shared_pages == ()
    assert cow2.is_unique()


def test_cow_slice_keeps_externally_shared_page_marked():
    # external sharing (refcount>1 in the allocator) survives a cow of a
    # DIFFERENT logical page; the swapped-out page leaves shared_pages only
    # once no row references it
    lp = _layout(((1, 2),), shared=(1, 2))
    cow = lp.cow_slice(0, 0, 5)
    assert cow.block_table == ((5, 2),)
    assert cow.shared_pages == (2,)
    assert not cow.is_unique()
    cow2 = cow.cow_slice(0, 1, 6)
    assert cow2.shared_pages == ()
    assert cow2.is_unique()


def test_fork_validation():
    base = _layout(((1, 2),))
    with pytest.raises(ValueError):
        base.fork(3)
    with pytest.raises(ValueError):
        base.fork(0, fresh_pages=(3, 4, 5))  # more fresh pages than the row holds


# =====================================================================================
# page_hash_chain — the prefix keys
# =====================================================================================
def test_hash_chain_prefix_property():
    a = page_hash_chain(list(range(10)), 4)  # 2 full + 1 partial
    b = page_hash_chain(list(range(12)), 4)  # 3 full
    assert len(a) == 3 and len(b) == 3
    assert a[:2] == b[:2]  # equal full-page prefixes -> equal keys
    assert a[2] != b[2]  # partial(8,9) vs full(8..11)
    c = page_hash_chain([99] + list(range(1, 10)), 4)
    assert c[0] != a[0] and c[1] != a[1]  # chained: early divergence poisons all
    assert page_hash_chain([1, 2], 4)[0][-1] == "partial"


# =====================================================================================
# PagedKVCache allocator edges (fake model: L=1, Hkv=2, Dh=4)
# =====================================================================================
@dataclasses.dataclass
class FakeCfg:
    n_kv_heads: int = 2
    head_dim: int = 4


class FakeModel:
    cfg = FakeCfg()

    def init_paged_cache(self, num_pages, page_size):
        shape = (1, num_pages, self.cfg.n_kv_heads, page_size, self.cfg.head_dim)
        return [{"k": jnp.zeros(shape), "v": jnp.zeros(shape)}]


def make_cache(num_pages=10, page_size=4, prefix_sharing=True, max_pages_per_seq=8):
    return PagedKVCache(
        FakeModel(), num_pages=num_pages, page_size=page_size, max_batch=4,
        max_pages_per_seq=max_pages_per_seq, prefix_sharing=prefix_sharing,
    )


def test_free_list_exhaustion_mid_append_page():
    c = make_cache(num_pages=4)  # 3 usable pages
    c.allocate(0, 3, tokens=list(range(12)))
    assert c.num_free == 0
    assert not c.append_page(0)  # exhausted -> False, state intact
    assert c.pages_of[0] == [1, 2, 3]
    c.free_slot(0)
    assert c.num_free == 3


def test_allocate_exhaustion_raises_without_corrupting_state():
    c = make_cache(num_pages=4)
    c.allocate(0, 2, tokens=list(range(8)))
    before = c.ref.copy()
    with pytest.raises(RuntimeError, match="pool exhausted"):
        c.allocate(1, 3, tokens=list(range(100, 112)))
    np.testing.assert_array_equal(c.ref, before)
    assert 1 not in c.pages_of


def test_double_free_slot_is_idempotent_and_refs_stay_nonnegative():
    c = make_cache()
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    c.allocate(1, 3, tokens=toks)  # full share
    free0 = c.num_free
    c.free_slot(0)
    c.free_slot(0)  # double free: no-op
    c.free_slot(0)
    assert c.num_free == free0  # shared pages survive with slot 1
    assert int(c.ref.min()) >= 0
    c.free_slot(1)
    c.free_slot(1)
    assert int(c.ref.min()) >= 0 and int(c.ref.max()) == 0
    assert c.num_free == c.num_pages - 1
    assert not c._index  # index emptied with the last holder


def test_prefix_sharing_counts_and_index_eviction():
    c = make_cache()
    donor = list(range(10))  # pages: 2 full + partial
    c.allocate(0, c.pages_for(11), tokens=donor)
    assert c.new_pages_needed(donor) == 0  # identical prompt: all 3 adoptable
    assert c.new_pages_needed(donor[:8] + [77, 78]) == 1  # diverges in partial
    assert c.new_pages_needed([77] + donor[1:]) == 3  # diverges at once
    c.allocate(1, c.pages_for(11), tokens=donor)
    assert c.pages_of[1] == c.pages_of[0]
    assert c.pages_shared_total == 3
    # free the donor: pages live on under slot 1, then die with it
    c.free_slot(0)
    assert c.new_pages_needed(donor) == 0
    c.free_slot(1)
    assert c.new_pages_needed(donor) == 3  # index evicted at refcount zero


def test_sharing_disabled_never_matches():
    c = make_cache(prefix_sharing=False)
    toks = list(range(8))
    c.allocate(0, 2, tokens=toks)
    assert c.new_pages_needed(toks) == c.pages_for(9)
    c.allocate(1, 2, tokens=toks)
    assert c.pages_shared_total == 0
    assert not set(c.pages_of[0]) & set(c.pages_of[1])


def test_cow_leaves_donor_pages_byte_identical():
    c = make_cache()
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    # stamp recognizable content into the donor's pages
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal(c.pools[0]["k"].shape), jnp.float32)
    v = jnp.asarray(rng.standard_normal(c.pools[0]["v"].shape), jnp.float32)
    c.pools = [{"k": k, "v": v}]
    donor_k = np.array(k[:, c.pages_of[0]])
    c.allocate(1, 3, tokens=toks)
    c.lens[1] = 10
    assert c.needs_cow(1)
    assert c.cow_page(1)
    new_page = c.pages_of[1][2]
    assert new_page != c.pages_of[0][2]
    # the copy carries the donor's bytes; the sharer now scribbles over it
    np.testing.assert_array_equal(
        np.array(c.pools[0]["k"][:, new_page]), donor_k[:, 2]
    )
    c.pools = [
        {"k": c.pools[0]["k"].at[:, new_page].set(-1.0),
         "v": c.pools[0]["v"].at[:, new_page].set(-1.0)}
    ]
    # ... and the donor's pages are byte-identical to before the fork
    np.testing.assert_array_equal(np.array(c.pools[0]["k"][:, c.pages_of[0]]), donor_k)
    assert not c.needs_cow(1)
    assert c.cow_copies == 1
    assert int(c.ref.min()) >= 0


def test_cow_page_reports_pool_exhaustion():
    c = make_cache(num_pages=4)  # 3 usable
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    c.allocate(1, 3, tokens=toks)  # full share, free list empty
    c.lens[1] = 10
    assert c.needs_cow(1)
    assert not c.cow_page(1)  # no free page -> caller must preempt
    c.free_slot(0)
    assert not c.needs_cow(1)  # donor gone: page is private again


def test_layout_for_reports_aliasing_until_cow():
    c = make_cache()
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    assert c.layout_for(0).is_unique()
    c.allocate(1, 3, tokens=toks)
    assert not c.layout_for(0).is_unique()
    assert not c.layout_for(1).is_unique()
    assert c.layout_for(1).shared_pages == tuple(c.pages_of[0])
    c.lens[1] = 10
    assert c.cow_page(1)
    # slot 1 still shares the two full pages; only the partial page went private
    assert not c.layout_for(1).is_unique()
    assert c.layout_for(1).shared_pages == tuple(c.pages_of[0][:2])
    c.free_slot(0)
    assert c.layout_for(1).is_unique()
