"""Paper benchmark-suite kernels: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Extents, LayoutLeft, LayoutRight, MdSpan
from repro.kernels import ref
from repro.kernels.matvec import matvec_left, matvec_right
from repro.kernels.stencil3d import stencil3d_pallas
from repro.kernels.sum3d import sum3d_mdspan, sum3d_pallas
from repro.kernels.tinymatsum import tinymatsum_dynamic, tinymatsum_static

SHAPES_3D = [(4, 4, 8), (8, 16, 128), (16, 24, 136), (5, 7, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_3D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sum3d_sweep(shape, dtype):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        float(sum3d_pallas(x)), float(ref.sum3d(x)), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("order", ["right", "left"])
def test_sum3d_layout_dispatch(order):
    x = jax.random.normal(jax.random.key(1), (6, 10, 132))
    lay = (LayoutRight if order == "right" else LayoutLeft)(Extents.fully_dynamic(*x.shape))
    m = MdSpan.from_dense(x, layout=lay)
    np.testing.assert_allclose(float(sum3d_mdspan(m)), float(ref.sum3d(x)), rtol=2e-4)


@pytest.mark.parametrize("shape", [(6, 8, 16), (12, 10, 132), (4, 4, 4)])
@pytest.mark.parametrize("br", [1, 2, 4])
def test_stencil3d_sweep(shape, br):
    x = jax.random.normal(jax.random.key(2), shape)
    got = stencil3d_pallas(x, block_rows=br)
    np.testing.assert_allclose(np.array(got), np.array(ref.stencil3d(x)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [10, 100, 513])
@pytest.mark.parametrize("jk", [(3, 3), (5, 7), (8, 8)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_tinymatsum_static_vs_dynamic(n, jk, dtype):
    j, k = jk
    o = jax.random.normal(jax.random.key(3), (n, j, k), dtype)
    s = jax.random.normal(jax.random.key(4), (n, j, k), dtype)
    want = ref.tinymatsum(o, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(
        np.array(tinymatsum_static(o, s)).astype(np.float32), np.array(want).astype(np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        np.array(tinymatsum_dynamic(o, s, jmax=8, kmax=8)).astype(np.float32),
        np.array(want).astype(np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("ij", [(8, 128), (200, 384), (256, 256)])
def test_matvec_both_layouts(ij):
    i, j = ij
    a = jax.random.normal(jax.random.key(5), (i, j))
    x = jax.random.normal(jax.random.key(6), (j,))
    want = np.array(ref.matvec(a, x))
    np.testing.assert_allclose(np.array(matvec_right(a, x)), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(matvec_left(a.T, x)), want, rtol=2e-4, atol=2e-4)
