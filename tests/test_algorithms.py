"""Layout-generic algorithms + trace-time property gating (paper's scale/dot)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AccumulateAccessor,
    Extents,
    LayoutError,
    LayoutRight,
    LayoutStride,
    LayoutSymmetricPacked,
    MdSpan,
    QuantizedAccessor,
    algorithms as alg,
)


def test_scale_dense():
    m = MdSpan.from_dense(jnp.arange(6.0).reshape(2, 3))
    np.testing.assert_allclose(np.array(alg.scale(m, 2.0).to_dense()), 2 * np.arange(6.0).reshape(2, 3))


def test_scale_symmetric_via_contiguous_codomain():
    """The paper's key example: naive domain iteration would double-scale
    off-diagonals; the contiguous-codomain path scales each packed slot once."""
    x = jnp.array([[1.0, 2.0, 3.0], [2.0, 5.0, 6.0], [3.0, 6.0, 9.0]])
    m = MdSpan.from_dense(x, layout=LayoutSymmetricPacked(Extents.fully_dynamic(3, 3)))
    r = alg.scale(m, 2.0)
    np.testing.assert_allclose(np.array(r.to_dense()), 2 * np.array(x))


def test_scale_non_unique_non_contiguous_rejected():
    # a deliberately aliasing strided layout with an offset (not contiguous)
    lay = LayoutStride(Extents.fully_dynamic(2, 2), strides=(1, 1), offset=1)
    assert not lay.is_unique() and not lay.is_contiguous()
    m = MdSpan(jnp.zeros(4), lay, __import__("repro.core", fromlist=["BasicAccessor"]).BasicAccessor(jnp.float32))
    with pytest.raises(LayoutError):
        alg.scale(m, 2.0)


def test_scale_quantized_touches_only_scales():
    qa = QuantizedAccessor(jnp.float32, bits=8, block=8)
    m = MdSpan.from_dense(jnp.linspace(-1, 1, 16).reshape(2, 8), accessor=qa)
    r = alg.scale(m, 3.0)
    # negative-overhead path: q unchanged, scales scaled
    np.testing.assert_array_equal(np.array(r.buffers["q"]), np.array(m.buffers["q"]))
    np.testing.assert_allclose(np.array(r.buffers["scale"]), 3 * np.array(m.buffers["scale"]), rtol=1e-6)


def test_dot_no_uniqueness_requirement():
    """Paper: dot product works on non-unique layouts."""
    x = jnp.array([[1.0, 2.0], [2.0, 3.0]])
    sym = LayoutSymmetricPacked(Extents.fully_dynamic(2, 2))
    a = MdSpan.from_dense(x, layout=sym)
    b = MdSpan.from_dense(x, layout=sym)
    assert float(alg.dot(a, b)) == float(jnp.sum(x * x))


def test_reduce_sum_counts_domain_not_codomain():
    x = jnp.array([[1.0, 5.0], [5.0, 2.0]])
    m = MdSpan.from_dense(x, layout=LayoutSymmetricPacked(Extents.fully_dynamic(2, 2)))
    assert float(alg.reduce_sum(m)) == 13.0  # off-diagonal counted twice


def test_add_into_non_unique_requires_accumulate():
    sym = LayoutSymmetricPacked(Extents.fully_dynamic(2, 2))
    x = jnp.array([[1.0, 2.0], [2.0, 3.0]])
    m = MdSpan.from_dense(x, layout=sym)
    with pytest.raises(LayoutError):
        alg.add_into(m, m)
    macc = MdSpan(
        AccumulateAccessor(jnp.float32).from_codomain(m.buffers), sym, AccumulateAccessor(jnp.float32)
    )
    r = alg.add_into(macc, m)
    # accumulate semantics: each codomain slot receives ALL domain contributions
    # diag slots get 1 contribution, off-diag get 2
    np.testing.assert_allclose(
        np.array(r.to_dense()), np.array([[2.0, 6.0], [6.0, 6.0]])
    )


def test_matvec_layout_generic():
    a = jnp.arange(12.0).reshape(3, 4)
    x = jnp.arange(4.0)
    from repro.core import LayoutLeft

    for lay in [LayoutRight(Extents.fully_dynamic(3, 4)), LayoutLeft(Extents.fully_dynamic(3, 4))]:
        m = MdSpan.from_dense(a, layout=lay)
        np.testing.assert_allclose(np.array(alg.matvec(m, MdSpan.from_dense(x))), np.array(a @ x))


def test_fill_and_copy():
    m = MdSpan.from_dense(jnp.zeros((2, 3)))
    f = alg.fill(m, 7.0)
    np.testing.assert_allclose(np.array(f.to_dense()), 7.0)
    dst = alg.copy(m, f)
    np.testing.assert_allclose(np.array(dst.to_dense()), 7.0)
