"""Checkpoint store: atomicity, resume discovery, reshard-on-load, GC, async."""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(4)},
        "opt": {"m": {"q": jnp.zeros((4,), jnp.int8), "scale": jnp.ones(1)}, "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save(tmp_path, 5, t)
    assert latest_step(tmp_path) == 5
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = restore(tmp_path, 5, target)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_uncommitted_checkpoint_invisible(tmp_path):
    t = tree()
    save(tmp_path, 3, t)
    # simulate a crash mid-save: step dir without COMMIT
    broken = tmp_path / "step_00000009"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 3
    with pytest.raises(FileNotFoundError):
        restore(tmp_path, 9, tree())


def test_manager_gc_keeps_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree())
    steps = sorted(
        int(d.name.split("_")[1]) for d in Path(tmp_path).iterdir() if d.name.startswith("step_")
    )
    assert steps == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(11, tree())
    mgr.wait()
    assert mgr.latest() == 11


def test_reshard_on_load_changes_sharding(tmp_path):
    """Restore onto a different sharding than saved — the elastic path."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(tmp_path, 1, t)
    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data", None))
    target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32, sharding=sh)}
    r = restore(tmp_path, 1, target)
    assert r["w"].sharding == sh
    np.testing.assert_array_equal(np.array(r["w"]), np.array(t["w"]))


def test_dtype_cast_on_restore(tmp_path):
    t = {"w": jnp.ones((2, 2), jnp.float32)}
    save(tmp_path, 1, t)
    target = {"w": jax.ShapeDtypeStruct((2, 2), jnp.bfloat16)}
    r = restore(tmp_path, 1, target)
    assert r["w"].dtype == jnp.bfloat16
