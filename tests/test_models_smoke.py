"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, build_model, get_config


def make_batch(cfg, B=2, S=16, key=jax.random.key(0)):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), cfg.param_dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    ctx = model.encode_ctx(params, batch)
    logits, aux = model.forward(params, batch["tokens"][:, :S], ctx=ctx, remat=False)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.array(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    from repro.optim import AdamWConfig
    from repro.train import make_train_step

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    step, pspecs, sspecs = make_train_step(model, AdamWConfig(lr=1e-3))
    from repro.core.distributed import tree_initialize

    params = tree_initialize(pspecs, jax.random.key(0))
    opt_state = tree_initialize(sspecs, jax.random.key(1))
    batch = make_batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt_state2["step"]) == 1
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "dbrx-132b", "mamba2-780m", "recurrentgemma-2b", "whisper-large-v3"])
def test_smoke_microbatched_step_matches_loss_scale(arch):
    """Gradient accumulation gives a comparable loss to single-batch."""
    from repro.core.distributed import tree_initialize
    from repro.optim import AdamWConfig
    from repro.train import TrainProfile, make_train_step

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    batch = make_batch(cfg, B=4, S=16)
    losses = {}
    for k in (1, 2):
        step, pspecs, sspecs = make_train_step(
            model, AdamWConfig(lr=0.0, weight_decay=0.0), TrainProfile(num_microbatches=k)
        )
        params = tree_initialize(pspecs, jax.random.key(0))
        opt_state = tree_initialize(sspecs, jax.random.key(1))
        _, _, m = jax.jit(step)(params, opt_state, batch)
        losses[k] = float(m["loss"])
    assert abs(losses[1] - losses[2]) < 0.1, losses
