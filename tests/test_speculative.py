"""Speculative decoding: n-gram drafts, one-call verify, lens-rollback accept.

Pins the speculative contract (serving/speculative.py, ops.verify_draft_tokens,
the engine's _decode_spec_once driver):

  - GREEDY speculative output is token-EXACT against the non-speculative
    engine — same seed, same params, any K, any window count, quantized KV
    included (acceptance is argmax agreement, so the committed stream IS the
    serial greedy stream);
  - the n-gram table is a pure function of the token context: the host
    rebuild (NGramProposer.rebuild_row) is bit-identical to the device's
    incremental in-window insertion history, so preemption-recompute and
    plain/speculative interleaving never drift the proposer;
  - rollback is layout arithmetic: after a run full of rejected drafts the
    persistent device mirrors still equal the host allocator state;
  - EOS inside an accepted draft truncates the commit exactly like the fused
    window's overrun-discard rule;
  - speculation degrades, never errors: page starvation, per-request opt-out
    and short horizons all fall back to the plain path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import build_model, get_config
from repro.serving import GenerationParams
from repro.serving.engine import (
    EngineConfig, Request, SamplingParams, ServeEngine,
)
from repro.serving.engine.cache import PagedKVCache
from repro.serving.engine.request import RequestQueue, RequestState
from repro.serving.engine.scheduler import Scheduler, SchedulerConfig
from repro.serving.speculative import (
    NGramProposer, ngram_keys_jnp, ngram_keys_np,
)


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _mk(prompts, n, **kw):
    return [
        Request(rid=i, prompt=list(p), params=GenerationParams(
            max_new_tokens=n, **kw))
        for i, p in enumerate(prompts)
    ]


# =====================================================================================
# the n-gram proposer — host/device hash equality, rebuild == incremental
# =====================================================================================
def test_ngram_hash_host_device_bit_identical():
    rng = np.random.default_rng(0)
    grams = rng.integers(0, 50_000, size=(64, 3)).astype(np.int32)
    host = ngram_keys_np(grams, 256)
    dev = np.asarray(ngram_keys_jnp(jnp.asarray(grams), 256))
    np.testing.assert_array_equal(host, dev)
    assert host.min() >= 0 and host.max() < 256


def test_rebuild_row_matches_incremental_device_updates():
    """The device's in-window update (shifted insertion: gram ending at q
    inserted once token q+1 commits) replays EXACTLY as the host rebuild of
    the final context — the invariant that makes _spec_stale rebuilds safe."""
    prop = NGramProposer(spec_tokens=3, ngram=2, table_size=64, vocab=40,
                         hist_len=96)
    rng = np.random.default_rng(1)
    ctx = rng.integers(0, 40, size=9).tolist()  # current token last
    hist_np, table_np = prop.rebuild_row(ctx)
    hist = jnp.asarray(hist_np[None])
    table = jnp.asarray(table_np[None])
    active = jnp.asarray([1], jnp.int32)
    c = prop.spec_tokens + 1
    for _ in range(6):  # several windows with varying partial acceptance
        lens = jnp.asarray([len(ctx) - 1], jnp.int32)
        tokens_out = jnp.asarray(
            rng.integers(0, 40, size=(1, c)).astype(np.int32)
        )
        a = int(rng.integers(1, c + 1))
        hist, table = prop.update(
            hist, table, lens, tokens_out, jnp.asarray([a], jnp.int32), active
        )
        ctx = ctx + np.asarray(tokens_out)[0, :a].tolist()
        h_ref, t_ref = prop.rebuild_row(ctx)
        n = len(ctx)
        np.testing.assert_array_equal(np.asarray(hist)[0, :n], h_ref[:n])
        np.testing.assert_array_equal(
            np.asarray(table)[0, : prop.table_size], t_ref[: prop.table_size]
        )


def test_propose_never_self_matches_and_drafts_from_history():
    """A repeating stream must draft its own continuation; the lookup must
    find the EARLIER occurrence (shifted insertion), never the gram currently
    being extended."""
    prop = NGramProposer(spec_tokens=3, ngram=2, table_size=64, vocab=40,
                         hist_len=64)
    ctx = [5, 6, 7, 8] * 3  # current token = 8 at position 11
    hist, table = prop.rebuild_row(ctx)
    draft = prop.propose(
        jnp.asarray(hist[None]), jnp.asarray(table[None]),
        jnp.asarray([len(ctx) - 1], jnp.int32), jnp.asarray([1], jnp.int32),
    )
    # gram (7, 8) last INSERTED ending at position 7 -> continuation 5, 6, 7
    assert np.asarray(draft)[0].tolist() == [5, 6, 7]
    # inactive rows never draft
    draft0 = prop.propose(
        jnp.asarray(hist[None]), jnp.asarray(table[None]),
        jnp.asarray([len(ctx) - 1], jnp.int32), jnp.asarray([0], jnp.int32),
    )
    assert np.asarray(draft0)[0].tolist() == [0, 0, 0]


# =====================================================================================
# ops.verify_draft_tokens — the accept/resample op
# =====================================================================================
def _verify(logits, draft, temperature=0.0, active=None, vocab=None):
    b = logits.shape[0]
    full = lambda v, dt: jnp.full((b,), v, dt)
    if active is None:
        active = full(1, jnp.int32)
    return ops.verify_draft_tokens(
        jnp.asarray(logits), jnp.asarray(draft), full(temperature, jnp.float32),
        full(0, jnp.int32), full(1.0, jnp.float32), full(0, jnp.uint32),
        full(4, jnp.int32), active, vocab=vocab or logits.shape[-1],
    )


def test_verify_greedy_accepts_longest_agreeing_prefix():
    vp, k = 16, 3
    rng = np.random.default_rng(2)
    logits = rng.standard_normal((1, k + 1, vp)).astype(np.float32)
    g = np.argmax(logits, axis=-1)[0]  # per-position greedy targets
    # draft agrees at position 0, diverges at 1
    draft = np.array([[g[0], (g[1] + 1) % vp, g[2]]], np.int32)
    toks, committed, lp = _verify(logits, draft)
    assert int(committed[0]) == 2  # 1 agreed draft token + the correction
    np.testing.assert_array_equal(np.asarray(toks)[0], g)  # rows ARE greedy
    # fully agreeing draft: K accepted + bonus
    toks, committed, _ = _verify(logits, np.array([g[:k]], np.int32))
    assert int(committed[0]) == k + 1
    # inactive row commits nothing
    _, committed, _ = _verify(
        logits, np.array([g[:k]], np.int32), active=jnp.zeros((1,), jnp.int32)
    )
    assert int(committed[0]) == 0


def test_verify_sampled_commits_at_least_one_token():
    vp, k = 16, 3
    rng = np.random.default_rng(3)
    logits = rng.standard_normal((2, k + 1, vp)).astype(np.float32)
    draft = rng.integers(0, vp, size=(2, k)).astype(np.int32)
    toks, committed, lp = _verify(logits, draft, temperature=0.9)
    assert (np.asarray(committed) >= 1).all()
    assert (np.asarray(committed) <= k + 1).all()
    assert (np.asarray(toks) < vp).all() and (np.asarray(toks) >= 0).all()
    # deterministic: same inputs, same commits
    toks2, committed2, _ = _verify(logits, draft, temperature=0.9)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
    np.testing.assert_array_equal(np.asarray(committed), np.asarray(committed2))


# =====================================================================================
# scheduler — horizon/tokens_per_step edges, window page reservation
# =====================================================================================
def test_event_free_horizon_tokens_per_step_edges(small_model):
    cfg, model, params = small_model
    cache = PagedKVCache(model, num_pages=16, page_size=4, max_batch=2,
                         max_pages_per_seq=6)
    sched = Scheduler(cache, SchedulerConfig(2, 1))
    queue = RequestQueue()
    st = RequestState(Request(0, [1, 2, 3, 4, 5, 6, 7],
                              GenerationParams(max_new_tokens=12)))
    queue.push(st)
    sched.admit(queue, 0.0)
    st.generated.append(1)  # DECODING
    cache.set_len(st.slot, 8)  # EXACTLY the owned-page boundary (2 pages * 4)
    assert cache.capacity_tokens(st.slot) == 0
    assert sched.event_free_horizon(queue) == 0
    assert sched.event_free_horizon(queue, tokens_per_step=4) == 0
    # reserve one speculative window's budget: capacity rounds up by pages
    assert sched.reserve_decode_tokens(st.slot, 4)
    assert cache.capacity_tokens(st.slot) == 4
    assert sched.event_free_horizon(queue) == 4
    assert sched.event_free_horizon(queue, tokens_per_step=4) == 1
    # tokens_per_step > capacity: no window fits
    assert sched.event_free_horizon(queue, tokens_per_step=5) == 0
    # remaining max_new budget caps it the same way (11 left, 4 per window)
    assert sched.reserve_decode_tokens(st.slot, 12)
    assert sched.event_free_horizon(queue, tokens_per_step=4) == 2
    # the per-seq page cap bounds reservation without raising
    assert not sched.reserve_decode_tokens(st.slot, 100)


# =====================================================================================
# engine — exactness, EOS, preemption, mirrors, opt-out, acceptance
# =====================================================================================
@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
@pytest.mark.parametrize("windows", [1, 2])
def test_engine_spec_greedy_token_exact(small_model, kv_dtype, windows):
    """The headline law: greedy speculative output equals the non-speculative
    engine token-for-token — single and multi-window, f32 and quantized KV."""
    cfg, model, params = small_model
    rng = np.random.default_rng(10)
    prompts = [
        (rng.integers(0, cfg.vocab, size=4).tolist() * 3)[:10] for _ in range(2)
    ]
    econf = EngineConfig(num_pages=64, page_size=8, max_batch=2,
                         max_pages_per_seq=8, kv_dtype=kv_dtype)
    res0 = ServeEngine(model, params, econf).run(_mk(prompts, 20))
    spec = ServeEngine(model, params, dataclasses.replace(
        econf, spec_tokens=3, multi_step=windows, spec_backoff=0))
    res1 = spec.run(_mk(prompts, 20))
    for i in range(len(prompts)):
        assert res0[i].generated == res1[i].generated, i
    m = spec.metrics()
    assert m["spec_windows"] > 0  # the speculative path actually ran
    assert m["accepted_tokens_per_step"] >= 1.0


def test_engine_spec_sampled_reproducible(small_model):
    """temperature > 0 speculation is reproducible (pure function of seed,
    rid, position) even though its stream deliberately differs from the
    non-speculative one (rejection sampling vs Gumbel-max)."""
    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(2)]
    econf = EngineConfig(num_pages=64, page_size=8, max_batch=2,
                         max_pages_per_seq=8, spec_tokens=3, spec_backoff=0)
    kw = dict(temperature=0.8, top_k=12, top_p=0.95, seed=123)
    res_a = ServeEngine(model, params, econf).run(_mk(prompts, 12, **kw))
    res_b = ServeEngine(model, params, econf).run(_mk(prompts, 12, **kw))
    for i in range(len(prompts)):
        assert res_a[i].generated == res_b[i].generated, i
    res_c = ServeEngine(model, params, econf).run(
        _mk(prompts, 12, **{**kw, "seed": 124}))
    assert any(res_c[i].generated != res_a[i].generated for i in res_c)


def test_engine_spec_eos_in_draft_truncates_exact(small_model):
    """An EOS landing INSIDE an accepted draft finishes the request at the
    EOS token — the commit truncates exactly like the fused window's
    overrun-discard, and output matches the non-speculative engine."""
    cfg, model, params = small_model
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(2)]
    econf = EngineConfig(num_pages=64, page_size=8, max_batch=2,
                         max_pages_per_seq=8)
    probe = ServeEngine(model, params, econf).run(_mk(prompts, 16))
    eos = probe[0].generated[5]  # an id greedy is known to hit mid-sequence
    res0 = ServeEngine(model, params, econf).run(_mk(prompts, 16, eos_id=eos))
    spec = ServeEngine(model, params, dataclasses.replace(
        econf, spec_tokens=3, multi_step=2, spec_backoff=0))
    res1 = spec.run(_mk(prompts, 16, eos_id=eos))
    assert res0[0].generated[-1] == eos and len(res0[0].generated) <= 16
    for i in res0:
        assert res0[i].generated == res1[i].generated, i


def test_engine_spec_preemption_between_windows(small_model):
    """A page-starved speculative engine (preemptions interleaving plain and
    speculative dispatches, stale proposer rows rebuilt from recomputed
    contexts) still produces the exact greedy stream."""
    cfg, model, params = small_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(3)]
    big = ServeEngine(model, params, EngineConfig(
        num_pages=64, page_size=4, max_batch=3, max_pages_per_seq=8))
    starved = ServeEngine(model, params, EngineConfig(
        num_pages=12, page_size=4, max_batch=3, max_pages_per_seq=6,
        spec_tokens=2, spec_backoff=0))
    res_big = big.run(_mk(prompts, 10))
    res_sp = starved.run(_mk(prompts, 10))
    assert starved.metrics()["preemptions"] >= 1
    for i in range(len(prompts)):
        assert res_big[i].generated == res_sp[i].generated, i


def test_engine_spec_mirrors_match_host_after_rollbacks(small_model):
    """The device-mirror law survives speculation: every window over-writes
    KV for rejected positions and the lens rollback abandons them, yet at
    quiescence the persistent device tables/lens equal the host allocator."""
    cfg, model, params = small_model
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist() for _ in range(2)]
    eng = ServeEngine(model, params, EngineConfig(
        num_pages=48, page_size=4, max_batch=2, max_pages_per_seq=8,
        spec_tokens=3, multi_step=2, spec_backoff=0))
    eng.run(_mk(prompts, 12))
    assert eng.metrics()["spec_rollback_tokens"] > 0  # rollbacks happened
    tables_dev, lens_dev = eng.cache.device_state()
    np.testing.assert_array_equal(np.asarray(tables_dev), eng.cache.tables)
    np.testing.assert_array_equal(np.asarray(lens_dev), eng.cache.lens)


def test_engine_spec_opt_out_and_validation(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(15)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist()]
    spec_conf = EngineConfig(num_pages=32, page_size=8, max_batch=1,
                             max_pages_per_seq=4, spec_tokens=3)
    # speculative=False on a spec engine: plain path, same tokens
    eng = ServeEngine(model, params, spec_conf)
    res = eng.run(_mk(prompts, 8, speculative=False))
    assert eng.metrics()["spec_windows"] == 0
    base = ServeEngine(model, params, dataclasses.replace(
        spec_conf, spec_tokens=0)).run(_mk(prompts, 8))
    assert res[0].generated == base[0].generated
    # speculative=True on a non-spec engine fails at enqueue
    plain = ServeEngine(model, params, dataclasses.replace(
        spec_conf, spec_tokens=0))
    with pytest.raises(ValueError, match="spec_tokens"):
        plain.submit(prompts[0], GenerationParams(speculative=True))
    # incompatible combos fail at construction
    with pytest.raises(ValueError, match="beam"):
        GenerationParams(speculative=True, beam_width=2)
    # spec engine + record_logits fails at init
    with pytest.raises(ValueError, match="record_logits"):
        ServeEngine(model, params, dataclasses.replace(
            spec_conf, record_logits=True))


def test_engine_spec_accepts_on_predictable_stream(small_model):
    """End-to-end acceptance: a degenerate model whose greedy stream is
    constant (all params zeroed except the embedding, so logits are uniformly
    zero and argmax pins token 0) must accept nearly every draft —
    accepted_tokens_per_step approaches K+1, and the stream stays exact."""
    cfg, model, params = small_model
    zp = jax.tree.map(jnp.zeros_like, params)
    zp = dict(zp)
    zp["embed"] = params["embed"]
    prompts = [[3, 1, 4, 1, 5, 9, 2, 6]]
    econf = EngineConfig(num_pages=64, page_size=8, max_batch=1,
                         max_pages_per_seq=8)
    res0 = ServeEngine(model, zp, econf).run(_mk(prompts, 32))
    spec = ServeEngine(model, zp, dataclasses.replace(
        econf, spec_tokens=3, multi_step=2))
    res1 = spec.run(_mk(prompts, 32))
    assert res0[0].generated == res1[0].generated
    m = spec.metrics()
    assert m["accepted_tokens_per_step"] > 1.5
    assert m["draft_hit_rate"] > 0.5
    # spec did the bulk of the decode work: every token not produced by a
    # plain decode step or the prefill first-token came from a window
    plain_steps = m["decode_steps"] - m["spec_windows"]
    assert m["spec_accepted_tokens"] == 32 - 1 - plain_steps
    # full acceptance keeps the EMA at K+1 — the backoff never fires
    assert m["spec_backoffs"] == 0


def test_engine_spec_adaptive_backoff_on_incompressible_stream(small_model):
    """On a stream with no n-gram structure drafts never hit; the acceptance
    EMA drops under spec_accept_floor after the first probe and the planner
    stops paying the per-step verify tax — plain dispatches carry the stream
    between rare re-probes, and the output stays token-exact throughout."""
    cfg, model, params = small_model
    rng = np.random.default_rng(16)
    prompts = [rng.integers(0, cfg.vocab, size=8).tolist()]
    econf = EngineConfig(num_pages=64, page_size=8, max_batch=1,
                         max_pages_per_seq=8)
    res0 = ServeEngine(model, params, econf).run(_mk(prompts, 40))
    spec = ServeEngine(model, params, dataclasses.replace(
        econf, spec_tokens=3, multi_step=2, spec_backoff=8))
    res1 = spec.run(_mk(prompts, 40))
    assert res0[0].generated == res1[0].generated
    m = spec.metrics()
    assert m["spec_backoffs"] >= 1  # the EMA tripped the floor
    # the plain path carried the stream between probes: more plain decode
    # steps than speculative windows, unlike the backoff=0 engines above
    plain_steps = m["decode_steps"] - m["spec_windows"]
    assert plain_steps > m["spec_windows"] > 0
