"""CountingAccessor: the accessor customization point used for observability.

Two layers of law:

*Pricing laws* — each accessor's ``bytes_for_offsets`` must charge the bytes
its representation actually moves: dense = one storage element per offset;
quantized = intN payload plus one f32 scale per DISTINCT block touched (the
scale is reused inside a block); bit-packed = distinct bytes touched.

*Agreement law* — driving the paged-decode twin through a counted accessor
over the flat LayoutPaged codomain must (a) reproduce the kernel twin's
output exactly and (b) measure byte traffic that matches
``benchmarks/roofline.py``'s analytic model within 10% for the f32, int8 and
int4 paths — the formula and the measurement derive the same number from
opposite ends, so a drift in either is a bug. int4 counts through
``Int4SplitHalfAccessor`` (the flat accessor that speaks the pages'
split-half nibble order), whose encoding must be byte-identical to
``PagedQuantSpec.encode_pages`` on the same pool.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.roofline import paged_decode_analytic_bytes
from repro.core.accessors import (
    BasicAccessor, BitPackedAccessor, QuantizedAccessor,
)
from repro.core.instrument import (
    CountingAccessor, TrafficTally, counted_paged_decode, flat_pool_offsets,
)
from repro.kernels.paged_attention import (
    paged_decode_attention_jnp, paged_decode_attention_quant_jnp,
)
from repro.serving.engine.kvquant import KV_DTYPES


# =====================================================================================
# bytes_for_offsets — the per-representation pricing laws
# =====================================================================================
def test_dense_bytes_one_element_per_offset():
    acc = BasicAccessor()
    assert acc.bytes_for_offsets(np.arange(10)) == 10 * 4
    assert acc.bytes_for_offsets(3) == 4
    acc16 = BasicAccessor(element_type=jnp.bfloat16)
    assert acc16.bytes_for_offsets(np.arange(10)) == 10 * 2


def test_quantized_int8_bytes_payload_plus_distinct_block_scales():
    acc = QuantizedAccessor(bits=8, block=16)
    # 10 offsets inside one block: 10 int8 payload bytes + one f32 scale
    assert acc.bytes_for_offsets(np.arange(10)) == 10 + 4
    # two offsets in two blocks: 2 payload + 2 scales
    assert acc.bytes_for_offsets(np.array([0, 16])) == 2 + 8
    # revisiting a block does NOT recharge its scale
    assert acc.bytes_for_offsets(np.array([0, 1, 15, 16])) == 4 + 8


def test_quantized_int4_bytes_distinct_bytes_plus_scales():
    acc = QuantizedAccessor(bits=4, block=16)
    # two nibbles of the same byte cost that byte once
    assert acc.bytes_for_offsets(np.array([0, 1])) == 1 + 4
    # nibbles of different bytes cost each byte
    assert acc.bytes_for_offsets(np.array([0, 2])) == 2 + 4


def test_bitpacked_bytes_distinct_bytes_touched():
    acc = BitPackedAccessor()
    assert acc.bytes_for_offsets(np.arange(8)) == 1
    assert acc.bytes_for_offsets(np.arange(16)) == 2
    assert acc.bytes_for_offsets(np.array([0, 8, 64])) == 3


# =====================================================================================
# CountingAccessor — transparent delegation + tallying
# =====================================================================================
def test_counting_accessor_delegates_and_tallies():
    acc = CountingAccessor(BasicAccessor())
    buffers = acc.from_codomain(np.arange(16.0))  # encode is not an access
    assert acc.tally.loads == 0 and acc.tally.bytes_moved == 0
    offs = np.array([1, 3, 5])
    np.testing.assert_allclose(np.asarray(acc.access(buffers, offs)),
                               [1.0, 3.0, 5.0])
    assert acc.tally.loads == 3
    assert acc.tally.bytes_loaded == 3 * 4
    buffers = acc.store(buffers, np.array([0, 2]), jnp.asarray([9.0, 9.0]))
    assert np.asarray(buffers)[0] == 9.0
    assert acc.tally.stores == 2
    assert acc.tally.bytes_stored == 2 * 4
    assert acc.tally.bytes_moved == 12 + 8
    # rebased views keep counting into the SAME tally
    assert acc.offset_policy is acc
    acc.tally.reset()
    assert acc.tally.loads == acc.tally.bytes_moved == 0


def test_counting_accessor_shared_tally():
    tally = TrafficTally()
    k_acc = CountingAccessor(BasicAccessor(), tally)
    v_acc = CountingAccessor(BasicAccessor(), tally)
    kb = k_acc.from_codomain(np.zeros(8))
    vb = v_acc.from_codomain(np.zeros(8))
    k_acc.access(kb, np.arange(4))
    v_acc.access(vb, np.arange(4))
    assert tally.loads == 8
    assert tally.bytes_loaded == 8 * 4


def test_flat_pool_offsets_matches_layout_formula():
    hkv, ps, d = 2, 4, 3
    pages = np.array([5, 0, 2])
    offs = flat_pool_offsets(pages, hkv, ps, d)
    assert offs.shape == (3, hkv, ps, d)
    for pi, page in enumerate(pages):
        for h in range(hkv):
            for s in range(ps):
                for dd in range(d):
                    want = ((page * hkv + h) * ps + s) * d + dd
                    assert offs[pi, h, s, dd] == want
    # whole-page offsets never alias
    assert np.unique(offs).size == offs.size


# =====================================================================================
# counted paged decode vs the kernel twin + the roofline analytic model
# =====================================================================================
def _paged_case(rng, *, b, hq, hkv, d, ps, num_pages, max_pages, lens):
    q = rng.standard_normal((b, hq, 1, d)).astype(np.float32)
    pool_k = rng.standard_normal((num_pages, hkv, ps, d)).astype(np.float32)
    pool_v = rng.standard_normal((num_pages, hkv, ps, d)).astype(np.float32)
    # disjoint physical pages per row, scattered through the pool
    perm = rng.permutation(num_pages)[: b * max_pages]
    tables = perm.reshape(b, max_pages).astype(np.int32)
    return (jnp.asarray(q), pool_k, pool_v, jnp.asarray(tables),
            jnp.asarray(np.asarray(lens, np.int32)))


def test_counted_paged_decode_f32_matches_twin_and_analytic():
    rng = np.random.default_rng(0)
    b, hq, hkv, d, ps = 4, 4, 2, 16, 8
    lens = [29, 0, 9, 17]  # a zero-length row must produce exact zeros
    q, pool_k, pool_v, tables, ctx = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, num_pages=16, max_pages=4,
        lens=lens,
    )
    acc = CountingAccessor(BasicAccessor())
    kb = acc.from_codomain(pool_k.reshape(-1))
    vb = acc.from_codomain(pool_v.reshape(-1))
    out, tally = counted_paged_decode(
        q, kb, vb, acc, tables, ctx, pool_shape=(16, hkv, ps, d),
    )
    ref = paged_decode_attention_jnp(
        q, jnp.asarray(pool_k), jnp.asarray(pool_v), tables, ctx,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert not np.any(np.asarray(out)[1])  # ctx 0: kernel-parity zeros
    analytic = paged_decode_analytic_bytes(
        lens, page_size=ps, n_kv_heads=hkv, head_dim=d, kv_dtype="f32",
    )
    assert analytic > 0
    assert abs(tally.bytes_moved - analytic) / analytic <= 0.10
    # live whole pages only: ceil(len/ps) pages per row, K and V
    live = sum(-(-n // ps) for n in lens)
    assert tally.loads == 2 * live * hkv * ps * d
    assert tally.stores == 0


def test_counted_paged_decode_int8_matches_twin_and_analytic():
    rng = np.random.default_rng(1)
    b, hq, hkv, d, ps = 3, 4, 2, 16, 8
    num_pages, max_pages = 12, 4
    lens = [29, 9, 17]
    q, pool_k, pool_v, tables, ctx = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, num_pages=num_pages,
        max_pages=max_pages, lens=lens,
    )
    flat = KV_DTYPES["int8"].as_flat_accessor(ps, d)
    assert flat.block == ps * d  # one scale per (page, head), kvquant's law
    acc = CountingAccessor(flat)
    kb = flat.from_codomain(jnp.asarray(pool_k.reshape(-1)))
    vb = flat.from_codomain(jnp.asarray(pool_v.reshape(-1)))
    out, tally = counted_paged_decode(
        q, kb, vb, acc, tables, ctx, pool_shape=(num_pages, hkv, ps, d),
    )
    # the SAME buffers, reshaped to the paged pool the quant kernel twin eats:
    # flat block i == (page, head) i, so q/scale reshape directly
    ref = paged_decode_attention_quant_jnp(
        q,
        jnp.asarray(kb["q"]).reshape(num_pages, hkv, ps, d),
        jnp.asarray(kb["scale"]).reshape(num_pages, hkv),
        jnp.asarray(vb["q"]).reshape(num_pages, hkv, ps, d),
        jnp.asarray(vb["scale"]).reshape(num_pages, hkv),
        tables, ctx, bits=8,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    analytic = paged_decode_analytic_bytes(
        lens, page_size=ps, n_kv_heads=hkv, head_dim=d, kv_dtype="int8",
    )
    assert abs(tally.bytes_moved - analytic) / analytic <= 0.10
    # int8 traffic must be ~4x lighter than the f32 pages it replaces (scales
    # add hkv * 4 bytes per live page against ps * d payload bytes per head)
    f32_bytes = paged_decode_analytic_bytes(
        lens, page_size=ps, n_kv_heads=hkv, head_dim=d, kv_dtype="f32",
    )
    assert f32_bytes / analytic > 3.5


def test_counted_paged_decode_int4_matches_twin_and_analytic():
    """int4's flat accessor is Int4SplitHalfAccessor (row = head_dim): its
    encoding must be byte-identical to the pool encoder's split-half packing,
    and the counted decode must match the quant kernel twin AND the analytic
    byte model — the full agreement law at the narrowest representation."""
    rng = np.random.default_rng(2)
    b, hq, hkv, d, ps = 3, 4, 2, 16, 8
    num_pages, max_pages = 12, 4
    lens = [29, 9, 17]
    q, pool_k, pool_v, tables, ctx = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, num_pages=num_pages,
        max_pages=max_pages, lens=lens,
    )
    spec = KV_DTYPES["int4"]
    flat = spec.as_flat_accessor(ps, d)
    assert flat.block == ps * d and flat.row == d
    acc = CountingAccessor(flat)
    kb = flat.from_codomain(jnp.asarray(pool_k.reshape(-1)))
    vb = flat.from_codomain(jnp.asarray(pool_v.reshape(-1)))
    # the composition law, bytes-level: the pool encoder's split-half packed
    # pages, flattened, ARE the flat accessor's q buffer (same for scales)
    enc_k = spec.encode_pages(jnp.asarray(pool_k))
    np.testing.assert_array_equal(
        np.asarray(enc_k["q"]).reshape(-1), np.asarray(kb["q"])
    )
    np.testing.assert_array_equal(
        np.asarray(enc_k["scale"]).reshape(-1), np.asarray(kb["scale"])
    )
    out, tally = counted_paged_decode(
        q, kb, vb, acc, tables, ctx, pool_shape=(num_pages, hkv, ps, d),
    )
    enc_v = spec.encode_pages(jnp.asarray(pool_v))
    ref = paged_decode_attention_quant_jnp(
        q, enc_k["q"], enc_k["scale"], enc_v["q"], enc_v["scale"],
        tables, ctx, bits=4,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    analytic = paged_decode_analytic_bytes(
        lens, page_size=ps, n_kv_heads=hkv, head_dim=d, kv_dtype="int4",
    )
    assert abs(tally.bytes_moved - analytic) / analytic <= 0.10
    # two int4 values share a byte: traffic beats int8 by ~2x at equal pages
    int8_bytes = paged_decode_analytic_bytes(
        lens, page_size=ps, n_kv_heads=hkv, head_dim=d, kv_dtype="int8",
    )
    assert int8_bytes / analytic > 1.7


def test_analytic_bytes_model():
    # one 9-token sequence, ps=8: 2 live pages, K+V, f32
    assert paged_decode_analytic_bytes(
        [9], page_size=8, n_kv_heads=2, head_dim=4, kv_dtype="f32",
    ) == 2 * (2 * 8 * 2 * 4 * 4)
    # int8 adds one f32 scale per (page, head) per pool
    assert paged_decode_analytic_bytes(
        [9], page_size=8, n_kv_heads=2, head_dim=4, kv_dtype="int8",
    ) == 2 * (2 * 8 * 2 * 4 + 2 * 2 * 4)
    # zero-length sequences move nothing
    assert paged_decode_analytic_bytes(
        [0, 0], page_size=8, n_kv_heads=2, head_dim=4,
    ) == 0
    with pytest.raises(ValueError):
        paged_decode_analytic_bytes([1], page_size=8, n_kv_heads=2,
                                    head_dim=4, kv_dtype="fp8")
