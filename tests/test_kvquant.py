"""Quantized KV page subsystem: PagedQuantSpec laws, the accessor ∘ LayoutPaged
composition, the dequantizing kernel vs its jnp twin, and the allocator/CoW
laws over quantized pools (representation-blind: identical to the f32 regime).

Engine-level accuracy/capacity tests (real model) live in
test_serving_engine.py; everything here runs on synthetic pools or a fake
model in milliseconds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Extents, LayoutPaged, QuantizedAccessor
from repro.kernels.paged_attention import (
    dequantize_pages,
    pack_int4_splithalf,
    paged_decode_attention_jnp,
    paged_decode_attention_quant_jnp,
    paged_flash_decode_quant,
    unpack_int4_splithalf,
)
from repro.serving.engine.cache import PagedKVCache
from repro.serving.engine.kvquant import KV_DTYPES, PagedQuantSpec, kv_pool_bytes


# =====================================================================================
# PagedQuantSpec — encode/decode laws
# =====================================================================================
@pytest.mark.parametrize("bits", [8, 4])
def test_encode_decode_roundtrip_within_half_step(bits):
    spec = PagedQuantSpec(bits=bits)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, 2, 4, 8)).astype(np.float32)  # (P, H, ps, D)
    enc = spec.encode_pages(jnp.asarray(x))
    rec = np.array(spec.decode_pages(enc["q"], enc["scale"]))
    step = np.abs(x).max(axis=(-2, -1)) / spec.qmax  # per (page, head)
    assert np.all(np.abs(rec - x) <= step[..., None, None] * 0.5 + 1e-6)
    # scale is per (page, head): shape matches, zero slices get the 1.0 default
    assert enc["scale"].shape == (5, 2)
    z = spec.encode_pages(jnp.zeros((1, 1, 4, 8)))
    assert float(z["scale"][0, 0]) == 1.0
    assert np.all(np.array(spec.decode_pages(z["q"], z["scale"])) == 0.0)


def test_int4_splithalf_pack_unpack_identity():
    rng = np.random.default_rng(1)
    q = rng.integers(-7, 8, size=(3, 5, 16)).astype(np.int8)
    rt = np.array(unpack_int4_splithalf(pack_int4_splithalf(jnp.asarray(q))))
    np.testing.assert_array_equal(rt, q)


def test_int4_requires_even_head_dim():
    with pytest.raises(ValueError, match="even head_dim"):
        PagedQuantSpec(bits=4).packed_dim(7)


def test_quantize_tokens_uses_given_scale_and_clips():
    spec = PagedQuantSpec(bits=8)
    tok = jnp.asarray([[1.0, -2.0, 1000.0]])
    scale = jnp.asarray([2.0 / spec.qmax])
    q = np.array(spec.quantize_tokens(tok, scale))
    assert q[0, 2] == spec.qmax  # out-of-range clips at the existing scale
    # fresh scale from the token itself round-trips its absmax exactly
    s = spec.token_scale(tok)
    q2 = spec.quantize_tokens(tok, s)
    assert float(q2[0, 2]) * float(s[0]) == pytest.approx(1000.0, rel=1e-5)


# =====================================================================================
# the composition law: (page, head) scales == flat QuantizedAccessor blocks
# =====================================================================================
def test_int8_pool_is_flat_quantized_accessor_over_layout_paged():
    """The paper's claim made literal: PagedQuantSpec's int8 pool bytes+scales
    ARE QuantizedAccessor buffers with block = page_size * head_dim over the
    flat LayoutPaged codomain, so accessor.access ∘ layout.offsets reads the
    same values as the page-level decode."""
    P, H, ps, D = 5, 2, 4, 8
    spec = KV_DTYPES["int8"]
    rng = np.random.default_rng(2)
    pool = rng.standard_normal((P, H, ps, D)).astype(np.float32)
    enc = spec.encode_pages(jnp.asarray(pool))
    acc = spec.as_flat_accessor(ps, D)
    bufs = acc.from_codomain(jnp.asarray(pool.reshape(-1)))
    # identical encodings (bytes and block scales)
    np.testing.assert_array_equal(np.array(bufs["q"]), np.array(enc["q"]).reshape(-1))
    np.testing.assert_allclose(
        np.array(bufs["scale"]), np.array(enc["scale"]).reshape(-1), rtol=0
    )
    # identical reads through a scattered block table
    lp = LayoutPaged(Extents.fully_dynamic(2, H, 2 * ps, D), ((3, 1), (4, 0)), ps, P)
    offs = lp.offsets_dense()
    via_accessor = np.array(acc.access(bufs, offs))
    via_pages = np.array(
        jnp.take(spec.decode_pages(enc["q"], enc["scale"]).reshape(-1), offs)
    )
    np.testing.assert_allclose(via_accessor, via_pages, rtol=0, atol=0)


def test_int4_flat_accessor_speaks_the_page_packing():
    """as_flat_accessor covers int4 too (the PR-6 refusal is gone): the
    returned split-half accessor reads back exactly what encode_pages packed,
    element for element — the law that lets CountingAccessor price int4
    pools through the bytes the kernel really touches."""
    spec = KV_DTYPES["int4"]
    ps, hkv, d = 4, 2, 8
    flat = spec.as_flat_accessor(ps, d)
    assert flat.bits == 4 and flat.row == d and flat.block == ps * d
    pool = jnp.asarray(
        np.random.default_rng(0).standard_normal((3, hkv, ps, d)), jnp.float32
    )
    enc = spec.encode_pages(pool)
    bufs = {"q": jnp.asarray(np.asarray(enc["q"]).reshape(-1)),
            "scale": jnp.asarray(np.asarray(enc["scale"]).reshape(-1))}
    dense = np.asarray(spec.decode_pages(enc["q"], enc["scale"])).reshape(-1)
    for o in (0, 1, d // 2, d - 1, d, ps * d, pool.size - 1):
        assert float(flat.access(bufs, o)) == pytest.approx(dense[o], abs=1e-6)


def test_quantized_accessor_rejects_negative_offsets():
    """Regression: a negative offset's nibble parity/block index depends on the
    true span, which packed buffers don't record — access(bufs, -1) on an
    odd-span int4 buffer used to silently read the pad nibble (always 0) and
    store(bufs, -1, v) corrupted it."""
    acc = QuantizedAccessor(jnp.float32, bits=4, block=8)
    bufs = acc.from_codomain(jnp.asarray([1.0, -2.0, 3.0, -1.0, -3.0]))  # odd span
    assert float(acc.access(bufs, 4)) == pytest.approx(-3.0, abs=0.25)
    with pytest.raises(TypeError, match="non-negative"):
        acc.access(bufs, -1)
    with pytest.raises(TypeError, match="non-negative"):
        acc.access(bufs, np.int64(-1))  # numpy scalars index the same paths
    with pytest.raises(TypeError, match="non-negative"):
        acc.store(bufs, -1, 1.0)
    with pytest.raises(TypeError, match="non-negative"):
        QuantizedAccessor(jnp.float32, bits=8, block=4).access(
            {"q": jnp.zeros(6, jnp.int8), "scale": jnp.ones(2)}, -3
        )


# =====================================================================================
# dequantizing kernel vs jnp twin
# =====================================================================================
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("lens", [(5, 20), (1, 16)])
def test_quant_kernel_matches_twin(bits, lens):
    b, hq, hkv, d, ps = len(lens), 4, 2, 16, 8
    mp = -(-max(lens) // ps)
    P = b * mp + 1
    dq = d if bits == 8 else d // 2
    rng = np.random.default_rng(bits * 10 + len(lens))
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    kq = jnp.asarray(rng.integers(-7 if bits == 4 else -127, 8 if bits == 4 else 128,
                                  size=(P, hkv, ps, dq)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, size=(P, hkv, ps, dq)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.2, size=(P, hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.2, size=(P, hkv)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, P)).reshape(b, mp), jnp.int32)
    cl = jnp.asarray(lens, jnp.int32)
    out_kernel = paged_flash_decode_quant(
        q, kq, ks, vq, vs, bt, cl, bits=bits, interpret=True
    )
    out_twin = paged_decode_attention_quant_jnp(q, kq, ks, vq, vs, bt, cl, bits=bits)
    np.testing.assert_allclose(
        np.array(out_kernel), np.array(out_twin), atol=1e-4, rtol=0
    )
    # and the twin IS the f32 path over the dequantized pool (same masks/norms)
    out_f32 = paged_decode_attention_jnp(
        q, dequantize_pages(kq, ks, bits=bits), dequantize_pages(vq, vs, bits=bits),
        bt, cl,
    )
    np.testing.assert_array_equal(np.array(out_twin), np.array(out_f32))


# =====================================================================================
# allocator + layout laws over quantized pools (fake model: L=1, Hkv=2, Dh=4)
# =====================================================================================
@dataclasses.dataclass
class FakeCfg:
    n_kv_heads: int = 2
    head_dim: int = 4


class FakeModel:
    cfg = FakeCfg()

    def init_paged_cache(self, num_pages, page_size, kv_spec=None):
        hkv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
        if kv_spec is None:
            shape = (1, num_pages, hkv, page_size, dh)
            return [{"k": jnp.zeros(shape), "v": jnp.zeros(shape)}]
        dq = kv_spec.packed_dim(dh)
        leaf = lambda: {
            "q": jnp.zeros((1, num_pages, hkv, page_size, dq), jnp.int8),
            "scale": jnp.zeros((1, num_pages, hkv), jnp.float32),
        }
        return [{"k": leaf(), "v": leaf()}]


def make_cache(kv_dtype="f32", num_pages=10, page_size=4, prefix_sharing=True):
    return PagedKVCache(
        FakeModel(), num_pages=num_pages, page_size=page_size, max_batch=4,
        max_pages_per_seq=8, prefix_sharing=prefix_sharing, kv_dtype=kv_dtype,
    )


def _stamp_random(cache, seed=0):
    """Fill the pool leaves with recognizable random content (q bytes, scales)."""
    rng = np.random.default_rng(seed)

    def rand_like(a):
        if a.dtype == jnp.int8:
            return jnp.asarray(rng.integers(-7, 8, size=a.shape), jnp.int8)
        return jnp.asarray(rng.uniform(0.01, 1.0, size=a.shape), a.dtype)

    cache.pools = jax.tree.map(rand_like, cache.pools)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_layout_laws_identical_in_quantized_regime(kv_dtype):
    """fork()/cow_slice()/is_unique() are representation-blind: the same
    allocator history produces identical layout observers on an f32 and a
    quantized cache (ISSUE: 'is_unique() laws must hold identically')."""
    caches = [make_cache("f32"), make_cache(kv_dtype)]
    toks = list(range(10))
    for c in caches:
        c.allocate(0, 3, tokens=toks)
        c.allocate(1, 3, tokens=toks)  # full share
        c.lens[0] = c.lens[1] = 10
    for c in caches:
        assert c.pages_of[1] == c.pages_of[0]
        assert not c.layout_for(0).is_unique()
        assert not c.layout_for(1).is_unique()
    # CoW the quantized slot 1 and the f32 slot 1: same layout transitions
    for c in caches:
        assert c.needs_cow(1)
        assert c.cow_page(1)
    ref, quant = caches
    assert quant.layout_for(1).block_table == ref.layout_for(1).block_table
    assert quant.layout_for(1).shared_pages == ref.layout_for(1).shared_pages
    assert not quant.layout_for(1).is_unique()  # full pages still shared
    # fork/cow_slice algebra on the materialized layout object
    lp = quant.layout_for(0)
    forked = lp.fork(0, fresh_pages=(quant.pages_of[1][2],))
    assert not forked.is_unique()
    for c in caches:
        c.free_slot(0)
    assert quant.layout_for(1).is_unique() == ref.layout_for(1).is_unique() is True


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_cow_copies_quantized_bytes_and_scales_donor_untouched(kv_dtype):
    c = make_cache(kv_dtype)
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    _stamp_random(c)
    donor_pages = list(c.pages_of[0])
    donor = jax.tree.map(lambda a: np.array(a[:, donor_pages]), c.pools[0])
    c.allocate(1, 3, tokens=toks)
    c.lens[1] = 10
    assert c.needs_cow(1)
    assert c.cow_page(1)
    new_page = c.pages_of[1][2]
    assert new_page != c.pages_of[0][2]
    # the private copy carries the donor's q bytes AND its (page, head) scales
    np.testing.assert_array_equal(
        np.array(c.pools[0]["k"]["q"][:, new_page]), donor["k"]["q"][:, 2]
    )
    np.testing.assert_array_equal(
        np.array(c.pools[0]["k"]["scale"][:, new_page]), donor["k"]["scale"][:, 2]
    )
    # scribble over the copy; the donor stays byte-identical (bytes and scales)
    c.pools = [jax.tree.map(lambda a: a.at[:, new_page].set(0), c.pools[0])]
    got = jax.tree.map(lambda a: np.array(a[:, donor_pages]), c.pools[0])
    jax.tree.map(np.testing.assert_array_equal, got, donor)
    assert not c.needs_cow(1)
    assert int(c.ref.min()) >= 0


def test_refcounts_nonnegative_under_shared_quantized_churn():
    """Shared prompts adopted, CoW'd, freed and re-adopted over a quantized
    pool: refcounts never go negative and the pool drains clean."""
    c = make_cache("int8", num_pages=12)
    donor = list(range(10))
    for round_ in range(4):
        c.allocate(0, 3, tokens=donor)
        c.allocate(1, 3, tokens=donor)
        c.allocate(2, 3, tokens=donor)
        assert c.pages_shared_total > 0
        for slot in (1, 2):
            c.lens[slot] = 10
            while c.needs_cow(slot):
                assert c.cow_page(slot)
        assert int(c.ref.min()) >= 0
        for slot in (0, 1, 2):
            c.free_slot(slot)
            c.free_slot(slot)  # idempotent double-free
        assert int(c.ref.min()) >= 0
    assert int(c.ref.max()) == 0
    assert c.num_free == c.num_pages - 1
    assert not c._index


def test_prefix_index_dedupes_quantized_pages_like_f32():
    """The hash chain keys on token ids, never bytes: admission costs match
    exactly across representations (the ROADMAP 'refcount interplay with
    QuantizedAccessor scales' follow-on)."""
    for kv_dtype in ("f32", "int8", "int4"):
        c = make_cache(kv_dtype)
        donor = list(range(10))
        c.allocate(0, c.pages_for(11), tokens=donor)
        assert c.new_pages_needed(donor) == 0
        assert c.new_pages_needed(donor[:8] + [77, 78]) == 1
        assert c.new_pages_needed([77] + donor[1:]) == 3


def test_quantized_pool_bytes_shrink():
    b32 = kv_pool_bytes(make_cache("f32").pools)
    b8 = kv_pool_bytes(make_cache("int8").pools)
    b4 = kv_pool_bytes(make_cache("int4").pools)
    assert b32 / b8 >= 1.9 and b8 > b4
    c = make_cache("int8")
    assert c.stats()["kv_pool_bytes"] == b8


def test_unknown_kv_dtype_rejected():
    with pytest.raises(ValueError, match="kv_dtype"):
        make_cache("fp8")
