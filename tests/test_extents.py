"""Extents unit tests (paper: mixing static and dynamic extents)."""
import pytest

from repro.core import Extents, dynamic_extent


def test_static_dynamic_mix():
    e = Extents.of(20, dynamic_extent)(40)
    assert e.rank == 2 and e.rank_dynamic == 1
    assert e.extent(0) == 20 and e.extent(1) == 40
    assert e.static_extent(0) == 20 and e.static_extent(1) is None
    assert not e.is_fully_static


def test_fully_static_and_dynamic():
    s = Extents.fully_static(3, 4, 5)
    d = Extents.fully_dynamic(3, 4, 5)
    assert s.is_fully_static and not d.is_fully_static
    assert s.as_shape() == d.as_shape() == (3, 4, 5)
    assert s.size() == 60


def test_wrong_dynamic_count():
    with pytest.raises(TypeError):
        Extents.of(20, dynamic_extent)()  # missing
    with pytest.raises(TypeError):
        Extents.of(20, dynamic_extent)(40, 50)  # extra


def test_negative_extent_rejected():
    with pytest.raises(ValueError):
        Extents.fully_static(-1, 2)
    with pytest.raises(ValueError):
        Extents.of(dynamic_extent)(-3)


def test_contains_and_indices():
    e = Extents.fully_static(2, 3)
    assert e.contains((1, 2)) and not e.contains((2, 0)) and not e.contains((0,))
    assert sorted(e.indices()) == [(i, j) for i in range(2) for j in range(3)]


def test_with_extent():
    e = Extents.of(8, dynamic_extent)(16)
    e2 = e.with_extent(1, 32, static=True)
    assert e2.extent(1) == 32 and e2.static_extent(1) == 32
    assert e2.extent(0) == 8
