"""Runtime: trainer loop learns, checkpoints, survives failures (elastic restart),
and the health primitives behave."""
import jax
import numpy as np
import pytest

from repro.runtime import HeartbeatMonitor, RunConfig, StragglerPolicy, TrainerLoop, simulate_failure


def test_heartbeat_monitor():
    clock = [0.0]
    mon = HeartbeatMonitor(num_hosts=3, timeout_s=10, clock=lambda: clock[0])
    clock[0] = 5.0
    mon.beat(0)
    mon.beat(1)
    clock[0] = 12.0
    assert mon.dead_hosts() == [2]
    mon.beat(2)
    assert mon.all_alive()


def test_straggler_policy_escalates():
    p = StragglerPolicy(threshold=2.0, patience=2)
    assert p.observe(1.0) == "ok"
    assert p.observe(1.0) == "ok"
    assert p.observe(5.0) == "straggle"
    assert p.observe(5.0) == "rebalance"
    assert p.observe(1.0) == "ok"  # recovered


def test_trainer_loop_learns_and_checkpoints(tmp_path):
    run = RunConfig(
        arch="llama3.2-1b", smoke=True, steps=12, batch=4, seq=32,
        peak_lr=3e-3, warmup=2, ckpt_dir=str(tmp_path), ckpt_every=5, log_every=50,
    )
    loop = TrainerLoop(run)
    out = loop.run_loop()
    hist = out["history"]
    assert len(hist) == 12
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first, (first, last)  # synthetic data has learnable structure
    assert loop.ckpt.latest() == 12


def test_trainer_loop_resumes_from_checkpoint(tmp_path):
    run = RunConfig(arch="qwen2-0.5b", smoke=True, steps=6, batch=4, seq=16,
                    ckpt_dir=str(tmp_path), ckpt_every=3, log_every=50)
    TrainerLoop(run).run_loop()
    # second run continues (resume=True): starts from committed step 6
    run2 = RunConfig(arch="qwen2-0.5b", smoke=True, steps=8, batch=4, seq=16,
                     ckpt_dir=str(tmp_path), ckpt_every=3, log_every=50)
    loop2 = TrainerLoop(run2)
    out = loop2.run_loop()
    steps_run = [h["step"] for h in out["history"]]
    assert steps_run and steps_run[0] >= 6, steps_run


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >=2 devices for elastic test")
def test_trainer_loop_elastic_restart_on_failure(tmp_path):
    run = RunConfig(arch="llama3.2-1b", smoke=True, steps=10, batch=4, seq=16,
                    ckpt_dir=str(tmp_path), ckpt_every=2, log_every=50)
    fail = simulate_failure(at_step=5)
    loop = TrainerLoop(run, failure_hook=fail.maybe_fail)
    n_devices_before = len(loop.devices)
    out = loop.run_loop()
    assert len(loop.devices) < n_devices_before  # re-meshed smaller
    assert out["final_step"] == 10
    assert any(h["step"] == 9 for h in out["history"])  # finished after restart
