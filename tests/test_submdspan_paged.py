"""submdspan over LayoutPaged — the chunk-view laws (core/submdspan.py §chunk
views are submdspans): pointwise agreement with the parent at partial-page
boundaries, slice composition, shared-page filtering (the compute-skip regime),
and accessor orthogonality over quantized pools.

Hypothesis property tests are guarded with importorskip (CI runs a
no-hypothesis leg); the example-based laws below run everywhere.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Extents, LayoutPaged, MdSpan, all_, submdspan
from repro.core.layouts import LayoutError
from repro.serving.engine.cache import PagedKVCache
from repro.serving.engine.kvquant import KV_DTYPES


def span_over(layout: LayoutPaged) -> MdSpan:
    buf = jnp.arange(layout.required_span_size(), dtype=jnp.float32)
    return MdSpan.over(buf, layout)


def scattered_layout(shared=()):
    # 2 sequences x 3 pages out of a 9-page pool, deliberately out of order
    return LayoutPaged(
        Extents.fully_dynamic(2, 2, 12, 4), ((5, 2, 8), (7, 1, 3)), 4, 9, shared
    )


# =====================================================================================
# pointwise + observer laws
# =====================================================================================
@pytest.mark.parametrize("a,b", [(0, 12), (0, 5), (2, 7), (4, 8), (3, 4), (9, 12)])
def test_chunk_slice_matches_parent_pointwise(a, b):
    """sub(s, h, p, d) == parent(s, h, a + p, d) — including partial-page
    boundaries, where pos_offset carries the in-page start."""
    lp = scattered_layout()
    sub = submdspan(span_over(lp), all_, all_, (a, b), all_).layout
    assert isinstance(sub, LayoutPaged)
    assert sub.extents.extent(2) == b - a
    for s in range(2):
        for h in range(2):
            for p in range(b - a):
                for d in range(4):
                    assert sub(s, h, p, d) == lp(s, h, a + p, d)


def test_chunk_slice_trims_rows_to_covering_pages():
    lp = scattered_layout()
    sub = submdspan(span_over(lp), all_, all_, (5, 7), all_).layout
    # positions [5, 7) live entirely in logical page 1
    assert sub.block_table == ((2,), (1,))
    assert sub.pos_offset == 1
    assert not sub.is_contiguous()


def test_chunk_slice_composition():
    """Slicing a slice == one slice with the composed range (P0009)."""
    lp = scattered_layout()
    outer = submdspan(span_over(lp), all_, all_, (2, 11), all_)
    inner = submdspan(outer, all_, all_, (3, 7), all_).layout
    direct = submdspan(span_over(lp), all_, all_, (5, 9), all_).layout
    assert inner == direct


def test_chunk_slice_values_read_through_shared_buffer():
    """The chunk shares the parent's buffer: values agree elementwise."""
    lp = scattered_layout()
    span = span_over(lp)
    sub = submdspan(span, all_, all_, (3, 9), all_)
    for s in range(2):
        for h in range(2):
            for p in range(6):
                for d in range(4):
                    assert float(sub(s, h, p, d)) == float(span(s, h, 3 + p, d))


def test_seq_range_slice_and_rejections():
    lp = scattered_layout()
    sub = submdspan(span_over(lp), (1, 2), all_, (0, 12), all_).layout
    assert sub.block_table == ((7, 1, 3),)
    with pytest.raises(LayoutError):
        submdspan(span_over(lp), 0, all_, (0, 4), all_)  # int drops the rank
    with pytest.raises(LayoutError):
        submdspan(span_over(lp), all_, (0, 1), (0, 4), all_)  # head slice
    with pytest.raises(LayoutError):
        submdspan(span_over(lp), all_, all_, (0, 4), (0, 2))  # d slice


# =====================================================================================
# aliasing: the compute-skip regime
# =====================================================================================
def test_chunk_past_shared_prefix_is_unique():
    """shared_pages filters to the pages the chunk references: a chunk lying
    past a shared prefix is unique even when the parent is not — the formal
    shape of the shared-prefix compute skip."""
    lp = scattered_layout(shared=(5, 2))  # first two pages of row 0 shared
    assert not lp.is_unique()
    head = submdspan(span_over(lp), all_, all_, (0, 8), all_).layout
    assert not head.is_unique()
    assert head.shared_pages == (2, 5)
    tail = submdspan(span_over(lp), all_, all_, (8, 12), all_).layout
    assert tail.is_unique()
    assert tail.shared_pages == ()


def test_chunk_boundary_straddling_shared_page_stays_aliased():
    lp = scattered_layout(shared=(2,))  # row 0's middle page
    mid = submdspan(span_over(lp), all_, all_, (7, 9), all_).layout
    assert not mid.is_unique()  # position 7 still lives in shared page 2
    assert mid.shared_pages == (2,)


# =====================================================================================
# the engine's chunk views (PagedKVCache.chunk_view) + accessor orthogonality
# =====================================================================================
@dataclasses.dataclass
class FakeCfg:
    n_kv_heads: int = 2
    head_dim: int = 4


class FakeModel:
    cfg = FakeCfg()

    def init_paged_cache(self, num_pages, page_size, kv_spec=None):
        hkv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
        if kv_spec is not None:
            dq = kv_spec.packed_dim(dh)
            return [{
                k: {"q": jnp.zeros((1, num_pages, hkv, page_size, dq), jnp.int8),
                    "scale": jnp.zeros((1, num_pages, hkv), jnp.float32)}
                for k in ("k", "v")
            }]
        shape = (1, num_pages, hkv, page_size, dh)
        return [{"k": jnp.zeros(shape), "v": jnp.zeros(shape)}]


def make_cache(kv_dtype="f32"):
    return PagedKVCache(
        FakeModel(), num_pages=10, page_size=4, max_batch=2, max_pages_per_seq=6,
        kv_dtype=kv_dtype,
    )


@pytest.mark.parametrize("kv_dtype", ["f32", "int8", "int4"])
def test_cache_chunk_view_is_submdspan_of_dense_view(kv_dtype):
    """Reading a chunk through chunk_view's sliced offsets equals slicing the
    full dense view — for quantized pools the buffer is the DECODED codomain,
    so the slice transforms only the layout (accessor orthogonality)."""
    c = make_cache(kv_dtype)
    c.allocate(0, 3, tokens=list(range(10)))
    c.lens[0] = 10
    rng = np.random.default_rng(0)
    spec = KV_DTYPES[kv_dtype]
    if spec is None:
        c.pools = [{
            k: jnp.asarray(rng.standard_normal(c.pools[0][k].shape), jnp.float32)
            for k in ("k", "v")
        }]
    else:
        vals = rng.standard_normal((1, c.num_pages, 2, c.page_size, 4))
        c.pools = [{k: spec.encode_pages(jnp.asarray(vals, jnp.float32))
                    for k in ("k", "v")}]
    k_full, _ = c.dense_view(0)
    for start, stop in [(0, 4), (4, 10), (3, 7), (9, 10)]:
        chunk = c.chunk_view(0, start, stop)
        assert isinstance(chunk.layout, LayoutPaged)
        got = chunk.to_dense()[0]  # (Hkv, stop-start, Dh)
        np.testing.assert_allclose(
            np.array(got), np.array(k_full[:, start:stop]), rtol=1e-6, atol=1e-6
        )


def test_cache_chunk_view_uniqueness_tracks_adoption():
    """A chunk past the adopted prefix is unique — exactly the pages the
    chunked engine is allowed to write (the compute-skip write mask)."""
    c = make_cache()
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    c.allocate(1, 3, tokens=toks)  # adopts all three pages
    assert not c.chunk_view(1, 0, 8).layout.is_unique()
    c.lens[1] = 10
    assert c.cow_page(1)  # privatize the partial page
    assert c.chunk_view(1, 8, 10).layout.is_unique()
    assert not c.chunk_view(1, 0, 8).layout.is_unique()


def test_write_table_row_masks_adopted_prefix():
    c = make_cache()
    toks = list(range(10))
    c.allocate(0, 3, tokens=toks)
    c.allocate(1, 3, tokens=toks)
    assert c.adopted_pages(1) == 3
    row = c.write_table_row(1)
    assert list(row[:3]) == [0, 0, 0]  # all adopted pages nulled
    fresh = c.write_table_row(0)
    assert list(fresh[:3]) == c.pages_of[0]  # the donor owns its pages


# =====================================================================================
# hypothesis properties (conditionally defined: the example-based laws above
# must still run on the no-hypothesis CI leg)
# =====================================================================================
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis CI leg
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        n_pages_per_seq=st.integers(1, 4),
        page_size=st.integers(1, 5),
        data=st.data(),
    )
    def test_chunk_slice_pointwise_property(n_pages_per_seq, page_size, data):
        """For random pools/tables and random (a, b) pos ranges — page-aligned
        or not — the sliced layout agrees with the parent pointwise and its
        offsets stay injective on the chunk domain."""
        num_pages = 2 * n_pages_per_seq + 1
        pages = data.draw(st.permutations(list(range(1, num_pages))))
        table = (
            tuple(pages[:n_pages_per_seq]),
            tuple(pages[n_pages_per_seq : 2 * n_pages_per_seq]),
        )
        max_pos = n_pages_per_seq * page_size
        lp = LayoutPaged(
            Extents.fully_dynamic(2, 2, max_pos, 3), table, page_size, num_pages
        )
        a = data.draw(st.integers(0, max_pos - 1))
        b = data.draw(st.integers(a + 1, max_pos))
        sub = submdspan(span_over(lp), all_, all_, (a, b), all_).layout
        offs = []
        for s in range(2):
            for h in range(2):
                for p in range(b - a):
                    for d in range(3):
                        o = sub(s, h, p, d)
                        assert o == lp(s, h, a + p, d)
                        offs.append(o)
        assert len(set(offs)) == len(offs)  # injective on the chunk domain

    @settings(max_examples=40, deadline=None)
    @given(
        page_size=st.integers(1, 4),
        n_pages=st.integers(2, 5),
        data=st.data(),
    )
    def test_chunk_slice_shared_filter_property(page_size, n_pages, data):
        """is_unique() of a chunk is False iff the chunk's positions touch a
        shared page — for arbitrary shared sets and ranges."""
        table = (tuple(range(1, n_pages + 1)),)
        max_pos = n_pages * page_size
        shared = tuple(
            data.draw(st.sets(st.integers(1, n_pages), max_size=n_pages))
        )
        lp = LayoutPaged(
            Extents.fully_dynamic(1, 1, max_pos, 2), table, page_size,
            n_pages + 1, shared,
        )
        a = data.draw(st.integers(0, max_pos - 1))
        b = data.draw(st.integers(a + 1, max_pos))
        sub = submdspan(span_over(lp), all_, all_, (a, b), all_).layout
        touched = {table[0][p // page_size] for p in range(a, b)}
        assert sub.is_unique() == (not (touched & set(shared)))
        assert set(sub.shared_pages) == (touched & set(shared))
