"""MdSpan + submdspan behaviour, including the paper's own code examples."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Extents,
    LayoutLeft,
    LayoutRight,
    LayoutSymmetricPacked,
    LayoutTiledTPU,
    MdSpan,
    QuantizedAccessor,
    all_,
    mdspan,
    submdspan,
)


def test_paper_example_matrix_interpretation():
    """'interpret memory starting at data as a 20 x 40 matrix'."""
    data = jnp.arange(20 * 40, dtype=jnp.float32)
    m = mdspan(data, 20, 40)
    assert m.extent(0) == 20 and m.extent(1) == 40
    assert float(m(10, 5)) == 10 * 40 + 5
    # operator() compound assignment restated functionally
    m2 = m.set((10, 5), m(10, 5) + 3.14)
    assert abs(float(m2(10, 5)) - (10 * 40 + 5 + 3.14)) < 1e-4
    assert float(m2(0, 38)) == 38.0


def test_paper_example_subspan():
    """paper: subspan(my_tens, 2, all, pair{2,4}, 0) of a 3x4x5x20 tensor."""
    t = mdspan(jnp.arange(3 * 4 * 5 * 20, dtype=jnp.float32), 3, 4, 5, 20)
    sub = submdspan(t, 2, all_, (2, 4), 0)
    assert sub.shape == (4, 2)
    for i in range(4):
        for j in range(2):
            assert float(sub(i, j)) == float(t(2, i, j + 2, 0))


def test_subspan_static_extent_propagation():
    """all -> static extent preserved; pair -> dynamic (P0009)."""
    t = MdSpan.from_dense(jnp.zeros((4, 6)), static=True)
    sub = submdspan(t, all_, (1, 4))
    assert sub.extents.static_extent(0) == 4
    assert sub.extents.static_extent(1) is None


def test_subspan_shares_buffers_zero_copy():
    t = mdspan(jnp.arange(24, dtype=jnp.float32), 4, 6)
    sub = submdspan(t, (1, 3), all_)
    assert sub.buffers is t.buffers  # same array object: a view, not a copy


def test_subspan_of_subspan_composes():
    t = mdspan(jnp.arange(3 * 4 * 5, dtype=jnp.float32), 3, 4, 5)
    s1 = submdspan(t, 1, all_, all_)
    s2 = submdspan(s1, (1, 3), 2)
    assert s2.shape == (2,)
    for i in range(2):
        assert float(s2(i,)) == float(t(1, i + 1, 2))


def test_out_of_bounds_slices_rejected():
    t = mdspan(jnp.zeros(12), 3, 4)
    with pytest.raises(IndexError):
        submdspan(t, (0, 5), all_)
    with pytest.raises(IndexError):
        submdspan(t, 3, all_)


def test_from_dense_roundtrip_layouts():
    x = jnp.arange(30, dtype=jnp.float32).reshape(5, 6)
    for layout in [
        LayoutRight(Extents.fully_dynamic(5, 6)),
        LayoutLeft(Extents.fully_dynamic(5, 6)),
        LayoutTiledTPU(Extents.fully_dynamic(5, 6), tile=(2, 4)),
    ]:
        m = MdSpan.from_dense(x, layout=layout)
        np.testing.assert_array_equal(np.array(m.to_dense()), np.array(x))


def test_symmetric_from_dense_uses_one_triangle():
    x = jnp.array([[1.0, 2.0], [2.0, 5.0]])
    m = MdSpan.from_dense(x, layout=LayoutSymmetricPacked(Extents.fully_dynamic(2, 2)))
    assert m.buffers.shape == (3,)  # packed triangle
    np.testing.assert_array_equal(np.array(m.to_dense()), np.array(x))


def test_mdspan_is_pytree_through_jit_grad():
    m = MdSpan.from_dense(jnp.arange(8.0).reshape(2, 4))

    @jax.jit
    def f(span):
        return jnp.sum(span.to_dense() ** 2)

    g = jax.grad(lambda s: f(s))(m)
    assert isinstance(g, MdSpan)
    np.testing.assert_allclose(np.array(g.buffers), 2 * np.arange(8.0))


def test_quantized_mdspan_view():
    qa = QuantizedAccessor(jnp.float32, bits=8, block=8)
    x = jnp.linspace(-2, 2, 32).reshape(4, 8)
    m = MdSpan.from_dense(x, accessor=qa)
    assert np.max(np.abs(np.array(m.to_dense()) - np.array(x))) < 2 / 127 + 1e-6


def test_scatter_from_dense_gated_on_non_unique():
    from repro.core import LayoutError

    sym = LayoutSymmetricPacked(Extents.fully_dynamic(3, 3))
    m = MdSpan.from_dense(jnp.eye(3), layout=sym)
    with pytest.raises(LayoutError):
        m.scatter_from_dense(jnp.ones((3, 3)))
