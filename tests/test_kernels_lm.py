"""LM kernels (flash attention, flash VJP, quant matmul, SSD) — sweeps vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import dequantize_array, quantize_array
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.kernels.flash_vjp import flash_attention_jnp
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
def test_flash_kernel_sweep(hq, hkv, causal, window):
    k0 = jax.random.key(0)
    q = jax.random.normal(k0, (2, hq, 64, 32))
    k = jax.random.normal(jax.random.key(1), (2, hkv, 64, 32))
    v = jax.random.normal(jax.random.key(2), (2, hkv, 64, 32))
    got = flash_attention(q, k, v, causal=causal, window=window, block_q=16, block_k=16)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    q = jax.random.normal(jax.random.key(0), (1, 2, 32, 16), dtype)
    k = jax.random.normal(jax.random.key(1), (1, 2, 48, 16), dtype)
    v = jax.random.normal(jax.random.key(2), (1, 2, 48, 16), dtype)
    got = flash_attention(q, k, v, causal=False, block_q=8, block_k=16)
    want = ref.attention(q, k, v, causal=False)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(
        np.array(got, np.float32), np.array(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("pos", [0, 31, 57, 127])
def test_flash_decode_positions(pos):
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    kc = jax.random.normal(jax.random.key(3), (B, Hkv, S, D))
    vc = jax.random.normal(jax.random.key(4), (B, Hkv, S, D))
    q1 = jax.random.normal(jax.random.key(5), (B, Hq, 1, D))
    got = jax.jit(lambda q, k, v, p: flash_decode(q, k, v, p, block_k=32))(q1, kc, vc, pos)
    want = ref.attention(q1, kc, vc, causal=True, q_offset=pos)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-5)


def test_flash_vjp_grads_match_reference():
    q = jax.random.normal(jax.random.key(0), (2, 4, 37, 16))
    k = jax.random.normal(jax.random.key(1), (2, 2, 53, 16))
    v = jax.random.normal(jax.random.key(2), (2, 2, 53, 16))
    for kwargs in [dict(causal=True, window=None), dict(causal=True, window=24), dict(causal=False, window=None)]:
        f1 = lambda q, k, v: (
            flash_attention_jnp(q, k, v, jnp.int32(0), kwargs["causal"], kwargs["window"], None, 16) ** 2
        ).sum()
        f2 = lambda q, k, v: (ref.attention(q, k, v, **kwargs).astype(jnp.float32) ** 2).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=3e-3, atol=3e-4)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("mkn", [(16, 256, 384), (8, 128, 128), (33, 512, 256)])
def test_quant_matmul_sweep(bits, mkn):
    m, k, n = mkn
    x = jax.random.normal(jax.random.key(0), (m, k))
    w = jax.random.normal(jax.random.key(1), (k, n))
    qa = QuantizedAccessor(jnp.float32, bits=bits, block=64)
    bufs = quantize_array(w.T, qa)  # (N, K) output-major
    got = quant_matmul(x, bufs["q"], bufs["scale"], bits=bits, block_m=8, block_n=128)
    want = ref.quant_matmul(x, bufs["q"], bufs["scale"], bits=bits)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-3, atol=2e-3)
    # and the dequantized oracle agrees with dense math within quant error
    wd = dequantize_array(bufs, qa).T
    np.testing.assert_allclose(np.array(want), np.array(x @ wd), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("shape", [(2, 128, 4, 16, 32), (1, 64, 8, 8, 16)])
def test_ssd_scan_sweep(chunk, shape):
    b, t, h, p, n = shape
    ks = jax.random.split(jax.random.key(7), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, 1, n)) * 0.3
    C = jax.random.normal(ks[4], (b, t, 1, n)) * 0.3
    got, gs = ssd_scan(x, dt, A, B, C, chunk=chunk, return_final_state=True)
    want, ws = ref.ssd_scan(x, dt, A, B, C, return_final_state=True)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.array(gs), np.array(ws), rtol=2e-3, atol=2e-3)


def test_ssd_jnp_groups_and_grad():
    b, t, h, p, n = 2, 64, 4, 8, 16
    ks = jax.random.split(jax.random.key(8), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, 2, n)) * 0.3
    C = jax.random.normal(ks[4], (b, t, 2, n)) * 0.3
    np.testing.assert_allclose(
        np.array(ops.ssd_jnp(x, dt, A, B, C, chunk=16)),
        np.array(ref.ssd_scan(x, dt, A, B, C)),
        rtol=2e-3, atol=2e-3,
    )
    g = jax.grad(lambda x: ops.ssd_jnp(x, dt, A, B, C, chunk=16).sum())(x)
    assert np.isfinite(np.array(g)).all()


def test_ssd_state_chaining_matches_full_run():
    """chunked-with-carried-state == one long run (the SP/prefill invariant)."""
    b, t, h, p, n = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, 1, n)) * 0.3
    C = jax.random.normal(ks[4], (b, t, 1, n)) * 0.3
    y_full = ref.ssd_scan(x, dt, A, B, C)
    half = t // 2
    y1, s1 = ssd_scan(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half], chunk=16, return_final_state=True)
    y2 = ssd_scan(x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:], chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.array(jnp.concatenate([y1, y2], 1)), np.array(y_full), rtol=2e-3, atol=2e-3)


def test_rglru_associative_scan_equals_sequential():
    ks = jax.random.split(jax.random.key(10), 4)
    x = jax.random.normal(ks[0], (2, 32, 8))
    ig = jax.random.normal(ks[1], (2, 32, 8))
    ag = jax.random.normal(ks[2], (2, 32, 8))
    ap = jax.random.normal(ks[3], (8,))
    y_seq = ref.rglru(x, ig, ag, ap)
    # models/rglru.py uses associative_scan; compare through the block-level fn
    import repro.models.rglru as rg

    log_a = rg._log_a({"a_param": ap}, ag)
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(ig.astype(jnp.float32)) * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * gated
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    np.testing.assert_allclose(np.array(h.astype(x.dtype)), np.array(y_seq), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("chunk", [8, 16])
@pytest.mark.parametrize("shape", [(2, 32, 16), (1, 64, 128)])
def test_rglru_pallas_kernel(chunk, shape):
    """Pallas RG-LRU recurrence kernel vs the sequential oracle."""
    from repro.kernels.rglru_scan import rglru_scan

    b_, t, w = shape
    ks = jax.random.split(jax.random.key(11), 4)
    x = jax.random.normal(ks[0], (b_, t, w))
    ig = jax.random.normal(ks[1], (b_, t, w))
    ag = jax.random.normal(ks[2], (b_, t, w))
    ap = jax.random.normal(ks[3], (w,))
    want = ref.rglru(x, ig, ag, ap)
    # precompute decay/input terms exactly as models/rglru.py does
    a = jnp.exp(
        -8.0 * jax.nn.softplus(ap)[None, None, :] * jax.nn.sigmoid(ag)
    )
    bterm = jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (jax.nn.sigmoid(ig) * x)
    got, hf = rglru_scan(a, bterm, chunk=chunk, return_final_state=True)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-5)
    # state chaining: two halves == one run
    half = t // 2
    y1, h1 = rglru_scan(a[:, :half], bterm[:, :half], chunk=chunk, return_final_state=True)
    y2 = rglru_scan(a[:, half:], bterm[:, half:], chunk=chunk, initial_state=h1)
    np.testing.assert_allclose(
        np.array(jnp.concatenate([y1, y2], 1)), np.array(want), rtol=2e-4, atol=2e-5
    )
