"""Optimizer tests: AdamW correctness + int8 (QuantizedAccessor) moment state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import TensorSpec, tree_initialize
from repro.optim import AdamWConfig, adamw_init_specs, adamw_update, warmup_cosine


def quadratic_specs():
    return {"w": TensorSpec((8, 64), (None, None), dtype=jnp.float32, init="normal")}


def run_opt(opt_cfg, steps=60):
    specs = quadratic_specs()
    state_specs = adamw_init_specs(specs, opt_cfg)
    params = tree_initialize(specs, jax.random.key(0))
    state = tree_initialize(state_specs, jax.random.key(1))
    target = jax.random.normal(jax.random.key(2), (8, 64))

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    losses = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, specs, state_specs, opt_cfg)
        losses.append(float(loss(params)))
    return losses


def test_adamw_converges_fp32():
    losses = run_opt(AdamWConfig(lr=0.05, weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adamw_converges_int8_state():
    """8-bit moments (the accessor use case) still optimize the quadratic."""
    losses = run_opt(AdamWConfig(lr=0.05, weight_decay=0.0, int8_state=True, state_block=64))
    assert losses[-1] < 0.1 * losses[0]


def test_int8_state_specs_are_quantized_and_sharded_like_params():
    specs = {"w": TensorSpec((4, 128), ("heads", "embed"), dtype=jnp.bfloat16)}
    st = adamw_init_specs(specs, AdamWConfig(int8_state=True, state_block=64))
    m = st["m"]["w"]
    assert m.is_quantized()
    assert m.logical_axes == ("heads", "embed")  # sharding inherited
    # tiny tensors stay fp32
    tiny = {"b": TensorSpec((7,), (None,), dtype=jnp.float32)}
    st2 = adamw_init_specs(tiny, AdamWConfig(int8_state=True, state_block=64))
    assert not st2["m"]["b"].is_quantized()


def test_grad_clip_and_metrics():
    specs = quadratic_specs()
    st_specs = adamw_init_specs(specs, AdamWConfig())
    params = tree_initialize(specs, jax.random.key(0))
    state = tree_initialize(st_specs, jax.random.key(1))
    huge = {"w": jnp.full((8, 64), 1e6)}
    opt = AdamWConfig(lr=0.1, grad_clip=1.0)
    p2, s2, m = adamw_update(params, huge, state, specs, st_specs, opt)
    assert float(m["grad_norm"]) > 1e6
    delta = np.abs(np.array(p2["w"]) - np.array(params["w"]))
    assert delta.max() < 0.2 + 0.1 * np.abs(np.array(params["w"])).max()


def test_warmup_cosine_schedule():
    f = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 0.11
    assert float(f(jnp.int32(100))) < 0.01
    assert float(f(jnp.int32(5))) == pytest.approx(0.5, abs=0.01)


def test_no_weight_decay_on_1d_params():
    specs = {
        "w": TensorSpec((8, 8), (None, None), dtype=jnp.float32, init="ones"),
        "scale": TensorSpec((8,), (None,), dtype=jnp.float32, init="ones"),
    }
    st_specs = adamw_init_specs(specs, AdamWConfig())
    params = tree_initialize(specs, jax.random.key(0))
    state = tree_initialize(st_specs, jax.random.key(1))
    zero_g = jax.tree.map(jnp.zeros_like, params)
    opt = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=None)
    p2, _, _ = adamw_update(params, zero_g, state, specs, st_specs, opt)
    assert np.all(np.array(p2["w"]) < 1.0)  # decayed
    np.testing.assert_array_equal(np.array(p2["scale"]), 1.0)  # not decayed
