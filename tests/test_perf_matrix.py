"""Perf-matrix laws that hold without running the bench: grid pairing, byte
accounting, the ratchet gate's three verdicts, the roofline arithmetic, and
the once-per-host bandwidth calibration cache.

The expensive halves (engine timing, the autotune comparison) are exercised
by the bench itself — ``python -m benchmarks.run --only perf-matrix --smoke``
in CI. What lives here is everything whose correctness is a pure function of
its inputs, so a regression fails in seconds, not after a five-minute sweep.
"""
import json

import pytest

from benchmarks import perf_matrix, roofline
from benchmarks.serving_suite import bench_config


def _cell(key="ps8_ck32_f32_b2_k1", step_ms=1.0, attainment=0.5, **over):
    c = {
        "key": key, "page_size": 8, "chunk_tokens": 32, "kv_dtype": "f32",
        "max_batch": 2, "multi_step": 1, "step_ms_p50": step_ms,
        "step_ms_p95": step_ms * 1.5, "tokens_per_s": 1000.0,
        "decode_steps": 64, "measured_bytes_per_step": 4096,
        "analytic_bytes_per_step": 4096, "measured_vs_analytic_rel": 0.0,
        "achieved_gb_s": 0.004, "attainment": attainment,
        "attainment_floor": 5e-4, "below_floor": False,
    }
    c.update(over)
    return c


# =====================================================================================
# grid: smoke cells must pair against full-run baselines
# =====================================================================================
def test_smoke_grid_is_exact_subset_of_full():
    full = {perf_matrix.cell_key(*combo) for combo in perf_matrix.grid(False)}
    smoke = {perf_matrix.cell_key(*combo) for combo in perf_matrix.grid(True)}
    assert len(full) == 56 and len(smoke) == 12
    assert smoke < full  # strict subset: every smoke cell has a committed twin


def test_host_tier_cells_differ_only_by_suffix():
    # hk=0 keys keep their earlier spelling (committed baselines pair
    # unchanged); each hk cell's key is exactly its hk=0 sibling + "_hk", so
    # the pair prices the preempt-demote / readmit-promote machinery
    for combos in (perf_matrix.grid(False), perf_matrix.grid(True)):
        keys = {perf_matrix.cell_key(*c) for c in combos}
        hk = [c for c in combos if c[6]]
        assert hk  # both grids carry host-tier cells
        for c in hk:
            key = perf_matrix.cell_key(*c)
            assert key.endswith("_hk")
            assert key[: -len("_hk")] in keys  # hk=0 sibling exists
        for c in combos:
            if not c[6]:
                assert not perf_matrix.cell_key(*c).endswith("_hk")


def test_speculative_cells_differ_only_by_suffix():
    # sp=0 keys keep their pre-speculation spelling (committed baselines pair
    # unchanged); each spec cell's key is exactly its sp=0 sibling + "_sp{n}",
    # so the pair isolates the verify-window machinery
    for combos in (perf_matrix.grid(False), perf_matrix.grid(True)):
        keys = {perf_matrix.cell_key(*c) for c in combos}
        spec = [c for c in combos if c[5]]
        assert spec  # both grids carry speculative cells
        for c in spec:
            key = perf_matrix.cell_key(*c)
            assert key.endswith(f"_sp{c[5]}")
            assert key.rsplit("_sp", 1)[0] in keys  # sp=0 sibling exists
        for c in combos:
            if not c[5]:
                assert "_sp" not in perf_matrix.cell_key(*c)


def test_committed_baseline_covers_the_full_grid():
    report = json.loads(perf_matrix.OUT_PATH.read_text())
    assert report["schema_version"] == perf_matrix.SCHEMA_VERSION
    keys = {c["key"] for c in report["cells"]}
    assert keys == {
        perf_matrix.cell_key(*combo) for combo in perf_matrix.grid(False)
    }
    required = {
        "step_ms_p50", "step_ms_p95", "tokens_per_s",
        "measured_bytes_per_step", "analytic_bytes_per_step", "attainment",
    }
    for c in report["cells"]:
        assert required <= set(c), c["key"]
        assert 0.0 < c["attainment"] <= 1.0, c["key"]


# =====================================================================================
# ratchet gate: the three verdicts
# =====================================================================================
def test_check_cells_regression_fails_and_improvement_passes():
    baseline = {"cells": [_cell(step_ms=1.0)]}
    ok = perf_matrix.check_cells({"cells": [_cell(step_ms=1.19)]}, baseline)
    assert ok == []
    ok = perf_matrix.check_cells({"cells": [_cell(step_ms=0.2)]}, baseline)
    assert ok == []  # faster never trips the ratchet
    # one histogram bucket of quantization slack on top of REGRESSION_X: a
    # 1.25x reading could be a bucket-low baseline vs a bucket-high current
    ok = perf_matrix.check_cells({"cells": [_cell(step_ms=1.25)]}, baseline)
    assert ok == []
    bad = perf_matrix.check_cells({"cells": [_cell(step_ms=1.5)]}, baseline)
    assert len(bad) == 1 and "1.50x" in bad[0]


def test_check_cells_uniform_drift_cancels_targeted_regression_fails():
    # four paired cells: a uniform 1.5x slowdown of everything is host
    # condition (median-normalized away); the same 1.5x on ONE cell while its
    # peers hold steady is a code regression and fails
    keys = [f"ps8_ck32_f32_b2_k{k}" for k in (1, 2, 3, 4)]
    baseline = {"cells": [_cell(key=k, step_ms=1.0) for k in keys]}
    uniform = {"cells": [_cell(key=k, step_ms=1.5) for k in keys]}
    assert perf_matrix.check_cells(uniform, baseline) == []
    targeted = {"cells": [
        _cell(key=keys[0], step_ms=1.5),
        *[_cell(key=k, step_ms=1.0) for k in keys[1:]],
    ]}
    bad = perf_matrix.check_cells(targeted, baseline)
    assert len(bad) == 1 and keys[0] in bad[0]


def test_check_cells_roofline_violation_always_fails():
    # attainment > 1.0 is a measurement bug by definition: fails even with no
    # baseline to compare against, and even when latency looks fine
    bad = perf_matrix.check_cells(
        {"cells": [_cell(attainment=1.2)]}, baseline=None,
    )
    assert len(bad) == 1 and "1.0" in bad[0]


def test_check_cells_unpaired_key_is_skipped():
    baseline = {"cells": [_cell(key="ps8_ck32_f32_b2_k1", step_ms=1.0)]}
    report = {"cells": [_cell(key="ps16_ck64_int4_b4_k4", step_ms=99.0)]}
    assert perf_matrix.check_cells(report, baseline) == []


# =====================================================================================
# measured vs analytic bytes: the 10% law for every KV representation
# =====================================================================================
@pytest.mark.parametrize("kv_dtype", ["f32", "int8", "int4"])
def test_measured_step_bytes_matches_analytic(kv_dtype):
    cfg = bench_config(smoke=True)
    out = perf_matrix.measured_step_bytes(
        cfg, page_size=8, kv_dtype=kv_dtype, batch=2, context_len=32,
    )
    assert out["measured_bytes_per_step"] > 0
    assert out["measured_vs_analytic_rel"] <= 0.10


def test_quantized_cells_move_fewer_bytes():
    cfg = bench_config(smoke=True)
    bytes_of = {
        kv: perf_matrix.measured_step_bytes(
            cfg, page_size=8, kv_dtype=kv, batch=2, context_len=32,
        )["measured_bytes_per_step"]
        for kv in ("f32", "int8", "int4")
    }
    assert bytes_of["f32"] > bytes_of["int8"] > bytes_of["int4"]


# =====================================================================================
# rendering
# =====================================================================================
def test_render_markdown_smoke():
    report = {
        "cells": [_cell(), _cell(key="ps8_ck32_int4_b2_k1", kv_dtype="int4",
                                 below_floor=True)],
        "machine_bandwidth_gb_s": 10.0,
        "autotune": {
            "selected": {"tuned_page_size": 16, "tuned_block_pages": 1,
                         "tuned_chunk_tokens": 32, "tuned_source": "cached"},
            "tokens_per_s_autotuned": 900.0, "tokens_per_s_default": 850.0,
            "no_slower_than_default": True,
        },
    }
    md = perf_matrix.render_markdown(report)
    assert "ps8_ck32_f32_b2_k1" in md
    assert "below-floor" in md
    assert "page_size=16" in md and "no_slower=True" in md


# =====================================================================================
# roofline arithmetic + the per-host calibration cache
# =====================================================================================
def test_attainment_arithmetic():
    # 100 bytes in 1s against a 100 B/s roof is exactly the roof
    assert roofline.attainment(100, 1.0, 100.0) == pytest.approx(1.0)
    assert roofline.attainment(50, 1.0, 100.0) == pytest.approx(0.5)
    # degenerate inputs answer 0.0 instead of raising mid-bench
    assert roofline.attainment(0, 1.0, 100.0) == 0.0
    assert roofline.attainment(100, 0.0, 100.0) == 0.0
    assert roofline.attainment(100, 1.0, 0.0) == 0.0


def test_machine_bandwidth_measured_once_then_cached(tmp_path, monkeypatch):
    path = tmp_path / "bw.json"
    calls = []
    monkeypatch.setattr(
        roofline, "_stream_gbs", lambda: calls.append(1) or 7.5e9
    )
    bw = roofline.measure_machine_bandwidth(cache_path=path)
    assert bw == 7.5e9 and len(calls) == 1 and path.exists()
    # warm: a pure file read — the STREAM kernel must not run again
    bw2 = roofline.measure_machine_bandwidth(cache_path=path)
    assert bw2 == 7.5e9 and len(calls) == 1
    # refresh forces recalibration and rewrites the cache
    monkeypatch.setattr(
        roofline, "_stream_gbs", lambda: calls.append(1) or 9.0e9
    )
    bw3 = roofline.measure_machine_bandwidth(cache_path=path, refresh=True)
    assert bw3 == 9.0e9 and len(calls) == 2
    assert roofline.measure_machine_bandwidth(cache_path=path) == 9.0e9
