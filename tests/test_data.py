"""Data pipeline: determinism, host-sharding disjointness, binary loader, prefetch."""
import numpy as np

from repro.data import BinaryTokenDataset, DataConfig, SyntheticLM, make_pipeline


def test_synthetic_deterministic_in_step_and_seed():
    cfg = DataConfig(batch=4, seq=32, vocab=128, seed=7)
    a = SyntheticLM(cfg).batch_at(3)["tokens"]
    b = SyntheticLM(cfg).batch_at(3)["tokens"]
    c = SyntheticLM(cfg).batch_at(4)["tokens"]
    d = SyntheticLM(DataConfig(batch=4, seq=32, vocab=128, seed=8)).batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert not np.array_equal(a, d)


def test_synthetic_hosts_draw_disjoint_streams():
    cfg = DataConfig(batch=8, seq=16, vocab=1000, seed=0)
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).batch_at(0)["tokens"]
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).batch_at(0)["tokens"]
    assert h0.shape == (4, 17) and h1.shape == (4, 17)
    assert not np.array_equal(h0, h1)


def test_synthetic_has_learnable_structure():
    cfg = DataConfig(batch=8, seq=256, vocab=512, seed=0)
    t = SyntheticLM(cfg).batch_at(0)["tokens"]
    match = (t[:, 3:] == t[:, :-3]).mean()
    assert match > 0.4  # the copy-grammar injects ~50% shift-3 repeats


def test_binary_dataset(tmp_path):
    tokens = np.arange(10_000, dtype=np.uint16) % 512
    path = tmp_path / "tokens.bin"
    tokens.tofile(path)
    cfg = DataConfig(batch=4, seq=64, vocab=512, source="binary", path=str(path))
    ds = BinaryTokenDataset(cfg)
    b = ds.batch_at(0)["tokens"]
    assert b.shape == (4, 65) and b.dtype == np.int32
    assert b.max() < 512
    np.testing.assert_array_equal(b, ds.batch_at(0)["tokens"])  # deterministic


def test_prefetcher_yields_in_order():
    cfg = DataConfig(batch=2, seq=8, vocab=64, seed=1)
    pipe = make_pipeline(cfg, start_step=5, prefetch=True)
    steps = [next(pipe)[0] for _ in range(4)]
    assert steps == [5, 6, 7, 8]
    pipe.close()
