"""End-to-end system tests: training reduces loss across architectures; the
zero-overhead claim holds structurally (HLO identity); serving generates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.models import build_model, get_config
from repro.optim import AdamWConfig
from repro.train import make_train_step
from repro.core.distributed import tree_initialize


def run_short_training(arch, steps=15, batch=4, seq=32, lr=3e-3):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    step_fn, pspecs, sspecs = make_train_step(model, AdamWConfig(lr=lr))
    params = tree_initialize(pspecs, jax.random.key(0))
    opt = tree_initialize(sspecs, jax.random.key(1))
    data = SyntheticLM(DataConfig(batch=batch, seq=seq, vocab=cfg.vocab, seed=0))
    jitted = jax.jit(step_fn)
    losses = []
    for s in range(steps):
        b = data.batch_at(s)
        if cfg.family == "encdec":
            b["frames"] = np.zeros((batch, cfg.enc_seq, cfg.d_model), np.float32)
        if cfg.family == "vlm":
            b["image_embeds"] = np.zeros((batch, cfg.n_img_tokens, cfg.d_model), np.float32)
        params, opt, m = jitted(params, opt, b)
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m", "recurrentgemma-2b", "dbrx-132b"])
def test_training_reduces_loss(arch):
    losses = run_short_training(arch)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_zero_overhead_hlo_identity():
    """The paper's central claim, structurally: an mdspan-mediated computation
    compiles to IDENTICAL optimized HLO as the raw-array version (Subspan3D)."""
    from repro.core import MdSpan, all_, submdspan

    x = jnp.arange(4 * 6 * 8, dtype=jnp.float32).reshape(4, 6, 8)

    def raw(x):
        return jnp.sum(x)

    def via_mdspan(x):
        span = MdSpan.from_dense(x)
        total = jnp.float32(0)
        # subspan-composed traversal (paper's worst-case abstraction stress)
        for i in range(span.extent(0)):
            sub_i = submdspan(span, i, all_, all_)
            total = total + jnp.sum(sub_i.to_dense())
        return total

    h2 = jax.jit(via_mdspan).lower(x).compile().as_text()
    assert "gather" not in h2  # views folded into slices, no indirect addressing
    np.testing.assert_allclose(float(raw(x)), float(via_mdspan(x)), rtol=1e-6)

    def canon(h):
        import re
        ops = [l.split("=")[1].split(",")[0] for l in h.splitlines() if "=" in l and "metadata" in l]
        return [re.sub(r"%\S+", "%", o) for o in ops]

    # op-level identity for the direct (non-subspan) path
    def via_span_direct(x):
        return jnp.sum(MdSpan.from_dense(x).to_dense())

    h1 = jax.jit(raw).lower(x).compile().as_text()
    h3 = jax.jit(via_span_direct).lower(x).compile().as_text()
    assert canon(h1) == canon(h3), "mdspan view must compile away entirely"


def test_e2e_generate_after_training():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    prompt = jnp.array([[5, 9, 2, 7]], jnp.int32)
    logits, caches = model.prefill(params, prompt, max_len=12)
    tok = jnp.argmax(logits[:, 0], -1)
    toks = [int(tok[0])]
    for g in range(4):
        logits, caches = model.decode_step(params, caches, tok, prompt.shape[1] + g)
        tok = jnp.argmax(logits, -1)
        toks.append(int(tok[0]))
    assert len(toks) == 5 and all(0 <= t < cfg.vocab_padded for t in toks)
