"""Accessor laws — the paper's Table II, functionally restated (DESIGN.md §8).

  ROUND-TRIP   decay(from_codomain(x)) ≈ x  (within quantization error bound)
  ACCESS       access(p, i) == decay(p)[i]
  STORE        access(store(p, i, v), i) ≈ v ; other offsets untouched
  OFFSET       A::offset_policy(a).access(offset(p, i), 0) == access(p, i)
  ACCUMULATE   store-twice linearity (the TPU atomic analogue)
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccumulateAccessor,
    BasicAccessor,
    BitPackedAccessor,
    MemorySpace,
    MemorySpaceAccessor,
    QuantizedAccessor,
    RestrictAccessor,
    require_same_space,
)

floats = st.lists(
    st.floats(-100, 100, allow_nan=False, width=32), min_size=8, max_size=64
)


@settings(max_examples=30, deadline=None)
@given(floats)
def test_basic_roundtrip_access_store(vals):
    acc = BasicAccessor(jnp.float32)
    buf = acc.from_codomain(jnp.array(vals, jnp.float32))
    np.testing.assert_array_equal(np.array(acc.decay(buf)), np.float32(vals))
    i = len(vals) // 2
    assert float(acc.access(buf, i)) == np.float32(vals[i])
    buf2 = acc.store(buf, i, 7.5)
    assert float(acc.access(buf2, i)) == 7.5
    assert float(acc.access(buf2, 0)) == np.float32(vals[0])  # untouched


@settings(max_examples=30, deadline=None)
@given(floats, st.sampled_from([4, 8]))
def test_quantized_roundtrip_error_bound(vals, bits):
    acc = QuantizedAccessor(jnp.float32, bits=bits, block=8)
    x = jnp.array(vals, jnp.float32)
    bufs = acc.from_codomain(x)
    rec = acc.decay(bufs, span=len(vals))
    # error bound: half a quantization step per block
    xs = np.array(x).reshape(-1)
    nb = -(-len(xs) // 8)
    pad = np.pad(xs, (0, nb * 8 - len(xs))).reshape(nb, 8)
    step = np.abs(pad).max(axis=1) / acc.qmax
    bound = np.repeat(np.maximum(step, 1e-7), 8)[: len(xs)] * 0.5 + 1e-6
    assert np.all(np.abs(np.array(rec) - xs) <= bound + 1e-5)


@settings(max_examples=50, deadline=None)
@given(
    st.integers(1, 3),            # whole blocks
    st.integers(-1, 1),           # span offset: straddle / hit / overhang a boundary
    st.sampled_from([4, 8]),      # bits — int4 exercises nibble packing at tails
    st.integers(1, 9),            # block size, odd blocks make bytes straddle blocks
    st.data(),
)
def test_quantized_roundtrip_at_block_boundaries(nblocks, delta, bits, block, data):
    """Round-trip at spans exactly on, one under, and one over block boundaries
    — odd spans leave a pad nibble in the int4 byte stream, and per-offset
    access must agree with the bulk decay at both tails (nibble parity)."""
    span = max(1, nblocks * block + delta)
    vals = data.draw(
        st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                 min_size=span, max_size=span)
    )
    acc = QuantizedAccessor(jnp.float32, bits=bits, block=block)
    bufs = acc.from_codomain(jnp.array(vals, jnp.float32))
    rec = np.array(acc.decay(bufs, span=span))
    xs = np.array(vals, np.float32)
    nb = -(-span // block)
    pad = np.pad(xs, (0, nb * block - span)).reshape(nb, block)
    step = np.abs(pad).max(axis=1) / acc.qmax
    bound = np.repeat(np.maximum(step, 1e-7), block)[:span] * 0.5 + 1e-5
    assert np.all(np.abs(rec - xs) <= bound)
    for i in {0, span // 2, span - 1}:  # both tails + a block interior
        assert float(acc.access(bufs, i)) == rec[i]


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2), st.integers(-1, 1), st.sampled_from([4, 8]), st.data())
def test_quantized_store_roundtrip_at_tail_offsets(nblocks, delta, bits, data):
    """store/access at the first and last offsets around block boundaries:
    the written value reads back within half a step of the block's existing
    scale and every other offset is untouched (catches nibble-parity and
    read-modify-write bugs at odd int4 tails)."""
    block = 8
    span = max(1, nblocks * block + delta)
    vals = data.draw(
        st.lists(st.floats(-50, 50, allow_nan=False, width=32),
                 min_size=span, max_size=span)
    )
    acc = QuantizedAccessor(jnp.float32, bits=bits, block=block)
    bufs = acc.from_codomain(jnp.array(vals, jnp.float32))
    before = np.array(acc.decay(bufs, span=span))
    for i in (0, span - 1):
        scale = float(np.array(bufs["scale"])[i // block])
        v = data.draw(st.floats(-abs(scale) * acc.qmax, abs(scale) * acc.qmax,
                                allow_nan=False, width=32))
        b2 = acc.store(bufs, i, v)
        got = float(acc.access(b2, i))
        assert abs(got - v) <= max(scale, 1e-7) * 0.5 + 1e-5
        rest = np.array(acc.decay(b2, span=span))
        mask = np.arange(span) != i
        np.testing.assert_array_equal(rest[mask], before[mask])


def test_quantized_store_uses_block_scale():
    acc = QuantizedAccessor(jnp.float32, bits=8, block=4)
    bufs = acc.from_codomain(jnp.array([1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]))
    bufs = acc.store(bufs, 1, 3.5)
    got = float(acc.access(bufs, 1))
    assert abs(got - 3.5) <= 4.0 / 127 + 1e-6
    # storing beyond the block's representable range clips
    bufs = acc.store(bufs, 1, 1000.0)
    assert float(acc.access(bufs, 1)) <= 4.0 + 1e-6


def test_accessor_offset_law():
    for acc in [BasicAccessor(jnp.float32), QuantizedAccessor(jnp.float32, bits=8, block=4)]:
        x = jnp.arange(16, dtype=jnp.float32)
        bufs = acc.from_codomain(x)
        p2 = acc.offset(bufs, 4)
        a2 = acc.offset_policy
        np.testing.assert_allclose(
            float(a2.access(p2, 0)), float(acc.access(bufs, 4)), rtol=1e-6
        )


def test_bitpacked_roundtrip_and_bit_ops():
    acc = BitPackedAccessor()
    bits = jnp.array([True, False, True, True, False, False, True, False, True, True])
    bufs = acc.from_codomain(bits)
    assert bufs.dtype == jnp.uint8 and bufs.shape == (2,)
    np.testing.assert_array_equal(np.array(acc.decay(bufs)[:10]), np.array(bits))
    bufs = acc.store(bufs, 1, True)
    bufs = acc.store(bufs, 0, False)
    assert bool(acc.access(bufs, 1)) and not bool(acc.access(bufs, 0))


def test_accumulate_linearity():
    """The atomic-accessor law, TPU-adapted: order-independent accumulation."""
    acc = AccumulateAccessor(jnp.float32)
    buf = acc.from_codomain(jnp.zeros(4))
    idx = jnp.array([1, 1, 2, 1])
    vals = jnp.array([1.0, 2.0, 5.0, 4.0])
    buf = acc.store(buf, idx, vals)
    np.testing.assert_allclose(np.array(acc.decay(buf)), [0.0, 7.0, 5.0, 0.0])


def test_restrict_is_identity():
    acc = RestrictAccessor(jnp.float32)
    x = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_array_equal(np.array(acc.decay(acc.from_codomain(x))), np.array(x))


def test_memory_space_strong_typing():
    a = MemorySpaceAccessor(jnp.float32, MemorySpace.VMEM)
    b = MemorySpaceAccessor(jnp.float32, MemorySpace.HBM)
    c = MemorySpaceAccessor(jnp.float32, MemorySpace.ANY)
    with pytest.raises(TypeError):
        require_same_space(a, b)
    require_same_space(a, c)  # ANY unifies
    # offsetting a VMEM (alignment-carrying) accessor decays to ANY (paper's
    # over-aligned pointer example)
    assert a.offset_policy.space == MemorySpace.ANY
    assert b.offset_policy.space == MemorySpace.HBM
