"""Mamba-2 block (SSD): fused in-proj → causal conv → SSD scan → gated norm → out-proj.

Train/prefill use the chunked SSD path (Pallas kernel on TPU, jnp twin elsewhere);
decode is an O(1)-per-token state update — the property that makes the long_500k
shape feasible for this family.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributed import TensorSpec
from repro.kernels import ops

from .layers import NULL_SHARDER, Sharder, apply_rmsnorm


def ssm_specs(cfg, *, quant=None) -> Dict[str, TensorSpec]:
    d = cfg.d_model
    di = cfg.ssm_dinner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = cfg.ssm_conv_dim
    dt = cfg.param_dtype
    d_in_proj = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    return {
        "in_proj": TensorSpec((d, d_in_proj), ("embed", "ssm_inner"), dtype=dt),
        "conv_w": TensorSpec((cfg.conv_kernel, conv_dim), (None, "ssm_conv"), dtype=dt, init="fan_in"),
        "conv_b": TensorSpec((conv_dim,), ("ssm_conv",), dtype=jnp.float32, init="zeros"),
        "A_log": TensorSpec((h,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "D_skip": TensorSpec((h,), ("ssm_heads",), dtype=jnp.float32, init="ones"),
        "dt_bias": TensorSpec((h,), ("ssm_heads",), dtype=jnp.float32, init="zeros"),
        "norm": TensorSpec((di,), ("ssm_inner",), dtype=jnp.float32, init="ones"),
        "out_proj": TensorSpec((di, d), ("ssm_inner", "embed"), dtype=dt),
    }


def ssm_cache_specs(cfg, batch: int) -> Dict[str, TensorSpec]:
    h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "state": TensorSpec((batch, h, p, n), ("batch", "ssm_heads", None, None), dtype=jnp.float32, init="zeros"),
        "conv": TensorSpec(
            (batch, cfg.conv_kernel - 1, cfg.ssm_conv_dim), ("batch", None, "ssm_conv"), dtype=cfg.param_dtype, init="zeros"
        ),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.ssm_dinner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel K: y_t = b + sum_i w[i] * x_{t-K+1+i}."""
    k = w.shape[0]
    acc = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0)))[:, : xbc.shape[1], :]
        acc = acc + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (acc + b).astype(xbc.dtype)


def apply_ssm(
    cfg,
    p,
    x: jax.Array,
    *,
    shard: Sharder = NULL_SHARDER,
    initial_state=None,
    return_state: bool = False,
):
    """x: (B, S, D) -> y (B, S, D) [+ final ssm state]."""
    b, s, d = x.shape
    di, g, n, h, hd = cfg.ssm_dinner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = jnp.matmul(x, p["in_proj"].astype(x.dtype))
    z, xbc, dtp = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    x_in = xbc[..., :di]
    Bm = xbc[..., di : di + g * n].reshape(b, s, g, n)
    Cm = xbc[..., di + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    xh = x_in.reshape(b, s, h, hd)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    chunk = min(cfg.ssm_chunk, s) if s % min(cfg.ssm_chunk, s) == 0 else s
    if s % chunk != 0:
        chunk = s
    y, state = ops.ssd(
        xh, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state,
        return_final_state=True, impl="jnp",
    )
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    # mamba2 RMSNormGated: normalize the GATED value
    y = apply_rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"])
    out = jnp.matmul(y, p["out_proj"].astype(x.dtype))
    if return_state:
        conv_state = xbc_raw_tail(cfg, x, p, zxbcdt)
        return out, {"state": state, "conv": conv_state}
    return out


def xbc_raw_tail(cfg, x, p, zxbcdt):
    """Last (K-1) PRE-conv xBC rows — the conv state carried into decode."""
    _, xbc_raw, _ = _split_proj(cfg, zxbcdt)
    k = cfg.conv_kernel
    return xbc_raw[:, -(k - 1) :, :]


def apply_ssm_decode(cfg, p, x: jax.Array, cache, pos, *, shard: Sharder = NULL_SHARDER):
    """x: (B, 1, D); cache {"state": (B,H,P,N) f32, "conv": (B,K-1,conv_dim)}."""
    b, _, d = x.shape
    di, g, n, h, hd = cfg.ssm_dinner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = jnp.matmul(x[:, 0], p["in_proj"].astype(x.dtype))  # (B, ...)
    z, xbc_new, dtp = _split_proj(cfg, zxbcdt)
    k = cfg.conv_kernel
    # conv over [cache, new]: y = b + sum_{i<k-1} w[i]*cache[i] + w[k-1]*new
    conv = p["conv_b"].astype(jnp.float32) + xbc_new.astype(jnp.float32) * p["conv_w"][k - 1].astype(jnp.float32)
    for i in range(k - 1):
        conv = conv + cache["conv"][:, i].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    new_conv_state = jnp.concatenate(
        [cache["conv"][:, 1:], xbc_new[:, None].astype(cache["conv"].dtype)], axis=1
    )
    xbc = jax.nn.silu(conv).astype(x.dtype)
    x_in = xbc[..., :di]
    Bm = xbc[..., di : di + g * n].reshape(b, g, n)
    Cm = xbc[..., di + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x_in.reshape(b, h, hd)
    state, y = ops.ssd_decode_step(cache["state"], xh, dt, A, Bm, Cm)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(b, di)
    y = apply_rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"])
    out = jnp.matmul(y, p["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"state": state, "conv": new_conv_state}
