"""Shared layers: norms, RoPE, MLP, embeddings, Sharder, (Quant)Linear apply.

Every parameter is declared as a ``core.distributed.TensorSpec`` (the mdspan
descriptor: extents × logical axes × dtype × accessor); apply functions consume
the plain buffer pytrees those specs initialize. Quantized weights arrive as
{"q","scale"} buffer dicts and dispatch through kernels/ops.matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import ShardingRules, TensorSpec, dequantize_array
from repro.kernels import ops


# ---------------------------------------------------------------------------------
# Sharder: activation sharding constraints from logical axis names
# ---------------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sharder:
    """Applies with_sharding_constraint from logical names; identity off-mesh.

    The activation-side twin of TensorSpec: the same ShardingRules table that lays
    out parameters lays out activations, so a parallelism change (DP→SP, TP width)
    is one table edit (the paper's layout-swap-without-algorithm-change).
    """

    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None

    def __call__(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        if self.mesh is None or self.rules is None:
            return x
        sh = self.rules.sharding(logical_axes, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(x, sh)


NULL_SHARDER = Sharder()


# ---------------------------------------------------------------------------------
# linear / quantized linear
# ---------------------------------------------------------------------------------
def fit_quant(quant: Optional[QuantizedAccessor], d_in: int) -> Optional[QuantizedAccessor]:
    """Largest block <= quant.block that divides d_in; None when d_in is too
    small/odd to quantize (the spec then falls back to dense storage)."""
    if quant is None:
        return None
    import dataclasses as _dc

    for b in (quant.block, 128, 64, 32):
        if b <= quant.block and d_in % b == 0 and b >= 16:
            return _dc.replace(quant, block=b)
    return None


def linear_spec(
    d_in: int,
    d_out: int,
    axes: Tuple[Optional[str], Optional[str]],
    *,
    dtype=jnp.bfloat16,
    quant: Optional[QuantizedAccessor] = None,
    init: str = "fan_in",
) -> TensorSpec:
    """Weight spec. Dense storage: (d_in, d_out) [K-major]. Quantized storage:
    OUTPUT-major (d_out, d_in) int8/int4+scales (kernel layout, see quant_matmul)."""
    quant = fit_quant(quant, d_in)
    if quant is not None:
        return TensorSpec(
            (d_out, d_in), (axes[1], axes[0]), dtype=dtype, init=init, accessor=quant
        )
    return TensorSpec((d_in, d_out), axes, dtype=dtype, init=init)


def apply_linear(x: jax.Array, w, spec: Optional[TensorSpec] = None) -> jax.Array:
    """x: (..., d_in) @ w. Dispatches on the buffer form (dense vs quantized)."""
    if isinstance(w, dict):  # quantized {"q","scale"}: stored (d_out, d_in)
        acc = spec.accessor if spec is not None else QuantizedAccessor(x.dtype, bits=8)
        return ops.matmul(x, w, acc)
    return jnp.matmul(x, w.astype(x.dtype))


# ---------------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------------
def rmsnorm_spec(d: int, axes=( "embed",)) -> TensorSpec:
    return TensorSpec((d,), axes, dtype=jnp.float32, init="ones")


def apply_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layernorm_specs(d: int) -> Dict[str, TensorSpec]:
    return {
        "scale": TensorSpec((d,), ("embed",), dtype=jnp.float32, init="ones"),
        "bias": TensorSpec((d,), ("embed",), dtype=jnp.float32, init="zeros"),
    }


def apply_layernorm(x: jax.Array, p: Dict[str, jax.Array], eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


def norm_specs(cfg) -> Any:
    return layernorm_specs(cfg.d_model) if cfg.norm == "layernorm" else rmsnorm_spec(cfg.d_model)


def apply_norm(cfg, x, p):
    return apply_layernorm(x, p) if cfg.norm == "layernorm" else apply_rmsnorm(x, p)


# ---------------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, T, D); positions: (T,) or (B, T) absolute positions."""
    b, h, t, d = x.shape
    freqs = rope_freqs(d, theta)  # (d/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (T, d/2)
        ang = ang[None, None]  # (1, 1, T, d/2)
    else:
        ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
        ang = ang[:, None]  # (B, 1, T, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------------
def mlp_specs(cfg, *, d_model=None, d_ff=None, quant=None) -> Dict[str, TensorSpec]:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": linear_spec(d, f, ("embed", "ffn"), dtype=dt, quant=quant),
            "w_up": linear_spec(d, f, ("embed", "ffn"), dtype=dt, quant=quant),
            "w_down": linear_spec(f, d, ("ffn", "embed"), dtype=dt, quant=quant),
        }
    return {
        "w_up": linear_spec(d, f, ("embed", "ffn"), dtype=dt, quant=quant),
        "b_up": TensorSpec((f,), ("ffn",), dtype=jnp.float32, init="zeros"),
        "w_down": linear_spec(f, d, ("ffn", "embed"), dtype=dt, quant=quant),
        "b_down": TensorSpec((d,), ("embed",), dtype=jnp.float32, init="zeros"),
    }


def apply_mlp(cfg, p, x: jax.Array, shard: Sharder = NULL_SHARDER, specs=None) -> jax.Array:
    sp = specs or {}
    if cfg.mlp_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        g = apply_linear(x, p["w_gate"], sp.get("w_gate"))
        u = apply_linear(x, p["w_up"], sp.get("w_up"))
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
        h = shard(h, "batch", "seq", "ffn")
        return apply_linear(h, p["w_down"], sp.get("w_down"))
    h = apply_linear(x, p["w_up"], sp.get("w_up")) + p["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "ffn")
    return apply_linear(h, p["w_down"], sp.get("w_down")) + p["b_down"].astype(x.dtype)


# ---------------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------------
def embed_specs(cfg) -> Dict[str, TensorSpec]:
    vp = cfg.vocab_padded
    s = {
        "embedding": TensorSpec(
            (vp, cfg.d_model), ("vocab", "embed"), dtype=cfg.param_dtype, init="embed"
        ),
    }
    if not cfg.tie_embeddings:
        # lm head sharded on VOCAB (logits matmul + sharded softmax)
        s["lm_head"] = TensorSpec(
            (cfg.d_model, vp), ("embed", "vocab"), dtype=cfg.param_dtype, init="fan_in"
        )
    return s


def apply_embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def apply_lm_head(cfg, p, x: jax.Array) -> jax.Array:
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.matmul(x, w.astype(x.dtype))
    vp = logits.shape[-1]
    if vp != cfg.vocab:  # mask padded vocab slots
        mask = (jnp.arange(vp) < cfg.vocab)
        logits = jnp.where(mask, logits, jnp.asarray(-1e9, logits.dtype))
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean CE over valid positions. logits: (..., V); labels int32 (...).

    The label log-prob is extracted with a masked REDUCTION over the vocab axis
    (not take_along_axis): with vocab sharded over "model" this lowers to a local
    reduce + psum instead of an all-gather of the logits — the difference between
    ~0.5 GB and ~17 GB of temp per device on the 4k×256 cells.
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)
