"""GQA self-attention + cross-attention blocks (pre-norm), train/prefill/decode.

Caches are (B, Hkv, S, Dh) per layer — the TensorSpec for them carries the
LayoutTiledTPU-friendly (S on sublanes, Dh on lanes) orientation and the sharding
rules bind Hkv → "model" when divisible (else the KV tensors replicate across the
model axis and only the batch axis shards — the Megatron fallback; see
ShardingRules.binding_for).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import TensorSpec
from repro.kernels import ops

from .layers import (
    NULL_SHARDER,
    Sharder,
    apply_linear,
    apply_norm,
    apply_rope,
    norm_specs,
)


# ---------------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------------
def attn_specs(cfg, *, quant=None) -> Dict[str, TensorSpec]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    s = {
        "wq": TensorSpec((d, h, dh), ("embed", "heads", None), dtype=dt),
        "wk": TensorSpec((d, hkv, dh), ("embed", "kv_heads", None), dtype=dt),
        "wv": TensorSpec((d, hkv, dh), ("embed", "kv_heads", None), dtype=dt),
        "wo": TensorSpec((h, dh, d), ("heads", None, "embed"), dtype=dt),
    }
    if cfg.qkv_bias:
        s["bq"] = TensorSpec((h, dh), ("heads", None), dtype=jnp.float32, init="zeros")
        s["bk"] = TensorSpec((hkv, dh), ("kv_heads", None), dtype=jnp.float32, init="zeros")
        s["bv"] = TensorSpec((hkv, dh), ("kv_heads", None), dtype=jnp.float32, init="zeros")
    return s


def cross_attn_specs(cfg, *, quant=None) -> Dict[str, TensorSpec]:
    # same projection geometry; kv projects the (stubbed) modality context
    return attn_specs(cfg, quant=quant)


def cache_specs(cfg, batch: int, seq: int) -> Dict[str, TensorSpec]:
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    return {
        "k": TensorSpec((batch, hkv, seq, dh), ("batch", "kv_heads", "kv_seq", None), dtype=dt, init="zeros"),
        "v": TensorSpec((batch, hkv, seq, dh), ("batch", "kv_heads", "kv_seq", None), dtype=dt, init="zeros"),
    }


def paged_cache_specs(cfg, num_pages: int, page_size: int, kv_spec=None) -> Dict[str, TensorSpec]:
    """Per-layer paged KV pool — the LayoutPaged codomain (pool_shape()) as a
    TensorSpec. Page-major with (page_size, head_dim) innermost keeps each page a
    LayoutTiledTPU-friendly (sublane, lane) tile.

    ``kv_spec`` (serving.engine.kvquant.PagedQuantSpec) swaps the element
    representation — the accessor axis — without touching the layout: each of
    k/v becomes {"q": intN page bytes, "scale": one f32 per (page, head)}."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if kv_spec is not None:
        dq = kv_spec.packed_dim(dh)
        quant = {
            "q": TensorSpec((num_pages, hkv, page_size, dq),
                            (None, "kv_heads", None, None), dtype=jnp.int8, init="zeros"),
            "scale": TensorSpec((num_pages, hkv), (None, "kv_heads"),
                                dtype=jnp.float32, init="zeros"),
        }
        return {"k": quant, "v": dict(quant)}
    dt = cfg.param_dtype
    return {
        "k": TensorSpec((num_pages, hkv, page_size, dh), (None, "kv_heads", None, None), dtype=dt, init="zeros"),
        "v": TensorSpec((num_pages, hkv, page_size, dh), (None, "kv_heads", None, None), dtype=dt, init="zeros"),
    }


def pack_kv_pages(pool: Dict[str, jax.Array], k: jax.Array, v: jax.Array,
                  pages: jax.Array) -> Dict[str, jax.Array]:
    """Scatter freshly-prefilled K/V into pool pages (the prefill->paged adapter).

    pool k/v: (L, num_pages, Hkv, ps, Dh); k/v: (L, 1, Hkv, S, Dh) with S a
    multiple of ps (pack_kv_cache pads); pages: (n,) physical ids of the
    sequence's logical pages 0..n-1, n == S // ps.
    """
    l, _, hkv, s, dh = k.shape
    ps = pool["k"].shape[3]
    n = s // ps
    # (L, Hkv, n, ps, Dh) -> (L, n, Hkv, ps, Dh)
    kp = jnp.swapaxes(k[:, 0].reshape(l, hkv, n, ps, dh), 1, 2)
    vp = jnp.swapaxes(v[:, 0].reshape(l, hkv, n, ps, dh), 1, 2)
    return {
        "k": pool["k"].at[:, pages].set(kp.astype(pool["k"].dtype)),
        "v": pool["v"].at[:, pages].set(vp.astype(pool["v"].dtype)),
    }


def pack_kv_pages_quant(pool, k: jax.Array, v: jax.Array, pages: jax.Array, *,
                        spec) -> Dict[str, Dict[str, jax.Array]]:
    """pack_kv_pages for a quantized pool: quantize AT SCATTER TIME with a fresh
    scale per (page, head) (spec.encode_pages), then write {q, scale} together.

    pool k/v: {"q": (L, num_pages, Hkv, ps, Dq) int8, "scale": (L, num_pages,
    Hkv) f32}; k/v and pages as in pack_kv_pages. Page slack (prompt pad)
    participates in the scale like any other slot — prompts are zero-padded
    deterministically, so a page (bytes AND scale) stays a pure function of the
    tokens that hash to it and prefix sharing dedupes quantized pages exactly
    as f32 ones."""
    l, _, hkv, s, dh = k.shape
    ps = pool["k"]["q"].shape[3]
    n = s // ps
    # (L, Hkv, n, ps, Dh) -> (L, n, Hkv, ps, Dh)
    kp = jnp.swapaxes(k[:, 0].reshape(l, hkv, n, ps, dh), 1, 2)
    vp = jnp.swapaxes(v[:, 0].reshape(l, hkv, n, ps, dh), 1, 2)
    kq, vq = spec.encode_pages(kp), spec.encode_pages(vp)
    return {
        "k": {"q": pool["k"]["q"].at[:, pages].set(kq["q"]),
              "scale": pool["k"]["scale"].at[:, pages].set(kq["scale"])},
        "v": {"q": pool["v"]["q"].at[:, pages].set(vq["q"]),
              "scale": pool["v"]["scale"].at[:, pages].set(vq["scale"])},
    }


def pack_kv_cache(cfg, k: jax.Array, v: jax.Array, *, max_len: Optional[int],
                  window: Optional[int]) -> Dict[str, jax.Array]:
    """Lay freshly-prefilled K/V (B, Hkv, S, Dh) into the decode cache layout.

    Non-windowed: pad the seq dim to ``max_len`` capacity (token p at slot p).
    Windowed: a ring of size ``window`` where token p lives at slot p % window —
    the invariant self_attention_decode's ring arithmetic relies on.
    """
    s = k.shape[2]
    dt = cfg.param_dtype

    def pad_to(x, cap):
        if cap > x.shape[2]:
            return jnp.pad(x, ((0, 0), (0, 0), (0, cap - x.shape[2]), (0, 0)))
        return x

    if window is not None:
        w = window
        if s >= w:
            k = jnp.roll(k[:, :, -w:], s % w, axis=2)
            v = jnp.roll(v[:, :, -w:], s % w, axis=2)
        else:
            k, v = pad_to(k, w), pad_to(v, w)
    else:
        cap = max_len if max_len is not None else s
        k, v = pad_to(k, cap), pad_to(v, cap)
    return {"k": k.astype(dt), "v": v.astype(dt)}


# ---------------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------------
def _project_qkv(cfg, p, x, ctx=None):
    """q from x; k/v from ctx (cross) or x (self). Returns (B,H,T,Dh)×3."""
    src = x if ctx is None else ctx
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bhtk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bhtk", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
        k = k + p["bk"].astype(x.dtype)[None, :, None, :]
        v = v + p["bv"].astype(x.dtype)[None, :, None, :]
    return q, k, v


def _out_proj(p, attn_out, x_dtype):
    return jnp.einsum("bhtk,hkd->btd", attn_out, p["wo"].astype(x_dtype))


# ---------------------------------------------------------------------------------
# self-attention paths
# ---------------------------------------------------------------------------------
def self_attention(
    cfg,
    p,
    x: jax.Array,
    *,
    shard: Sharder = NULL_SHARDER,
    causal: bool = True,
    window: Optional[int] = None,
    pos_offset=0,
    return_kv: bool = False,
):
    """Full-sequence self-attention (train / prefill). x: (B, T, D)."""
    b, t, d = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    pos = jnp.arange(t) + pos_offset
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", "heads", "seq", None)
    k = shard(k, "batch", "kv_heads", "seq", None)
    out = ops.attention(q, k, v, causal=causal, window=window, q_offset=pos_offset, impl="jnp")
    out = shard(out, "batch", "heads", "seq", None)
    y = _out_proj(p, out, x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def _decode_attention_seq_sharded(cfg, q, k_new, v_new, cache, pos, mesh):
    """Distributed flash-decode over a kv_seq-sharded cache (§Perf decode fix).

    GSPMD's lowering of decode attention against a seq-sharded cache ALL-GATHERS
    the cache (~0.5 GB/layer/token on dbrx — measured). This shard_map version
    keeps every rank's KV slice local: each rank updates its slot (if the write
    position falls in its range), computes partial attention over its slice, and
    the ranks merge with a numerically-exact log-sum-exp combine — the collective
    is a (B, H, D)-sized psum (~3 MB) instead of the cache gather.

    q: (B, Hq, 1, D) [replicated over "model" on entry — a ~1 MB gather];
    cache k/v: (B, Hkv, S, D) sharded S→"model"; pos traced scalar.
    """
    from jax.sharding import PartitionSpec as P

    b, hq, _, d = q.shape
    s_total = cache["k"].shape[2]
    ep = mesh.shape["model"]
    s_loc = s_total // ep
    group = hq // cfg.n_kv_heads
    scale = 1.0 / np.sqrt(cfg.head_dim)

    def local_fn(q, k_new, v_new, ck, cv, pos):
        my = jax.lax.axis_index("model")
        slot = pos - my * s_loc
        in_range = (slot >= 0) & (slot < s_loc)
        slot_c = jnp.clip(slot, 0, s_loc - 1)
        ck_upd = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype), (0, 0, slot_c, 0))
        cv_upd = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype), (0, 0, slot_c, 0))
        ck = jnp.where(in_range, ck_upd, ck)
        cv = jnp.where(in_range, cv_upd, cv)

        # GQA via a group dim on q — the cache is NEVER repeated/materialized
        qg = q.reshape(b, cfg.n_kv_heads, group, d).astype(jnp.float32)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, ck.astype(jnp.float32)) * scale
        k_pos = my * s_loc + jnp.arange(s_loc)
        live = k_pos[None, None, None, :] <= pos
        s = jnp.where(live, s, -1e30)
        m_loc = jnp.max(s, axis=-1, keepdims=True)  # (B,Hkv,G,1)
        p_ = jnp.exp(s - m_loc)
        p_ = jnp.where(live, p_, 0.0)
        l_loc = jnp.sum(p_, axis=-1, keepdims=True)
        acc_loc = jnp.einsum("bhgk,bhkd->bhgd", p_, cv.astype(jnp.float32))
        # exact LSE merge across seq shards
        m_g = jax.lax.pmax(m_loc, "model")
        w = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * w, "model")
        acc_g = jax.lax.psum(acc_loc * w, "model")
        out = (acc_g / jnp.where(l_g == 0, 1.0, l_g)).reshape(b, hq, 1, d).astype(q.dtype)
        return out, ck, cv

    out, ck, cv = jax.shard_map(
        local_fn,
        mesh=mesh,
        axis_names={"model"},
        in_specs=(P(), P(), P(), P(None, None, "model", None), P(None, None, "model", None), P()),
        out_specs=(P(), P(None, None, "model", None), P(None, None, "model", None)),
        check_vma=False,
    )(q, k_new, v_new, cache["k"], cache["v"], jnp.asarray(pos, jnp.int32))
    return out, {"k": ck, "v": cv}


def self_attention_decode(
    cfg,
    p,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    pos,
    *,
    shard: Sharder = NULL_SHARDER,
    window: Optional[int] = None,
):
    """One-token decode. x: (B, 1, D); cache k/v: (B, Hkv, S, Dh); pos traced.

    For windowed attention the cache is a ring buffer of size >= window: we write
    at pos % S and attend with absolute positions reconstructed from the ring.
    """
    b, _, d = x.shape
    s_len = cache["k"].shape[2]
    q, k, v = _project_qkv(cfg, p, x)
    posv = jnp.reshape(jnp.asarray(pos, jnp.int32), (1,))
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    # distributed flash-decode when the cache's seq dim is sharded over "model"
    mesh = getattr(shard, "mesh", None)
    if (
        window is None
        and mesh is not None
        and "model" in mesh.shape
        and mesh.shape["model"] > 1
        and shard.rules is not None
        and shard.rules.rules.get("kv_seq") == "model"
        and s_len % mesh.shape["model"] == 0
    ):
        out, cache = _decode_attention_seq_sharded(cfg, q, k, v, cache, pos, mesh)
        return _out_proj(p, out, x.dtype), cache
    slot = jnp.asarray(pos, jnp.int32) % s_len  # ring for windowed; == pos otherwise
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
    if window is None:
        out = ops.decode_attention(q, ck, cv, pos, impl="jnp")
    else:
        # ring-buffer decode: positions of slot i is reconstructed; mask outside window
        # absolute position of ring slot i: pos - ((slot - i) mod S)
        idx = jnp.arange(s_len)
        abs_pos = pos - ((slot - idx) % s_len)
        live = (abs_pos >= jnp.maximum(pos - window + 1, 0)) & (abs_pos <= pos)
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
        group = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(ck.astype(jnp.float32), group, axis=1)
        vf = jnp.repeat(cv.astype(jnp.float32), group, axis=1)
        sL = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
        sL = jnp.where(live[None, None, None, :], sL, -1e30)
        pr = jax.nn.softmax(sL, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", pr, vf).astype(x.dtype)
    y = _out_proj(p, out, x.dtype)
    return y, {"k": ck, "v": cv}


def _quant_append(buf, tok, page, slot, spec):
    """Scatter one quantized token per batch row into its (page, slot).

    buf: {"q": (num_pages, Hkv, ps, Dq), "scale": (num_pages, Hkv)};
    tok: (B, Hkv, Dh) f32; page/slot: (B,) int32. Scale policy (kvquant §scale
    lifecycle): slot 0 means the page is brand new (decode just crossed a page
    boundary), so it takes a fresh per-head scale from the token; otherwise the
    token re-quantizes with the page's EXISTING scale, clipped — the
    QuantizedAccessor.store law. Inactive rows target the reserved null page;
    their writes (bytes and scale) land there harmlessly, like the f32 path."""
    fresh = (slot == 0)[:, None]                       # (B, 1)
    scale = jnp.where(fresh, spec.token_scale(tok), buf["scale"][page])  # (B, Hkv)
    qtok = spec.quantize_tokens(tok, scale)            # (B, Hkv, Dq)
    return {
        "q": buf["q"].at[page, :, slot, :].set(qtok),
        "scale": buf["scale"].at[page].set(scale),
    }


def self_attention_decode_paged(
    cfg,
    p,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    shard: Sharder = NULL_SHARDER,
    impl: str = "auto",
    kv_spec=None,
    block_pages: int | None = None,
):
    """One-token decode against a paged KV pool (the LayoutPaged cache adapter).

    x: (B, 1, D); cache k/v: (num_pages, Hkv, ps, Dh) — one layer's page pool;
    block_tables: (B, max_pages) int32 (rows shared by all layers);
    context_lens: (B,) int32 tokens already cached per sequence — the new token
    is written at position context_lens[b], i.e. page block_tables[b, len//ps]
    slot len % ps, exactly LayoutPaged's index->offset map. Unlike the dense
    decode path, every batch row has its OWN position (continuous batching).

    ``kv_spec`` (PagedQuantSpec) switches the pool to the quantized element
    representation: cache k/v are then {"q", "scale"} pytrees, the append
    quantizes at scatter time, and attention runs the dequantizing kernel (or
    its jnp twin) — same layout, same block tables, different accessor.

    ``block_pages`` is the autotuned kernel block-shape knob, forwarded
    verbatim to ops.paged_decode_attention{,_quant} (None = unblocked).

    Single-host path: ``shard`` is accepted for API symmetry with
    self_attention_decode but no mesh-aware variant exists yet — on a mesh the
    page pool replicates (multi-host paging is a ROADMAP open item).
    """
    b, _, d = x.shape
    ps = cache["k"]["q"].shape[2] if kv_spec is not None else cache["k"].shape[2]
    q, k, v = _project_qkv(cfg, p, x)
    pos = jnp.asarray(context_lens, jnp.int32)  # (B,)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    page = block_tables[jnp.arange(b), pos // ps]  # (B,)
    slot = pos % ps
    if kv_spec is not None:
        ck = _quant_append(cache["k"], k[:, :, 0, :], page, slot, kv_spec)
        cv = _quant_append(cache["v"], v[:, :, 0, :], page, slot, kv_spec)
        out = ops.paged_decode_attention_quant(
            q, ck["q"], ck["scale"], cv["q"], cv["scale"], block_tables, pos + 1,
            bits=kv_spec.bits, block_pages=block_pages, impl=impl,
        )
    else:
        ck = cache["k"].at[page, :, slot, :].set(k[:, :, 0, :].astype(cache["k"].dtype))
        cv = cache["v"].at[page, :, slot, :].set(v[:, :, 0, :].astype(cache["v"].dtype))
        out = ops.paged_decode_attention(
            q, ck, cv, block_tables, pos + 1, block_pages=block_pages, impl=impl
        )
    y = _out_proj(p, out, x.dtype)
    return y, {"k": ck, "v": cv}


def self_attention_verify_paged(
    cfg,
    p,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    shard: Sharder = NULL_SHARDER,
    impl: str = "auto",
    kv_spec=None,
):
    """Speculative VERIFY: score C = K+1 tokens per row in ONE chunk-style call.

    x: (B, C, D) embeddings of [current token, draft_1..draft_K];
    context_lens: (B,) tokens already resident per row. Token j of the present
    lands at position lens+j through the SAME per-token append law the decode
    path uses — a static sequential loop, because the quantized scale lifecycle
    (_quant_append: fresh scale at slot 0, existing scale otherwise) is
    order-dependent within a page. The present K/V are then gathered BACK from
    the pool (dequantized under ``kv_spec``, pool dtype otherwise) so each
    draft row attends exactly the bytes a sequential one-token decode would
    have read, and a single chunk-attention call with cursors = context_lens
    scores all C rows against past + causal present. Rejected suffixes need no
    undo here: positions ≥ the accepted length are dead under the rolled-back
    ``lens`` and are overwritten by later appends (rollback is lens
    arithmetic, not page surgery).

    Unlike the prefill chunk path, C is NOT page-aligned and the writes are
    per-token scatters, not whole-page encodes — drafts start mid-page.
    Inactive rows (nulled tables/lens) write into the reserved null page.
    """
    b, c, d = x.shape
    ps = cache["k"]["q"].shape[2] if kv_spec is not None else cache["k"].shape[2]
    q, k, v = _project_qkv(cfg, p, x)  # (B, H, C, Dh)
    lens = jnp.asarray(context_lens, jnp.int32)
    pos = lens[:, None] + jnp.arange(c)[None, :]  # (B, C)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    rows = jnp.arange(b)
    ck, cv = cache["k"], cache["v"]
    pages, slots = [], []
    for j in range(c):
        pj = pos[:, j]
        page = block_tables[rows, pj // ps]  # (B,)
        slot = pj % ps
        pages.append(page)
        slots.append(slot)
        if kv_spec is not None:
            ck = _quant_append(ck, k[:, :, j, :], page, slot, kv_spec)
            cv = _quant_append(cv, v[:, :, j, :], page, slot, kv_spec)
        else:
            ck = ck.at[page, :, slot, :].set(k[:, :, j, :].astype(ck.dtype))
            cv = cv.at[page, :, slot, :].set(v[:, :, j, :].astype(cv.dtype))
    # gather the present back from the pool: draft rows must attend the bytes
    # a sequential decode would read (pool dtype / page-scale dequant), not
    # the fresh f32 projections — greedy exactness depends on it
    pg = jnp.stack(pages, axis=1)  # (B, C)
    sl = jnp.stack(slots, axis=1)
    if kv_spec is not None:
        ks = ck["scale"][pg]  # (B, C, Hkv)
        vs = cv["scale"][pg]
        k_pres = kv_spec.decode_pages(ck["q"][pg, :, sl, :][:, :, :, None, :], ks)[..., 0, :]
        v_pres = kv_spec.decode_pages(cv["q"][pg, :, sl, :][:, :, :, None, :], vs)[..., 0, :]
    else:
        k_pres = ck[pg, :, sl, :].astype(jnp.float32)  # (B, C, Hkv, Dh)
        v_pres = cv[pg, :, sl, :].astype(jnp.float32)
    k_pres = jnp.swapaxes(k_pres, 1, 2)  # (B, Hkv, C, Dh)
    v_pres = jnp.swapaxes(v_pres, 1, 2)
    if kv_spec is not None:
        out = ops.paged_prefill_chunk_attention_quant(
            q, k_pres, v_pres, ck["q"], ck["scale"], cv["q"], cv["scale"],
            block_tables, lens, bits=kv_spec.bits, impl=impl,
        )
    else:
        out = ops.paged_prefill_chunk_attention(
            q, k_pres, v_pres, ck, cv, block_tables, lens, impl=impl
        )
    y = _out_proj(p, out, x.dtype)
    return y, {"k": ck, "v": cv}


def _scatter_chunk_pages(cache, kp, vp, dest, kv_spec):
    """Scatter whole chunk pages into the pool. kp/vp: (B, nP, Hkv, ps, Dh) page-
    factored chunk KV; dest: (B, nP) physical destinations (invalid entries
    already routed to the null page 0). Quantized pools encode one fresh scale
    per (page, head) from the page's own absmax — exactly pack_kv_pages_quant's
    law, so a chunk-written page is bit-compatible with a monolithic-prefill
    one and the prefix index may dedupe across the two regimes."""
    b, npg = dest.shape
    flat = dest.reshape(-1)
    if kv_spec is not None:
        kq, vq = kv_spec.encode_pages(kp), kv_spec.encode_pages(vp)
        hkv, ps, dq = kq["q"].shape[2:]
        ck = {
            "q": cache["k"]["q"].at[flat].set(kq["q"].reshape(b * npg, hkv, ps, dq)),
            "scale": cache["k"]["scale"].at[flat].set(kq["scale"].reshape(b * npg, hkv)),
        }
        cv = {
            "q": cache["v"]["q"].at[flat].set(vq["q"].reshape(b * npg, hkv, ps, dq)),
            "scale": cache["v"]["scale"].at[flat].set(vq["scale"].reshape(b * npg, hkv)),
        }
        return ck, cv
    hkv, ps, dh = kp.shape[2:]
    ck = cache["k"].at[flat].set(kp.reshape(b * npg, hkv, ps, dh).astype(cache["k"].dtype))
    cv = cache["v"].at[flat].set(vp.reshape(b * npg, hkv, ps, dh).astype(cache["v"].dtype))
    return ck, cv


def self_attention_prefill_chunk_paged(
    cfg,
    p,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    block_tables: jax.Array,
    write_tables: jax.Array,
    cursors: jax.Array,
    n_new: jax.Array,
    *,
    shard: Sharder = NULL_SHARDER,
    impl: str = "auto",
    kv_spec=None,
):
    """One prefill CHUNK against a paged KV pool — the mixed-step prefill half.

    x: (B, C, D) the chunk's token embeddings (C a page multiple, the engine's
    chunk bucket); block_tables: (B, max_pages) the READ view (every resident
    page, shared ones included); write_tables: the WRITE view — same rows with
    non-writable entries (adopted shared-prefix pages, slots past the
    allocation) nulled to page 0, so the scatter of a chunk that overlaps a
    shared prefix lands harmlessly while its reads still see the donor's KV.
    cursors: (B,) int32 page-aligned count of tokens resident before this
    chunk; n_new: (B,) int32 valid new tokens this chunk contributes (a page
    multiple; positions past it are pad whose KV routes to the null page).

    This is the chunk-view path: the unit of work is formally the submdspan
    ``[cursors, cursors + n_new)`` of the sequence's paged cache view
    (core/submdspan.py §chunk views), executed as: scatter the chunk's KV into
    its own pages, then attend Q rows against everything resident with causal
    masking across the chunk boundary. ``kv_spec`` swaps in the quantized
    accessor exactly as in the decode path.
    """
    b, c, d = x.shape
    ps = cache["k"]["q"].shape[2] if kv_spec is not None else cache["k"].shape[2]
    npg = c // ps
    max_pages = block_tables.shape[1]
    q, k, v = _project_qkv(cfg, p, x)  # (B, H, C, Dh)
    pos = cursors[:, None] + jnp.arange(c)[None, :]  # (B, C)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    hkv, dh = k.shape[1], k.shape[3]
    # page-factor the chunk KV: (B, Hkv, C, Dh) -> (B, nP, Hkv, ps, Dh)
    kp = jnp.swapaxes(k.reshape(b, hkv, npg, ps, dh), 1, 2)
    vp = jnp.swapaxes(v.reshape(b, hkv, npg, ps, dh), 1, 2)
    # destination pages: the chunk's logical pages through the WRITE table;
    # pages past n_new (chunk-bucket pad) go to the null page
    logical = cursors[:, None] // ps + jnp.arange(npg)[None, :]  # (B, nP)
    gathered = jnp.take_along_axis(
        write_tables, jnp.clip(logical, 0, max_pages - 1), axis=1
    )
    valid = jnp.arange(npg)[None, :] * ps < n_new[:, None]
    dest = jnp.where(valid, gathered, 0)
    ck, cv = _scatter_chunk_pages(cache, kp, vp, dest, kv_spec)
    # attention: past from the pool (positions < cursor), present from the
    # chunk's own f32 k/v — the scattered pages never feed back into their own
    # chunk's attention, so intra-chunk math matches monolithic prefill even
    # over quantized pools
    if kv_spec is not None:
        out = ops.paged_prefill_chunk_attention_quant(
            q, k, v, ck["q"], ck["scale"], cv["q"], cv["scale"], block_tables,
            cursors, bits=kv_spec.bits, impl=impl,
        )
    else:
        out = ops.paged_prefill_chunk_attention(
            q, k, v, ck, cv, block_tables, cursors, impl=impl
        )
    y = _out_proj(p, out, x.dtype)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------------
# cross-attention paths (whisper decoder, vlm image layers)
# ---------------------------------------------------------------------------------
def cross_attention(cfg, p, x: jax.Array, ctx: jax.Array, *, shard=NULL_SHARDER,
                    return_kv: bool = False):
    """x: (B, T, D) queries; ctx: (B, Tc, D) keys/values (no RoPE on cross)."""
    q, k, v = _project_qkv(cfg, p, x, ctx=ctx)
    out = ops.attention(q, k, v, causal=False, impl="jnp")
    y = _out_proj(p, out, x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_decode(cfg, p, x: jax.Array, kv: Tuple[jax.Array, jax.Array]):
    """Decode-time cross-attention against precomputed context KV."""
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
    k, v = kv
    out = ops.attention(q, k.astype(x.dtype), v.astype(x.dtype), causal=False, impl="jnp")
    return _out_proj(p, out, x.dtype)
