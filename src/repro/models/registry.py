"""Architecture registry: config lookup, model construction, parameter counting."""
from __future__ import annotations

import importlib
import math
from typing import Dict, Optional

import jax

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import is_spec, tree_param_count

from .config import ModelConfig
from .transformer import Model

ARCH_IDS = [
    "mamba2-780m",
    "whisper-large-v3",
    "dbrx-132b",
    "kimi-k2-1t-a32b",
    "granite-8b",
    "qwen2-0.5b",
    "qwen2.5-3b",
    "llama3.2-1b",
    "llama-3.2-vision-90b",
    "recurrentgemma-2b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.smoke_config() if smoke else mod.config()


def build_model(cfg: ModelConfig, *, quantized: bool = False) -> Model:
    quant = (
        QuantizedAccessor(cfg.param_dtype, bits=8, block=128) if quantized else None
    )
    return Model(cfg, quant=quant)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count from the spec tree; MoE active = routed fraction top_k/E."""
    model = Model(cfg)
    specs = model.param_specs()
    if not active_only or cfg.n_experts == 0:
        return tree_param_count(specs)
    total = 0
    expert_total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_spec)[0]:
        n = math.prod(s.shape)
        if any(ax == "expert" for ax in s.logical_axes):
            expert_total += n
        else:
            total += n
    return total + expert_total * cfg.top_k // cfg.n_experts
