"""Mixture-of-Experts block: top-k token-choice routing with sort-based dispatch.

Dispatch is capacity-based (deterministic shapes — required for SPMD lowering):
tokens are ranked within their chosen expert via an argsort over expert ids, then
scattered into an (E, C, D) buffer whose expert dim shards over the "model" axis —
the token→expert all-to-all materializes at this sharding boundary, and the
expert FFN einsums run expert-parallel (EP). Combine is the gather transpose.

Aux load-balance loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributed import TensorSpec

from .layers import NULL_SHARDER, Sharder

# jax.shard_map (with check_vma) landed after 0.4.x; older releases ship it as
# jax.experimental.shard_map.shard_map with the check_rep spelling of the same
# knob. Resolve once so the EP path runs on both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NOCHECK = {"check_rep": False}


def moe_specs(cfg, *, quant=None) -> Dict[str, TensorSpec]:
    from .layers import fit_quant

    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype

    def mk(shape, axes):
        q = fit_quant(quant, shape[-1])
        return TensorSpec(shape, axes, dtype=dt, init="fan_in", accessor=q)
    return {
        "router": TensorSpec((d, e), ("embed", None), dtype=jnp.float32, init="fan_in"),
        "w_gate": mk((e, d, f), ("expert", "embed", "expert_ffn")),
        "w_up": mk((e, d, f), ("expert", "embed", "expert_ffn")),
        "w_down": mk((e, f, d), ("expert", "expert_ffn", "embed")),
    }


def _capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return -(-c // 8) * 8  # sublane-aligned


def apply_moe(
    cfg, p, x: jax.Array, shard: Sharder = NULL_SHARDER
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)
    xt = shard(xt, "tokens", None)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch aux loss: E * sum_e f_e * P_e
    ohot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)  # top-1 fraction
    f_e = jnp.mean(ohot, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    # rank within expert via stable sort over expert ids
    eflat = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(eflat)  # stable
    sorted_e = eflat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")  # (E,)
    ranks_sorted = jnp.arange(t * k) - starts[sorted_e]
    ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)

    slot = eflat * cap + ranks
    valid = ranks < cap
    safe_slot = jnp.where(valid, slot, e * cap)  # out-of-range -> dropped

    token_of = jnp.arange(t * k) // k
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[safe_slot].set(xt[token_of], mode="drop")
    buf = buf.reshape(e, cap, d)
    buf = shard(buf, "expert", None, None)  # ← token→expert all-to-all boundary

    # expert FFN (SwiGLU), expert-parallel batched einsums
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype) if not isinstance(p["w_gate"], dict) else _deq(p["w_gate"], cfg))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype) if not isinstance(p["w_up"], dict) else _deq(p["w_up"], cfg))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "expert", None, "expert_ffn")
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype) if not isinstance(p["w_down"], dict) else _deq(p["w_down"], cfg))
    y = y.reshape(e * cap, d)

    # combine: gather back and weight
    gathered = y[jnp.where(valid, slot, 0)]  # (T*k, D)
    w = (gate_vals.reshape(-1) * valid.astype(jnp.float32)).astype(x.dtype)
    out = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)
    out = shard(out, "tokens", None)
    return out.reshape(b, s, d), aux


# ------------------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (§Perf hillclimb #1)
#
# The pure-SPMD scatter/gather dispatch above lets GSPMD choose the collectives, and
# it chooses disastrously at 384-expert scale: the dispatch scatter materializes and
# ALL-GATHERS a (T·k, D) u32 index tensor (~240 GB/device/layer on the kimi-k2 train
# cell — measured, see EXPERIMENTS.md §Perf). The shard_map formulation makes the
# data movement explicit and minimal:
#
#   * tokens are sharded over the batch axes and REPLICATED over "model", so every
#     model-rank routes identically and just SLICES its own experts' buffers — the
#     dispatch itself moves zero bytes;
#   * each rank computes its experts' outputs and the gate-weighted COMBINE for its
#     expert subset; one bf16 psum over "model" (activation-sized, T_loc × D) merges
#     the contributions — this is the only forward collective;
#   * FSDP weight gathers still happen at the shard_map boundary (declared in_specs),
#     where XLA can overlap them with the previous layer.
# ------------------------------------------------------------------------------------
MOE_IMPL = "auto"  # "auto" -> shard_map when a mesh with a "model" axis is present


def set_moe_impl(impl: str) -> None:
    global MOE_IMPL
    assert impl in ("auto", "einsum", "shard_map")
    MOE_IMPL = impl


def use_shard_map(shard) -> bool:
    if MOE_IMPL == "einsum":
        return False
    mesh = getattr(shard, "mesh", None)
    return mesh is not None and "model" in mesh.shape and mesh.shape["model"] > 1


def apply_moe_ep(cfg, p, x: jax.Array, shard) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel MoE. x: (B, S, D) sharded (batch→batch axes)."""
    from jax.sharding import PartitionSpec as P

    mesh = shard.mesh
    ep = mesh.shape["model"]
    tok_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_tok = 1
    for a in tok_axes:
        n_tok *= mesh.shape[a]
    b, s, d = x.shape
    t = b * s
    assert t % n_tok == 0
    t_loc = t // n_tok
    e, k = cfg.n_experts, cfg.top_k
    assert e % ep == 0
    e_loc = e // ep
    cap = -(-(int(t_loc * k * cfg.capacity_factor / e) + 1) // 8) * 8  # ceil to 8

    def local_fn(xt, router_w, wg, wu, wd):
        # xt: (T_loc, D); router_w: (D, E); wg/wu: (e_loc, D, F); wd: (e_loc, F, D)
        f32 = jnp.float32
        logits = xt.astype(f32) @ router_w.astype(f32)  # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        ohot = jax.nn.one_hot(idx[:, 0], e, dtype=f32)
        aux = e * jnp.sum(jnp.mean(ohot, 0) * jnp.mean(probs, 0))
        aux = jax.lax.pmean(aux, tok_axes) if tok_axes else aux

        # local slot assignment (all ints are (T_loc*k,) — nothing big)
        eflat = idx.reshape(-1)
        order = jnp.argsort(eflat)
        sorted_e = eflat[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        ranks_sorted = jnp.arange(t_loc * k) - starts[sorted_e]
        ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
        slot = eflat * cap + ranks
        valid = ranks < cap
        token_of = jnp.arange(t_loc * k) // k

        # dispatch rows for MY experts only: slice the slot table, gather locally
        my = jax.lax.axis_index("model")
        src = jnp.full((e * cap,), t_loc * k, jnp.int32)
        src = src.at[jnp.where(valid, slot, e * cap)].set(
            jnp.arange(t_loc * k, dtype=jnp.int32), mode="drop"
        )
        src_my = jax.lax.dynamic_slice_in_dim(src, my * e_loc * cap, e_loc * cap, 0)
        live = src_my < t_loc * k
        rows = jnp.where(
            live[:, None], xt[token_of[jnp.minimum(src_my, t_loc * k - 1)]], 0
        )  # (e_loc*cap, D)
        buf = rows.reshape(e_loc, cap, d)

        wg_, wu_, wd_ = (
            _deq(w, cfg) if isinstance(w, dict) else w.astype(x.dtype)
            for w in (wg, wu, wd)
        )
        g = jnp.einsum("ecd,edf->ecf", buf, wg_)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_)
        h = (jax.nn.silu(g.astype(f32)) * u.astype(f32)).astype(x.dtype)
        y = jnp.einsum("ecf,efd->ecd", h, wd_).reshape(e_loc * cap, d)

        # combine MY experts' contributions at their source tokens, then psum
        w_gate_flat = (gate_vals.reshape(-1) * valid.astype(f32)).astype(x.dtype)
        contrib = jnp.zeros((t_loc, d), x.dtype)
        src_tok = jnp.where(live, token_of[jnp.minimum(src_my, t_loc * k - 1)], t_loc)
        src_w = jnp.where(live, w_gate_flat[jnp.minimum(src_my, t_loc * k - 1)], 0)
        contrib = contrib.at[src_tok].add(y * src_w[:, None], mode="drop")
        out = jax.lax.psum(contrib, "model")
        return out, aux

    xt = x.reshape(t, d)
    tok = tok_axes if len(tok_axes) > 1 else (tok_axes[0] if tok_axes else None)
    wspec3 = P("model", None, None)  # prefix-matches quantized {"q","scale"} leaves too
    out, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(tok, None), P(None, None), wspec3, wspec3, wspec3),
        out_specs=(P(tok, None), P()),
        **_SHARD_MAP_NOCHECK,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out.reshape(b, s, d), aux


def _deq(wbufs, cfg):
    """Expert weights stored quantized: dequantize at use (serving path).

    NOTE: expert matmuls dominate MoE compute; the Pallas quant path covers 2-D
    weights — batched-expert quantized einsum falls back to dequant-then-einsum
    (HBM still holds int8; dequant is at the compute boundary)."""
    from repro.core.accessors import QuantizedAccessor
    from repro.core.distributed import dequantize_array

    # accessor metadata travels on the spec; bits inferred from buffer dtypes
    acc = QuantizedAccessor(cfg.param_dtype, bits=8, block=wbufs["q"].shape[-1] // wbufs["scale"].shape[-1])
    return dequantize_array(wbufs, acc)


def apply_moe_dispatch(cfg, p, x, shard) -> Tuple[jax.Array, jax.Array]:
    """Entry point: shard_map EP when a model axis exists (hillclimbed path),
    pure-SPMD einsum dispatch otherwise (single-host smoke paths, baselines)."""
    if use_shard_map(shard):
        return apply_moe_ep(cfg, p, x, shard)
    return apply_moe(cfg, p, x, shard)
