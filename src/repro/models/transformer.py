"""Generic transformer stack executor covering all 10 assigned architectures.

An architecture is a PROGRAM: a list of (block-kind, count) entries. Homogeneous
runs of blocks are stacked (leading `count` dim on every param/cache leaf) and
executed with jax.lax.scan (+ optional per-layer remat for training) — keeping
compiled HLO size O(1) in depth, which is what makes the 100-layer dry-runs cheap.

Block kinds:
  dense       self-attn (+optional local window) + MLP          (llama/qwen/granite)
  moe         self-attn + mixture-of-experts FFN                (dbrx, kimi-k2)
  ssm         mamba-2 SSD block (no MLP)                        (mamba2-780m)
  rec         RG-LRU temporal block + MLP                       (recurrentgemma)
  local_attn  windowed self-attn + MLP                          (recurrentgemma)
  rg_group    composite [rec, rec, local_attn]                  (recurrentgemma 1:2)
  enc         non-causal self-attn + MLP (no cache)             (whisper encoder)
  dec         causal self-attn + cross-attn + MLP               (whisper decoder)
  vis_group   composite [4 × dense self] + gated cross-attn     (llama-3.2-vision)

Every kind implements: specs / cache_specs / train / prefill / decode with uniform
signatures so the executor is kind-agnostic. `train` returns (x, aux) where aux is
the MoE load-balance loss (0 elsewhere).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import TensorSpec, tree_initialize

from . import attention as attn
from . import moe as moe_mod
from . import rglru as rg_mod
from . import ssm as ssm_mod
from .layers import (
    NULL_SHARDER,
    Sharder,
    apply_embed,
    apply_lm_head,
    apply_mlp,
    apply_norm,
    cross_entropy,
    embed_specs,
    mlp_specs,
    norm_specs,
)


# Dry-run probes set this to unroll layer scans so XLA cost analysis (which
# counts while-loop bodies ONCE) sees every layer — see launch/dryrun.py.
_SCAN_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(flag)


def stack_scan(body, carry, xs):
    if _SCAN_UNROLL:
        return jax.lax.scan(body, carry, xs, unroll=True)
    return jax.lax.scan(body, carry, xs)


def stack_specs(specs, n: int):
    """Prepend a layer dim (logical axis "layers" → replicated) to every spec."""
    return jax.tree.map(
        lambda s: TensorSpec(
            (n,) + s.shape, ("layers",) + s.logical_axes, dtype=s.dtype,
            init=s.init, accessor=s.accessor,
        ),
        specs,
        is_leaf=lambda x: isinstance(x, TensorSpec),
    )


# =====================================================================================
# block kinds
# =====================================================================================
class DenseBlock:
    def __init__(self, use_window: bool = False, causal: bool = True):
        self.use_window = use_window
        self.causal = causal

    def _window(self, cfg):
        return cfg.window if self.use_window else None

    def specs(self, cfg, quant=None):
        return {
            "ln_attn": norm_specs(cfg),
            "attn": attn.attn_specs(cfg, quant=quant),
            "ln_mlp": norm_specs(cfg),
            "mlp": mlp_specs(cfg, quant=quant),
        }

    def cache_specs(self, cfg, batch: int, seq: int):
        w = self._window(cfg)
        s = min(seq, w) if w is not None else seq
        return attn.cache_specs(cfg, batch, s)

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        h = apply_norm(cfg, x, p["ln_attn"])
        x = x + attn.self_attention(
            cfg, p["attn"], h, shard=shard, causal=self.causal,
            window=self._window(cfg), pos_offset=pos_offset,
        )
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, (k, v) = attn.self_attention(
            cfg, p["attn"], h, shard=shard, causal=self.causal,
            window=self._window(cfg), return_kv=True,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        cache = attn.pack_kv_cache(cfg, k, v, max_len=max_len, window=self._window(cfg))
        return x, cache

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_decode(
            cfg, p["attn"], h, cache, pos, shard=shard, window=self._window(cfg)
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache

    def paged_cache_specs(self, cfg, num_pages: int, page_size: int, kv_spec=None):
        if self._window(cfg) is not None:
            raise NotImplementedError("paged KV caching does not support local windows")
        return attn.paged_cache_specs(cfg, num_pages, page_size, kv_spec=kv_spec)

    def decode_paged(self, cfg, p, x, cache, block_tables, context_lens, shard,
                     impl: str = "auto", kv_spec=None, block_pages=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_decode_paged(
            cfg, p["attn"], h, cache, block_tables, context_lens, shard=shard,
            impl=impl, kv_spec=kv_spec, block_pages=block_pages,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache

    def prefill_chunk_paged(self, cfg, p, x, cache, block_tables, write_tables,
                            cursors, n_new, shard, impl: str = "auto", kv_spec=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_prefill_chunk_paged(
            cfg, p["attn"], h, cache, block_tables, write_tables, cursors, n_new,
            shard=shard, impl=impl, kv_spec=kv_spec,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache

    def verify_paged(self, cfg, p, x, cache, block_tables, context_lens, shard,
                     impl: str = "auto", kv_spec=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_verify_paged(
            cfg, p["attn"], h, cache, block_tables, context_lens, shard=shard,
            impl=impl, kv_spec=kv_spec,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache


class MoEBlock(DenseBlock):
    def specs(self, cfg, quant=None):
        return {
            "ln_attn": norm_specs(cfg),
            "attn": attn.attn_specs(cfg, quant=quant),
            "ln_moe": norm_specs(cfg),
            "moe": moe_mod.moe_specs(cfg, quant=quant),
        }

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        h = apply_norm(cfg, x, p["ln_attn"])
        x = x + attn.self_attention(cfg, p["attn"], h, shard=shard, pos_offset=pos_offset)
        h = apply_norm(cfg, x, p["ln_moe"])
        y, aux = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, aux

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, (k, v) = attn.self_attention(cfg, p["attn"], h, shard=shard, return_kv=True)
        x = x + y
        h = apply_norm(cfg, x, p["ln_moe"])
        y, _ = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, attn.pack_kv_cache(cfg, k, v, max_len=max_len, window=None)

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_decode(cfg, p["attn"], h, cache, pos, shard=shard)
        x = x + y
        h = apply_norm(cfg, x, p["ln_moe"])
        y, _ = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, cache

    def decode_paged(self, cfg, p, x, cache, block_tables, context_lens, shard,
                     impl: str = "auto", kv_spec=None, block_pages=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_decode_paged(
            cfg, p["attn"], h, cache, block_tables, context_lens, shard=shard,
            impl=impl, kv_spec=kv_spec, block_pages=block_pages,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_moe"])
        y, _ = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, cache

    def prefill_chunk_paged(self, cfg, p, x, cache, block_tables, write_tables,
                            cursors, n_new, shard, impl: str = "auto", kv_spec=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_prefill_chunk_paged(
            cfg, p["attn"], h, cache, block_tables, write_tables, cursors, n_new,
            shard=shard, impl=impl, kv_spec=kv_spec,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_moe"])
        y, _ = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, cache

    def verify_paged(self, cfg, p, x, cache, block_tables, context_lens, shard,
                     impl: str = "auto", kv_spec=None):
        h = apply_norm(cfg, x, p["ln_attn"])
        y, cache = attn.self_attention_verify_paged(
            cfg, p["attn"], h, cache, block_tables, context_lens, shard=shard,
            impl=impl, kv_spec=kv_spec,
        )
        x = x + y
        h = apply_norm(cfg, x, p["ln_moe"])
        y, _ = moe_mod.apply_moe_dispatch(cfg, p["moe"], h, shard)
        return x + y, cache


class SSMBlock:
    def specs(self, cfg, quant=None):
        return {"ln": norm_specs(cfg), "ssm": ssm_mod.ssm_specs(cfg, quant=quant)}

    def cache_specs(self, cfg, batch: int, seq: int):
        return ssm_mod.ssm_cache_specs(cfg, batch)

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        h = apply_norm(cfg, x, p["ln"])
        return x + ssm_mod.apply_ssm(cfg, p["ssm"], h, shard=shard), jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        h = apply_norm(cfg, x, p["ln"])
        y, cache = ssm_mod.apply_ssm(cfg, p["ssm"], h, shard=shard, return_state=True)
        return x + y, cache

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        h = apply_norm(cfg, x, p["ln"])
        y, cache = ssm_mod.apply_ssm_decode(cfg, p["ssm"], h, cache, pos, shard=shard)
        return x + y, cache


class RecBlock:
    def specs(self, cfg, quant=None):
        return {
            "ln_rec": norm_specs(cfg),
            "rec": rg_mod.rglru_specs(cfg, quant=quant),
            "ln_mlp": norm_specs(cfg),
            "mlp": mlp_specs(cfg, quant=quant),
        }

    def cache_specs(self, cfg, batch: int, seq: int):
        return rg_mod.rglru_cache_specs(cfg, batch)

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        h = apply_norm(cfg, x, p["ln_rec"])
        x = x + rg_mod.apply_rglru(cfg, p["rec"], h, shard=shard)
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        h = apply_norm(cfg, x, p["ln_rec"])
        y, cache = rg_mod.apply_rglru(cfg, p["rec"], h, shard=shard, return_state=True)
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        h = apply_norm(cfg, x, p["ln_rec"])
        y, cache = rg_mod.apply_rglru_decode(cfg, p["rec"], h, cache, pos, shard=shard)
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, cache


class RGGroup:
    """RecurrentGemma's repeating unit: [rec, rec, local_attn]."""

    def __init__(self):
        self.rec = RecBlock()
        self.attn = DenseBlock(use_window=True)

    def specs(self, cfg, quant=None):
        return {
            "rec0": self.rec.specs(cfg, quant),
            "rec1": self.rec.specs(cfg, quant),
            "attn": self.attn.specs(cfg, quant),
        }

    def cache_specs(self, cfg, batch, seq):
        return {
            "rec0": self.rec.cache_specs(cfg, batch, seq),
            "rec1": self.rec.cache_specs(cfg, batch, seq),
            "attn": self.attn.cache_specs(cfg, batch, seq),
        }

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        x, _ = self.rec.train(cfg, p["rec0"], x, shard)
        x, _ = self.rec.train(cfg, p["rec1"], x, shard)
        x, _ = self.attn.train(cfg, p["attn"], x, shard, pos_offset=pos_offset)
        return x, jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        x, c0 = self.rec.prefill(cfg, p["rec0"], x, shard)
        x, c1 = self.rec.prefill(cfg, p["rec1"], x, shard)
        x, ca = self.attn.prefill(cfg, p["attn"], x, shard, max_len=max_len)
        return x, {"rec0": c0, "rec1": c1, "attn": ca}

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        x, c0 = self.rec.decode(cfg, p["rec0"], x, cache["rec0"], pos, shard)
        x, c1 = self.rec.decode(cfg, p["rec1"], x, cache["rec1"], pos, shard)
        x, ca = self.attn.decode(cfg, p["attn"], x, cache["attn"], pos, shard)
        return x, {"rec0": c0, "rec1": c1, "attn": ca}


class DecBlock:
    """Whisper decoder layer: causal self-attn + cross-attn (encoder ctx) + MLP."""

    def specs(self, cfg, quant=None):
        return {
            "ln_self": norm_specs(cfg),
            "self": attn.attn_specs(cfg, quant=quant),
            "ln_cross": norm_specs(cfg),
            "cross": attn.cross_attn_specs(cfg, quant=quant),
            "ln_mlp": norm_specs(cfg),
            "mlp": mlp_specs(cfg, quant=quant),
        }

    def cache_specs(self, cfg, batch: int, seq: int):
        return {
            "self": attn.cache_specs(cfg, batch, seq),
            "cross": attn.cache_specs(cfg, batch, cfg.enc_seq),
        }

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        h = apply_norm(cfg, x, p["ln_self"])
        x = x + attn.self_attention(cfg, p["self"], h, shard=shard, pos_offset=pos_offset)
        h = apply_norm(cfg, x, p["ln_cross"])
        x = x + attn.cross_attention(cfg, p["cross"], h, ctx, shard=shard)
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        h = apply_norm(cfg, x, p["ln_self"])
        y, (k, v) = attn.self_attention(cfg, p["self"], h, shard=shard, return_kv=True)
        x = x + y
        h = apply_norm(cfg, x, p["ln_cross"])
        y, (ck, cv) = attn.cross_attention(cfg, p["cross"], h, ctx, shard=shard, return_kv=True)
        x = x + y
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        dt = cfg.param_dtype
        return x, {
            "self": attn.pack_kv_cache(cfg, k, v, max_len=max_len, window=None),
            "cross": {"k": ck.astype(dt), "v": cv.astype(dt)},
        }

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        h = apply_norm(cfg, x, p["ln_self"])
        y, self_cache = attn.self_attention_decode(cfg, p["self"], h, cache["self"], pos, shard=shard)
        x = x + y
        h = apply_norm(cfg, x, p["ln_cross"])
        x = x + attn.cross_attention_decode(
            cfg, p["cross"], h, (cache["cross"]["k"], cache["cross"]["v"])
        )
        h = apply_norm(cfg, x, p["ln_mlp"])
        x = x + apply_mlp(cfg, p["mlp"], h, shard)
        return x, {"self": self_cache, "cross": cache["cross"]}


class VisGroup:
    """llama-3.2-vision unit: 4 dense self-attn layers + 1 gated cross-attn layer."""

    N_SELF = 4

    def __init__(self):
        self.dense = DenseBlock()

    def specs(self, cfg, quant=None):
        return {
            "self": stack_specs(self.dense.specs(cfg, quant), self.N_SELF),
            "ln_cross": norm_specs(cfg),
            "cross": attn.cross_attn_specs(cfg, quant=quant),
            "gate": TensorSpec((), (), dtype=jnp.float32, init="zeros"),
            "ln_mlp": norm_specs(cfg),
            "mlp": mlp_specs(cfg, quant=quant),
        }

    def cache_specs(self, cfg, batch, seq):
        return {
            "self": stack_specs(self.dense.cache_specs(cfg, batch, seq), self.N_SELF),
            "cross": attn.cache_specs(cfg, batch, cfg.n_img_tokens),
        }

    def _cross(self, cfg, p, x, ctx, shard, kv=None):
        h = apply_norm(cfg, x, p["ln_cross"])
        gate = jnp.tanh(p["gate"]).astype(x.dtype)
        if kv is not None:
            y = attn.cross_attention_decode(cfg, p["cross"], h, kv)
            x = x + gate * y
            h = apply_norm(cfg, x, p["ln_mlp"])
            return x + apply_mlp(cfg, p["mlp"], h, shard), None
        y, (ck, cv) = attn.cross_attention(cfg, p["cross"], h, ctx, shard=shard, return_kv=True)
        x = x + gate * y
        h = apply_norm(cfg, x, p["ln_mlp"])
        return x + apply_mlp(cfg, p["mlp"], h, shard), (ck, cv)

    def train(self, cfg, p, x, shard, ctx=None, pos_offset=0):
        def body(xc, pl):
            y, _ = self.dense.train(cfg, pl, xc, shard, pos_offset=pos_offset)
            return y, None

        x, _ = stack_scan(body, x, p["self"])
        x, _ = self._cross(cfg, p, x, ctx, shard)
        return x, jnp.float32(0)

    def prefill(self, cfg, p, x, shard, ctx=None, max_len=None):
        def body(xc, pl):
            return self.dense.prefill(cfg, pl, xc, shard, max_len=max_len)

        x, self_caches = stack_scan(body, x, p["self"])
        x, (ck, cv) = self._cross(cfg, p, x, ctx, shard)
        dt = cfg.param_dtype
        return x, {"self": self_caches, "cross": {"k": ck.astype(dt), "v": cv.astype(dt)}}

    def decode(self, cfg, p, x, cache, pos, shard, ctx=None):
        def body(xc, pc):
            pl, cl = pc
            return self.dense.decode(cfg, pl, xc, cl, pos, shard)

        x, self_caches = stack_scan(body, x, (p["self"], cache["self"]))
        kv = (cache["cross"]["k"], cache["cross"]["v"])
        x, _ = self._cross(cfg, p, x, None, shard, kv=kv)
        return x, {"self": self_caches, "cross": cache["cross"]}


KINDS: Dict[str, Any] = {
    "dense": DenseBlock(),
    "local_attn": DenseBlock(use_window=True),
    "enc": DenseBlock(causal=False),
    "moe": MoEBlock(),
    "ssm": SSMBlock(),
    "rec": RecBlock(),
    "rg_group": RGGroup(),
    "dec": DecBlock(),
    "vis_group": VisGroup(),
}


# =====================================================================================
# model programs
# =====================================================================================
def block_program(cfg) -> List[Tuple[str, int]]:
    if cfg.family in ("dense",):
        return [("dense", cfg.n_layers)]
    if cfg.family == "moe":
        return [("moe", cfg.n_layers)]
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        n_groups, rem = divmod(cfg.n_layers, len(cfg.pattern))
        prog: List[Tuple[str, int]] = [("rg_group", n_groups)]
        if rem:
            prog.append(("rec", rem))
        return prog
    if cfg.family == "vlm":
        assert cfg.n_layers % (VisGroup.N_SELF + 1) == 0
        return [("vis_group", cfg.n_layers // (VisGroup.N_SELF + 1))]
    if cfg.family == "encdec":
        return [("dec", cfg.n_layers)]
    raise ValueError(cfg.family)


def _sinusoidal(t: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# =====================================================================================
# Model
# =====================================================================================
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    quant: Optional[QuantizedAccessor] = None  # serving-weight accessor

    # ---- specs -----------------------------------------------------------------
    def param_specs(self):
        cfg = self.cfg
        specs: Dict[str, Any] = {"embed": embed_specs(cfg)}
        specs["blocks"] = [
            stack_specs(KINDS[k].specs(cfg, self.quant), n) for k, n in block_program(cfg)
        ]
        specs["final_norm"] = norm_specs(cfg)
        if cfg.family == "encdec":
            enc_cfg = dataclasses.replace(cfg, mlp_act="gelu")
            specs["encoder"] = {
                "blocks": [stack_specs(KINDS["enc"].specs(enc_cfg, self.quant), cfg.n_enc_layers)],
                "final_norm": norm_specs(cfg),
            }
        return specs

    def cache_specs(self, batch: int, seq: int):
        cfg = self.cfg
        return [
            stack_specs(KINDS[k].cache_specs(cfg, batch, seq), n)
            for k, n in block_program(cfg)
        ]

    def init_params(self, key):
        return tree_initialize(self.param_specs(), key)

    def init_cache(self, batch: int, seq: int):
        return tree_initialize(self.cache_specs(batch, seq), jax.random.key(0))

    # ---- context (stub frontends) ------------------------------------------------
    def encode_ctx(self, params, batch: Dict[str, jax.Array], shard=NULL_SHARDER):
        """Returns the cross-attention context: whisper = encoder(frames stub);
        vlm = the precomputed image embeddings; None otherwise."""
        cfg = self.cfg
        if cfg.family == "encdec":
            frames = batch["frames"]  # (B, enc_seq, D) — precomputed frame embeds
            x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
            enc_cfg = dataclasses.replace(cfg, mlp_act="gelu")

            def body(xc, pl):
                y, _ = KINDS["enc"].train(enc_cfg, pl, xc, shard)
                return y, None

            x, _ = stack_scan(body, x, params["encoder"]["blocks"][0])
            return apply_norm(cfg, x, params["encoder"]["final_norm"])
        if cfg.family == "vlm":
            return batch["image_embeds"]
        return None

    # ---- full-sequence forward ------------------------------------------------------
    def forward(
        self,
        params,
        tokens: jax.Array,
        *,
        ctx=None,
        shard: Sharder = NULL_SHARDER,
        remat: bool = True,
        remat_policy=None,
    ):
        cfg = self.cfg
        x = apply_embed(params["embed"], tokens)
        if cfg.family == "hybrid":  # gemma convention
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
        x = shard(x, "batch", "seq", None)
        aux_total = jnp.float32(0)
        for (kind, n), p in zip(block_program(cfg), params["blocks"]):
            blk = KINDS[kind]

            def body(carry, pl, _blk=blk):
                xc, aux = carry
                y, a = _blk.train(cfg, pl, xc, shard, ctx=ctx)
                return (y, aux + a), None

            if remat:
                body = jax.checkpoint(body, policy=remat_policy)
            (x, aux_total), _ = stack_scan(body, (x, aux_total), p)
        x = apply_norm(cfg, x, params["final_norm"])
        logits = apply_lm_head(cfg, params["embed"], x)
        logits = shard(logits, "batch", "seq", "vocab")
        return logits, aux_total

    def loss_fn(self, params, batch, *, shard=NULL_SHARDER, remat=True, remat_policy=None,
                aux_weight: float = 0.01):
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        ctx = self.encode_ctx(params, batch, shard)
        logits, aux = self.forward(
            params, inp, ctx=ctx, shard=shard, remat=remat, remat_policy=remat_policy
        )
        loss = cross_entropy(logits, labels, batch.get("mask"))
        return loss + aux_weight * aux, {"ce": loss, "aux": aux}

    # ---- serving -----------------------------------------------------------------
    def prefill(self, params, tokens: jax.Array, *, ctx=None, batch_inputs=None,
                shard: Sharder = NULL_SHARDER, max_len: Optional[int] = None,
                last_index=None):
        """``last_index`` (traced int32 scalar) reads the logits at that position
        instead of the static last column — the paged engine right-pads prompts
        to whole-page lengths so ONE compile serves every prompt in a page
        bucket, and the pad tail (causal: it attends backward only) never leaks
        into real positions' KV. Leave None for recurrent/hybrid families: their
        caches carry a final state that padding would pollute."""
        cfg = self.cfg
        if ctx is None and batch_inputs is not None:
            ctx = self.encode_ctx(params, batch_inputs, shard)
        x = apply_embed(params["embed"], tokens)
        if cfg.family == "hybrid":
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
        x = shard(x, "batch", "seq", None)
        caches = []
        for (kind, n), p in zip(block_program(cfg), params["blocks"]):
            blk = KINDS[kind]

            def body(xc, pl, _blk=blk):
                return _blk.prefill(cfg, pl, xc, shard, ctx=ctx, max_len=max_len)

            x, cache = stack_scan(body, x, p)
            caches.append(cache)
        x = apply_norm(cfg, x, params["final_norm"])
        if last_index is None:
            x_last = x[:, -1:]
        else:
            x_last = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
        logits = apply_lm_head(cfg, params["embed"], x_last)
        logits = shard(logits, "batch", "seq", "vocab")
        return logits, caches

    # ---- paged serving (continuous batching) -------------------------------------
    def paged_cache_specs(self, num_pages: int, page_size: int, kv_spec=None):
        cfg = self.cfg
        for kind, _ in block_program(cfg):
            if not hasattr(KINDS[kind], "paged_cache_specs"):
                raise NotImplementedError(
                    f"paged KV caching supports dense-attention blocks; got {kind!r}"
                )
        return [
            stack_specs(KINDS[k].paged_cache_specs(cfg, num_pages, page_size, kv_spec), n)
            for k, n in block_program(cfg)
        ]

    def init_paged_cache(self, num_pages: int, page_size: int, kv_spec=None):
        return tree_initialize(
            self.paged_cache_specs(num_pages, page_size, kv_spec), jax.random.key(0)
        )

    def decode_step_paged(self, params, caches, tokens: jax.Array,
                          block_tables: jax.Array, context_lens: jax.Array, *,
                          shard: Sharder = NULL_SHARDER, attn_impl: str = "auto",
                          kv_spec=None, write_tables=None, n_new=None,
                          last_index=None, active=None, block_pages=None,
                          spec_verify: bool = False):
        """The MIXED serving step: decode rows and prefill chunks are the same
        computation at different widths.

        tokens (B,): classic continuous-batching decode — block_tables
        (B, max_pages) int32, context_lens (B,) int32 per-sequence positions,
        caches per-layer page pools addressed through the shared block table
        (the LayoutPaged serving path). With ``kv_spec`` (PagedQuantSpec) the
        pools are intN {"q", "scale"} pytrees and decode runs the dequantizing
        kernel — same tables, same layout, different accessor.

        tokens (B, C): a prefill CHUNK per row — the chunk-view path
        (core/submdspan.py §chunk views). ``context_lens`` is then the chunk
        cursor (tokens resident before the chunk, page-aligned and TRACED, so
        one compile serves every chunk position of every prompt in the C
        bucket); ``write_tables`` routes the chunk's KV scatter (adopted
        shared-prefix pages nulled — the compute-skip regime reads them but
        never writes); ``n_new`` (B,) is the chunk's valid token count and
        ``last_index`` (B,) picks the logits row (the prompt's true last
        position when the chunk completes a prefill). Decode is the C == 1
        degenerate case; the split exists so decode keeps its one-token
        scatter-append (with the CoW contract) while chunks scatter whole
        pages.

        ``active`` (B,) int32/bool — decode path only — is the phase bitmap:
        rows with active == 0 (PREFILLING or empty slots in a mixed step) have
        their table row and length nulled ON DEVICE, so their lockstep write
        lands in the null page and the host never copies/patches the full
        tables to mask them. The engine's device-resident table/len mirrors
        stay untouched.

        ``spec_verify=True`` with tokens (B, C) is the speculative VERIFY step:
        C = K+1 rows of [current token, draft] appended and scored per block
        via verify_paged, ``context_lens`` the per-row resident length
        (NOT page-aligned), ``active`` honored as in decode, and the lm_head
        applied to ALL C rows — returns logits (B, C, Vp)."""
        cfg = self.cfg
        chunk = tokens.ndim == 2 and not spec_verify
        if active is not None and not chunk:
            block_tables = jnp.where(active[:, None] > 0, block_tables, 0)
            context_lens = jnp.where(active > 0, context_lens, 0)
        x = apply_embed(params["embed"], tokens if tokens.ndim == 2 else tokens[:, None])
        if cfg.family == "hybrid":
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
        new_caches = []
        for (kind, n), p, cache in zip(block_program(cfg), params["blocks"], caches):
            blk = KINDS[kind]

            if chunk:
                def body(xc, pc, _blk=blk):
                    pl, cl = pc
                    return _blk.prefill_chunk_paged(
                        cfg, pl, xc, cl, block_tables, write_tables,
                        context_lens, n_new, shard, impl=attn_impl,
                        kv_spec=kv_spec,
                    )
            elif spec_verify:
                def body(xc, pc, _blk=blk):
                    pl, cl = pc
                    return _blk.verify_paged(
                        cfg, pl, xc, cl, block_tables, context_lens, shard,
                        impl=attn_impl, kv_spec=kv_spec,
                    )
            else:
                def body(xc, pc, _blk=blk):
                    pl, cl = pc
                    return _blk.decode_paged(
                        cfg, pl, xc, cl, block_tables, context_lens, shard,
                        impl=attn_impl, kv_spec=kv_spec, block_pages=block_pages,
                    )

            x, cache = stack_scan(body, x, (p, cache))
            new_caches.append(cache)
        x = apply_norm(cfg, x, params["final_norm"])
        if spec_verify:
            # every row of the verify window needs its logits: row j decides
            # the fate of draft token j+1 (and the last row the bonus token)
            logits = apply_lm_head(cfg, params["embed"], x)
            return logits, new_caches
        if chunk:
            # read hidden state only at each row's requested position before
            # the lm_head: the chunk's other C-1 rows never pay the vocab matmul
            x = jnp.take_along_axis(
                x, jnp.asarray(last_index, jnp.int32)[:, None, None], axis=1
            )
        logits = apply_lm_head(cfg, params["embed"], x)
        return logits[:, 0], new_caches

    def decode_step(self, params, caches, tokens: jax.Array, pos, *,
                    shard: Sharder = NULL_SHARDER):
        """tokens: (B,) current token ids; pos: traced int32 scalar position."""
        cfg = self.cfg
        x = apply_embed(params["embed"], tokens[:, None])
        if cfg.family == "hybrid":
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
        new_caches = []
        for (kind, n), p, cache in zip(block_program(cfg), params["blocks"], caches):
            blk = KINDS[kind]

            def body(xc, pc, _blk=blk):
                pl, cl = pc
                return _blk.decode(cfg, pl, xc, cl, pos, shard, ctx=None)

            x, cache = stack_scan(body, x, (p, cache))
            new_caches.append(cache)
        x = apply_norm(cfg, x, params["final_norm"])
        logits = apply_lm_head(cfg, params["embed"], x)
        return logits[:, 0], new_caches
