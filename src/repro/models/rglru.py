"""RG-LRU recurrent block (RecurrentGemma/Griffin): conv1d + gated linear recurrence.

Train/prefill run the recurrence as an associative scan (log-depth, TPU-friendly —
the recurrence h_t = a_t h_{t-1} + b_t is exactly the first-order linear form
jax.lax.associative_scan composes). Decode is an O(1) state update.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.distributed import TensorSpec

from .layers import NULL_SHARDER, Sharder

RG_C = 8.0


def rglru_specs(cfg, *, quant=None) -> Dict[str, TensorSpec]:
    d, w = cfg.d_model, cfg.lru_width
    dt = cfg.param_dtype
    return {
        "w_x": TensorSpec((d, w), ("embed", "lru"), dtype=dt),
        "w_y": TensorSpec((d, w), ("embed", "lru"), dtype=dt),
        "conv_w": TensorSpec((cfg.conv_kernel, w), (None, "lru"), dtype=dt, init="fan_in"),
        "conv_b": TensorSpec((w,), ("lru",), dtype=jnp.float32, init="zeros"),
        "w_input_gate": TensorSpec((w, w), ("lru", "lru_gate"), dtype=dt),
        "b_input_gate": TensorSpec((w,), ("lru_gate",), dtype=jnp.float32, init="zeros"),
        "w_a_gate": TensorSpec((w, w), ("lru", "lru_gate"), dtype=dt),
        "b_a_gate": TensorSpec((w,), ("lru_gate",), dtype=jnp.float32, init="zeros"),
        "a_param": TensorSpec((w,), ("lru",), dtype=jnp.float32, init="ones"),
        "w_out": TensorSpec((w, d), ("lru", "embed"), dtype=dt),
    }


def rglru_cache_specs(cfg, batch: int) -> Dict[str, TensorSpec]:
    w = cfg.lru_width
    return {
        "h": TensorSpec((batch, w), ("batch", "lru"), dtype=jnp.float32, init="zeros"),
        "conv": TensorSpec(
            (batch, cfg.conv_kernel - 1, w), ("batch", None, "lru"), dtype=cfg.param_dtype, init="zeros"
        ),
    }


def _causal_conv(xb, w, b):
    k = w.shape[0]
    acc = jnp.zeros_like(xb, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(xb, ((0, 0), (shift, 0), (0, 0)))[:, : xb.shape[1], :]
        acc = acc + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (acc + b).astype(xb.dtype)


def _gates(p, xc):
    ig = jnp.matmul(xc, p["w_input_gate"].astype(xc.dtype)) + p["b_input_gate"].astype(xc.dtype)
    ag = jnp.matmul(xc, p["w_a_gate"].astype(xc.dtype)) + p["b_a_gate"].astype(xc.dtype)
    return ig, ag


def _log_a(p, ag):
    return (
        -RG_C
        * jax.nn.softplus(p["a_param"].astype(jnp.float32))[None, None, :]
        * jax.nn.sigmoid(ag.astype(jnp.float32))
    )


def apply_rglru(
    cfg, p, x: jax.Array, *, shard: Sharder = NULL_SHARDER,
    initial_state=None, return_state: bool = False,
):
    """x: (B, S, D) -> (B, S, D)."""
    xb = jnp.matmul(x, p["w_x"].astype(x.dtype))
    yb = jax.nn.gelu(jnp.matmul(x, p["w_y"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    xb_raw = xb
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
    ig, ag = _gates(p, xc)
    a = jnp.exp(_log_a(p, ag))
    gated = jax.nn.sigmoid(ig.astype(jnp.float32)) * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    if initial_state is not None:
        # fold h0 into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * initial_state.astype(jnp.float32))

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = shard(h.astype(x.dtype), "batch", "seq", "lru")
    out = jnp.matmul(h * yb, p["w_out"].astype(x.dtype))
    if return_state:
        conv_state = xb_raw[:, -(cfg.conv_kernel - 1) :, :]
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return out


def apply_rglru_decode(cfg, p, x: jax.Array, cache, pos, *, shard: Sharder = NULL_SHARDER):
    """x: (B, 1, D); cache {"h": (B, W) f32, "conv": (B, K-1, W)}."""
    xb = jnp.matmul(x[:, 0], p["w_x"].astype(x.dtype))  # (B, W)
    yb = jax.nn.gelu(jnp.matmul(x[:, 0], p["w_y"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    k = cfg.conv_kernel
    conv = p["conv_b"].astype(jnp.float32) + xb.astype(jnp.float32) * p["conv_w"][k - 1].astype(jnp.float32)
    for i in range(k - 1):
        conv = conv + cache["conv"][:, i].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    new_conv = jnp.concatenate([cache["conv"][:, 1:], xb[:, None].astype(cache["conv"].dtype)], axis=1)
    xc = conv.astype(x.dtype)
    ig = jnp.matmul(xc, p["w_input_gate"].astype(xc.dtype)) + p["b_input_gate"].astype(xc.dtype)
    ag = jnp.matmul(xc, p["w_a_gate"].astype(xc.dtype)) + p["b_a_gate"].astype(xc.dtype)
    log_a = (
        -RG_C
        * jax.nn.softplus(p["a_param"].astype(jnp.float32))[None, :]
        * jax.nn.sigmoid(ag.astype(jnp.float32))
    )
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(ig.astype(jnp.float32)) * xc.astype(jnp.float32)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    out = jnp.matmul(h.astype(x.dtype) * yb, p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"h": h, "conv": new_conv}
