"""ModelConfig — one dataclass covering all 10 assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None  # local attention window (hybrid archs)
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rec","rec","attn")
    pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    # encoder-decoder (whisper): n_layers == decoder layers
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame embeddings fed by the stub frontend
    # vlm (llama-3.2-vision): every `cross_every`-th layer is cross-attention
    cross_every: int = 0
    n_img_tokens: int = 0
    # numerics / embedding
    dtype: str = "bfloat16"
    vocab_pad_to: int = 256
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, self.vocab_pad_to)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    # ssm derived
    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    @property
    def ssm_conv_dim(self) -> int:
        return self.ssm_dinner + 2 * self.ssm_ngroups * self.ssm_state

    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (long_500k shape)?"""
        return self.family in ("ssm", "hybrid")

    def has_decode(self) -> bool:
        return True  # no encoder-only archs in the assigned pool

    # approximate parameter counts for MODEL_FLOPS = 6·N·D (see benchmarks/roofline)
    def param_count(self, active_only: bool = False) -> int:
        from . import registry

        return registry.count_params(self, active_only=active_only)
