"""Model zoo: 10 assigned architectures on the TensorSpec (mdspan-descriptor) system."""
from .config import ModelConfig
from .registry import ARCH_IDS, build_model, count_params, get_config
from .transformer import Model, block_program

__all__ = [
    "ModelConfig",
    "ARCH_IDS",
    "build_model",
    "count_params",
    "get_config",
    "Model",
    "block_program",
]
