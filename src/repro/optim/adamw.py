"""AdamW with optional int8 (QuantizedAccessor) moment storage.

The 8-bit optimizer is the paper's accessor concept applied at cluster scale:
the m/v moments are mdspans whose accessor is ``QuantizedAccessor(int8, block)``;
the update dequantizes at the compute boundary and re-encodes (fresh per-block
scales each step — ``quantize_array``'s blockwise absmax). This is what makes the
kimi-k2 (1T-param) training cell fit 512 × 16 GB chips (DESIGN.md §3).

Moment TensorSpecs inherit each parameter's logical axes, so optimizer state is
sharded exactly like its parameter (ZeRO-compatible by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import (
    TensorSpec,
    dequantize_array,
    is_spec,
    quantize_array,
)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable[[jax.Array], jax.Array]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    int8_state: bool = False
    state_block: int = 64

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)


def _moment_spec(pspec: TensorSpec, opt: AdamWConfig) -> TensorSpec:
    """Moment spec mirrors the parameter's shape/axes; int8-quantized when enabled
    and the trailing dim is block-divisible (tiny tensors stay f32)."""
    if (
        opt.int8_state
        and pspec.shape
        and pspec.shape[-1] % opt.state_block == 0
        and not pspec.is_quantized()
    ):
        acc = QuantizedAccessor(jnp.float32, bits=8, block=opt.state_block)
        return TensorSpec(pspec.shape, pspec.logical_axes, dtype=jnp.float32, init="zeros", accessor=acc)
    return TensorSpec(pspec.shape, pspec.logical_axes, dtype=jnp.float32, init="zeros")


def adamw_init_specs(param_specs, opt: AdamWConfig):
    """Optimizer-state TensorSpec tree: {"m": ..., "v": ..., "step": scalar}."""
    m = jax.tree.map(lambda s: _moment_spec(s, opt), param_specs, is_leaf=is_spec)
    v = jax.tree.map(lambda s: _moment_spec(s, opt), param_specs, is_leaf=is_spec)
    return {
        "m": m,
        "v": v,
        "step": TensorSpec((), (), dtype=jnp.int32, init="zeros"),
    }


_V_FLOOR = 1e-12
_V_SHIFT = 27.631021  # -log(_V_FLOOR): a zero-initialized buffer decodes to v == 0


def _decode_moment(buf, spec: TensorSpec, *, log_domain: bool = False):
    if isinstance(buf, dict):  # quantized
        val = dequantize_array(buf, spec.accessor)
        if log_domain:
            return jnp.maximum(jnp.exp(val - _V_SHIFT) - _V_FLOOR, 0.0)
        return val
    return buf


def _encode_moment(val, spec: TensorSpec, *, log_domain: bool = False):
    """int8 moments. m is zero-mean → linear symmetric quantization is fine.
    v spans orders of magnitude within a block (linear quant zeroes the small
    entries → the Adam denominator collapses and training diverges — observed,
    tests/test_optim.py). v is therefore stored in LOG domain: a 0.2-step in
    log space is a bounded ~20% relative error on v and can never produce 0.
    """
    if spec.is_quantized():
        if log_domain:
            val = jnp.log(val + _V_FLOOR) + _V_SHIFT  # >= 0; zeros stay zeros
        return quantize_array(val, spec.accessor)
    return val


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state, param_specs, state_specs, opt: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics).

    params may be bf16 (they act as the master copy when int8_state is on —
    documented precision trade-off) — update math is f32 throughout.
    """
    step = state["step"] + 1
    grads, gnorm = (
        clip_by_global_norm(grads, opt.grad_clip) if opt.grad_clip else (grads, jnp.float32(0))
    )
    lr = opt.lr_at(step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    p_leaves, treedef = jax.tree.flatten(params, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    ps_leaves = treedef.flatten_up_to(param_specs)
    ms_leaves = treedef.flatten_up_to(state_specs["m"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v, pspec, mspec in zip(
        p_leaves, g_leaves, m_leaves, v_leaves, ps_leaves, ms_leaves
    ):
        gf = g.astype(jnp.float32)
        mf = _decode_moment(m, mspec)
        vf = _decode_moment(v, mspec, log_domain=True)
        mf = b1 * mf + (1 - b1) * gf
        vf = b2 * vf + (1 - b2) * gf * gf
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + opt.eps)
        pf = p.astype(jnp.float32)
        if opt.weight_decay and pf.ndim >= 2:  # no decay on norms/biases/scalars
            update = update + opt.weight_decay * pf
        pf = pf - lr * update
        new_p.append(pf.astype(p.dtype))
        new_m.append(_encode_moment(mf, mspec))
        new_v.append(_encode_moment(vf, mspec, log_domain=True))

    params = jax.tree.unflatten(treedef, new_p)
    state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return params, state, {"grad_norm": gnorm, "lr": lr}
