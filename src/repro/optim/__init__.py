from .adamw import AdamWConfig, adamw_init_specs, adamw_update, clip_by_global_norm
from .schedules import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init_specs",
    "adamw_update",
    "clip_by_global_norm",
    "constant",
    "warmup_cosine",
]
