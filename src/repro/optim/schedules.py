"""LR schedules (callables of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)

    return f


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)

    return f
