"""Checkpoint store: atomic, resumable, reshard-on-load (elastic) checkpoints.

Layout:  <dir>/step_<N>/{manifest.json, <leaf>.npy..., COMMIT}

Properties engineered for the fault-tolerance story (runtime/loop.py):
  * atomic commit — leaves write into step_<N>.tmp, a COMMIT marker + rename make
    the step visible; a crash mid-save never corrupts the latest checkpoint;
  * reshard-on-load — ``restore(dir, target)`` device_puts every leaf onto the
    sharding of the TARGET ShapeDtypeStructs, so a checkpoint written on one mesh
    restores onto any other (elastic re-mesh after node loss: rebuild the mesh,
    rebuild specs, restore);
  * async save — a background thread serializes while training continues (the
    caller passes already-fetched numpy or lets us block on device_get);
  * keep-N garbage collection.

Multi-host note: this store writes full logical arrays (process_count == 1 in
this container). On a real cluster each host writes its addressable shards;
``restore``'s reshard-on-load path is unchanged because it only depends on the
target shardings.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't cast to/from ml_dtypes types through .astype on load; round-trip
# them through a same-width integer view with the logical dtype in the manifest
_VIEW_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16)}

_LEAF_RX = re.compile(r"[^a-zA-Z0-9_.-]+")


def _leaf_name(path) -> str:
    name = jax.tree_util.keystr(path)
    return _LEAF_RX.sub("_", name).strip("_")[:180]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [_leaf_name(p) for p, _ in leaves]
    assert len(set(names)) == len(names), "leaf name collision"
    return names, [v for _, v in leaves], treedef


def save(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical][0])
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": logical}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and (d / "COMMIT").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target: Any) -> Any:
    """Load step; every leaf is device_put onto the sharding of the corresponding
    TARGET leaf (ShapeDtypeStruct or array) — reshard-on-load."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    manifest = {l["name"]: l for l in json.loads((d / "manifest.json").read_text())["leaves"]}
    names, targets, treedef = _flatten(target)
    out = []
    for name, tgt in zip(names, targets):
        arr = np.load(d / f"{name}.npy")
        logical = manifest.get(name, {}).get("dtype", str(arr.dtype))
        if logical in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical][1])
        want_dtype = getattr(tgt, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            if str(want_dtype) in _VIEW_DTYPES or str(arr.dtype) in _VIEW_DTYPES:
                arr = np.asarray(jax.device_get(jax.numpy.asarray(arr).astype(want_dtype)))
            else:
                arr = arr.astype(want_dtype)
        sharding = getattr(tgt, "sharding", None)
        if sharding is not None:
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.device_put(arr))
    leaves_only = jax.tree_util.tree_unflatten(treedef, out)
    return leaves_only


class CheckpointManager:
    """Async save + keep-N retention + resume discovery."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _do_save(self, step, host_tree):
        try:
            save(self.dir, step, host_tree)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def save(self, step: int, tree: Any):
        self.wait()
        # fetch to host synchronously (cheap vs serialize), serialize async
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(target=self._do_save, args=(step, host_tree))
            self._thread.start()
        else:
            self._do_save(step, host_tree)

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1])
            for d in self.dir.iterdir()
            if d.is_dir() and d.name.startswith("step_") and (d / "COMMIT").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore(self, step: int, target: Any) -> Any:
        return restore(self.dir, step, target)
