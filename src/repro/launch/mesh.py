"""Production meshes. Functions, not module constants — importing this module never
touches jax device state (dryrun.py must set XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch/token dims shard over (pod composes with data)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)
