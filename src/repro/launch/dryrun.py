"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell, record
memory/cost/collective analyses for the roofline (EXPERIMENTS.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k

The FIRST two lines below must run before ANY other import (jax locks the device
count on first init); smoke tests and benches must NOT import this module.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.shapes import SHAPES, cell_is_applicable
from repro.core.distributed import tree_shape_structs
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import rules_for
from repro.models import ARCH_IDS, build_model, count_params, get_config
from repro.optim import AdamWConfig
from repro.serving import make_serve_step
from repro.train import TrainProfile, make_train_step

# ------------------------------------------------------------------------------------
# per-arch training profiles (microbatching & 8-bit optimizer state where memory
# demands it — see DESIGN.md §3 and the roofline notes)
# ------------------------------------------------------------------------------------
TRAIN_PROFILES = {
    "kimi-k2-1t-a32b": dict(
        opt=AdamWConfig(int8_state=True, state_block=64),
        profile=TrainProfile(num_microbatches=8, accum_dtype=jnp.bfloat16),
    ),
    "llama-3.2-vision-90b": dict(
        opt=AdamWConfig(), profile=TrainProfile(num_microbatches=8)
    ),
    "dbrx-132b": dict(
        opt=AdamWConfig(int8_state=True, state_block=64),
        profile=TrainProfile(num_microbatches=4),
    ),
    "_default": dict(opt=AdamWConfig(), profile=TrainProfile(num_microbatches=1)),
}


def train_profile_for(arch: str):
    d = TRAIN_PROFILES.get(arch, TRAIN_PROFILES["_default"])
    return d["opt"], d["profile"]


# ------------------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable, no alloc)
# ------------------------------------------------------------------------------------
def input_specs(cfg, shape, mesh, rules):
    """Model inputs for one cell as sharded ShapeDtypeStructs."""
    bsh = rules.sharding(("batch", None), (shape.batch, shape.seq), mesh)
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((shape.batch, shape.seq + 1), jnp.int32, sharding=bsh)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32, sharding=bsh)
    else:  # decode
        tsh = rules.sharding(("batch",), (shape.batch,), mesh)
        specs["tokens"] = jax.ShapeDtypeStruct((shape.batch,), jnp.int32, sharding=tsh)
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, PartitionSpec()))
    if cfg.family == "encdec" and shape.kind != "decode":
        fsh = rules.sharding(("batch", None, None), (shape.batch, cfg.enc_seq, cfg.d_model), mesh)
        specs["frames"] = jax.ShapeDtypeStruct(
            (shape.batch, cfg.enc_seq, cfg.d_model), cfg.param_dtype, sharding=fsh
        )
    if cfg.family == "vlm" and shape.kind != "decode":
        ish = rules.sharding(("batch", None, None), (shape.batch, cfg.n_img_tokens, cfg.d_model), mesh)
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (shape.batch, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype, sharding=ish
        )
    return specs


# ------------------------------------------------------------------------------------
# collective-bytes extraction from post-SPMD HLO
# ------------------------------------------------------------------------------------
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RX = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RX = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RX = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RX = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RX.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RX.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RX.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return world


# per-device bytes moved over links, ring-algorithm estimates
_RING_FACTOR = {
    "all-gather": lambda n: n - 1,          # operand is the local shard
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1,
}


def collective_stats(hlo_text: str, world: int):
    """Per-op collective stats + a TPU-corrected total.

    Correction: XLA:CPU legalizes bf16 dots to f32 and places the convert AFTER
    the collective, so activation all-reduces appear at 2x their TPU volume
    (verified with a minimal sharded bf16 matmul — EXPERIMENTS.md §Methodology).
    ``moved_bytes_tpu`` halves the f32 collective volume to model the bf16-native
    TPU lowering; both raw and corrected totals are recorded.
    """
    per_op = {k: {"count": 0, "result_bytes": 0, "moved_bytes": 0.0} for k in _COLL_OPS}
    f32_moved = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RX.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if f" {op}(" not in line and f" {op}-start(" not in line:
            # op name also matches -start/-done variants; count starts only
            if f"{op}-done" in line:
                continue
        rb = _shape_bytes(type_str)
        n = max(_group_size(line, world), 1)
        if op == "all-gather":
            # operand bytes = result / n; moved = operand * (n-1) ≈ result*(n-1)/n
            moved = rb * (n - 1) / n
        else:
            moved = rb * _RING_FACTOR[op](n)
        d = per_op[op]
        d["count"] += 1
        d["result_bytes"] += rb
        d["moved_bytes"] += moved
        if "f32[" in type_str:
            f32_moved += moved
    total_moved = sum(d["moved_bytes"] for d in per_op.values())
    return {
        "per_op": per_op,
        "moved_bytes_per_device": total_moved,
        "moved_bytes_f32": f32_moved,
        "moved_bytes_tpu": total_moved - f32_moved / 2,
    }


# ------------------------------------------------------------------------------------
# depth probes: XLA cost analysis counts while-loop (lax.scan) bodies ONCE, so the
# full-module numbers undercount layer compute by ~L×. We compile two UNROLLED
# shallow probes (1 and 2 depth units), fit  metric(L) = a + L·b,  and extrapolate
# to the true depth. Memory analysis comes from the FULL compile (buffer assignment
# is exact); flops/bytes/collectives come from the probes.
# ------------------------------------------------------------------------------------
def cfg_with_depth_units(cfg, units: int):
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=len(cfg.pattern) * units)
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, n_layers=5 * units)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=units, n_enc_layers=units)
    return dataclasses.replace(cfg, n_layers=units)


def depth_units(cfg) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers / len(cfg.pattern)  # fractional remainder approximated
    if cfg.family == "vlm":
        return cfg.n_layers / 5
    return float(cfg.n_layers)


# ------------------------------------------------------------------------------------
# cell lowering
# ------------------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, *, seq_shard: bool = False,
               remat_policy=None, extra_rules=None, cfg_override=None,
               force_single_microbatch: bool = False, quantized: bool = False):
    """Returns (jitted_fn, example_args_structs) for one cell."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    quantized = quantized and shape.kind != "train"
    rules = rules_for(cfg, shape.kind, seq_shard=seq_shard, quantized=quantized)
    if extra_rules:
        rules = dataclasses.replace(rules, rules={**rules.rules, **extra_rules})
    model = build_model(cfg, quantized=quantized)

    if shape.kind == "train":
        opt, profile = train_profile_for(arch)
        if remat_policy is not None:
            profile = dataclasses.replace(profile, remat_policy=remat_policy)
        if force_single_microbatch:
            profile = dataclasses.replace(profile, num_microbatches=1)
        step, pspecs, sspecs = make_train_step(model, opt, profile, mesh=mesh, rules=rules)
        params = tree_shape_structs(pspecs, mesh, rules)
        opt_state = tree_shape_structs(sspecs, mesh, rules)
        batch = input_specs(cfg, shape, mesh, rules)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (params, opt_state, batch)

    if shape.kind == "prefill":
        from repro.serving import make_prefill

        prefill = make_prefill(model, mesh=mesh, rules=rules, max_len=shape.seq)
        pspecs = model.param_specs()
        params = tree_shape_structs(pspecs, mesh, rules)
        specs = input_specs(cfg, shape, mesh, rules)
        tokens = specs.pop("tokens")
        binputs = specs if specs else None

        def fn(params, tokens, binputs=None):
            return prefill(params, tokens, binputs)

        return jax.jit(fn), (params, tokens, binputs)

    # decode
    serve = make_serve_step(model, mesh=mesh, rules=rules)
    pspecs = model.param_specs()
    params = tree_shape_structs(pspecs, mesh, rules)
    cache_specs = model.cache_specs(shape.batch, shape.seq)
    caches = tree_shape_structs(cache_specs, mesh, rules)
    specs = input_specs(cfg, shape, mesh, rules)
    fn = jax.jit(serve, donate_argnums=(1,))
    return fn, (params, caches, specs["tokens"], specs["pos"])


def _probe_metrics(arch, shape_name, mesh, world, units, **build_kw):
    """Compile one UNROLLED shallow variant; return (flops, bytes, coll_moved)."""
    from repro.models import transformer as tf

    cfg = cfg_with_depth_units(get_config(arch), units)
    tf.set_scan_unroll(True)
    try:
        fn, args = build_cell(
            arch, shape_name, mesh, cfg_override=cfg,
            force_single_microbatch=True, **build_kw,
        )
        args = [a for a in args if a is not None]
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    finally:
        tf.set_scan_unroll(False)
    cost = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text(), world)
    return (
        float(cost.get("flops", 0)),
        float(cost.get("bytes accessed", 0)),
        float(coll["moved_bytes_tpu"]),
    )


def extrapolated_metrics(arch, shape_name, mesh, world, **build_kw):
    """Fit metric(L) = a + L·b from unrolled probes at depth units 1 and 2."""
    f1, b1, c1 = _probe_metrics(arch, shape_name, mesh, world, 1, **build_kw)
    f2, b2, c2 = _probe_metrics(arch, shape_name, mesh, world, 2, **build_kw)
    L = depth_units(get_config(arch))

    def fit(m1, m2):
        slope = m2 - m1
        return max(m1 - slope, 0.0) + L * slope

    return {
        "flops_per_device": fit(f1, f2),
        "bytes_per_device": fit(b1, b2),
        "collective_moved_bytes_per_device": fit(c1, c2),
        "probe": {"units": [1, 2], "flops": [f1, f2], "bytes": [b1, b2], "coll": [c1, c2],
                  "depth_units": L},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, save_hlo: bool = False, tag: str = "", probes: bool = True, **build_kw):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{cell_id}.json"
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag, "ok": False}
    try:
        cfg = get_config(arch)
        if not cell_is_applicable(cfg, shape_name):
            result.update(ok=True, skipped=True, reason="full-attention arch: long_500k inapplicable")
            out_path.write_text(json.dumps(result, indent=1))
            print(f"[dryrun] SKIP {cell_id}")
            return result
        mesh = make_production_mesh(multi_pod=multi_pod)
        world = 512 if multi_pod else 256
        with mesh:
            fn, args = build_cell(arch, shape_name, mesh, **build_kw)
            args = [a for a in args if a is not None]
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # pragma: no cover
            mem_d = {"error": str(e)}
        hlo = compiled.as_text()
        coll = collective_stats(hlo, world)
        result.update(
            ok=True,
            world=world,
            seconds={"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
            cost_keys={k: float(v) for k, v in cost.items() if isinstance(v, (int, float)) and len(k) < 40},
            memory=mem_d,
            collectives=coll,
            params_total=count_params(cfg),
            params_active=count_params(cfg, active_only=True),
        )
        if probes:
            result["extrapolated"] = extrapolated_metrics(
                arch, shape_name, mesh, world, **build_kw
            )
        if save_hlo:
            (out_dir / f"{cell_id}.hlo.txt").write_text(hlo)
    except Exception as e:
        result.update(error=str(e)[:2000], traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] FAIL {cell_id}: {e}")
    out_path.write_text(json.dumps(result, indent=1))
    if result.get("ok") and not result.get("skipped"):
        print(
            f"[dryrun] OK   {cell_id} compile={result['seconds']['compile']}s "
            f"flops={result['flops']:.3g} coll={coll['moved_bytes_per_device']:.3g}B"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--remat-policy", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
                if args.skip_existing and (out_dir / f"{cell}.json").exists():
                    prev = json.loads((out_dir / f"{cell}.json").read_text())
                    if prev.get("ok"):
                        print(f"[dryrun] CACHED {cell}")
                        continue
                r = run_cell(
                    arch, shape, mp, out_dir, save_hlo=args.save_hlo, tag=args.tag,
                    seq_shard=args.seq_shard, remat_policy=args.remat_policy,
                    quantized=args.quantized,
                )
                n_fail += 0 if r.get("ok") else 1
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
