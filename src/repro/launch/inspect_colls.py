"""Inspect the collectives of a depth-1 probe module: shapes, groups, origin.

Usage: PYTHONPATH=src python -m repro.launch.inspect_colls ARCH SHAPE [--units 1]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import re
import sys

import jax

from repro.launch.dryrun import (
    _shape_bytes,
    build_cell,
    cfg_with_depth_units,
    collective_stats,
)
from repro.launch.mesh import make_production_mesh
from repro.models import get_config
from repro.models import transformer as tf

_OP_RX = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\("
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--units", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cfg = cfg_with_depth_units(get_config(args.arch), args.units)
    tf.set_scan_unroll(True)
    with mesh:
        fn, cell_args = build_cell(
            args.arch, args.shape, mesh, cfg_override=cfg,
            force_single_microbatch=True, seq_shard=args.seq_shard,
        )
        cell_args = [a for a in cell_args if a is not None]
        compiled = fn.lower(*cell_args).compile()
    hlo = compiled.as_text()
    rows = []
    for line in hlo.splitlines():
        m = _OP_RX.search(line)
        if not m:
            continue
        ty, op = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', line)
        if mm:
            meta = mm.group(1)[-110:]
        rows.append((b, op, ty[:60], meta))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"{len(rows)} collectives, total result bytes {total/1e9:.2f} GB")
    for b, op, ty, meta in rows[: args.top]:
        print(f"{b/1e9:9.3f}GB {op:18s} {ty:62s} {meta}")


if __name__ == "__main__":
    main()
