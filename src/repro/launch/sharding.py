"""Per-(arch × shape-kind) sharding policies: logical axis name → mesh axes.

One ShardingRules table IS the parallelism configuration (DESIGN.md §3):

  DP    "batch"/"tokens" → ("pod","data")
  FSDP  "embed" (the non-TP dim of weight matrices) → ("pod","data"); moments and
        grads inherit it (adamw moment specs copy the param's logical axes)
  TP    "heads"/"kv_heads"/"ffn"/"vocab"/"lru"/"ssm_*" → "model"
  EP    "expert" → "model" (token all-to-all at the dispatch boundary)
  SP    "seq" → "model" (long-context / activation sharding; off by default)
  cache "kv_seq" → "model" for serving (caches shard the sequence dim so archs
        whose kv_heads don't divide the model axis still scale; DUS writes stay
        shard-local under GSPMD)

Divisibility fallbacks happen inside ShardingRules.binding_for (replicate the
offending dim), so one table serves all 10 architectures.
"""
from __future__ import annotations

from typing import Dict

from repro.core.distributed import ShardingRules

BATCH = ("pod", "data")  # binding_for drops absent mesh axes automatically


def train_rules(cfg, *, fsdp: bool = True, seq_shard: bool = False) -> ShardingRules:
    rules: Dict[str, object] = {
        # data / tokens
        "batch": BATCH,
        "tokens": BATCH,
        "seq": "model" if seq_shard else None,
        # tensor parallel
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "lru": "model",
        "lru_gate": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_conv": "model",
        # expert parallel
        "expert": "model",
        "expert_ffn": None,
        # fsdp (ZeRO-3): shard the non-TP weight dim over the batch axes
        "embed": BATCH if fsdp else None,
        # caches (unused in training)
        "kv_seq": None,
        "layers": None,
    }
    return ShardingRules(rules)


def serve_rules(cfg, *, fsdp_params: bool = False) -> ShardingRules:
    rules: Dict[str, object] = {
        "batch": BATCH,
        "tokens": BATCH,
        "seq": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": None,  # caches shard the seq dim instead (uniform across archs)
        "kv_seq": "model",
        "ffn": "model",
        "lru": "model",
        "lru_gate": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_conv": "model",
        "expert": "model",
        "expert_ffn": None,
        "embed": BATCH if fsdp_params else None,
        "layers": None,
    }
    return ShardingRules(rules)


def needs_fsdp_for_serving(cfg, *, quantized: bool = False) -> bool:
    """Does TP-16 alone leave >8 GB of weights per chip? (kimi-k2: yes; dbrx only
    in bf16 — int8 QuantizedAccessor weights fit TP-16 and kill the FSDP gathers,
    §Perf hillclimb #2)."""
    from repro.models import count_params

    bytes_per_param = 1.07 if quantized else 2.0  # int8 + per-block f32 scales
    approx_tp_bytes = count_params(cfg) * bytes_per_param / 16
    # 16 GB HBM - ~3 GB cache - ~2 GB activations/temp -> ~11 GB weight budget
    return approx_tp_bytes > 11e9


def rules_for(cfg, shape_kind: str, *, seq_shard: bool = False,
              quantized: bool = False) -> ShardingRules:
    if shape_kind == "train":
        return train_rules(cfg, seq_shard=seq_shard)
    return serve_rules(cfg, fsdp_params=needs_fsdp_for_serving(cfg, quantized=quantized))
