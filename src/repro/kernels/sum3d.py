"""Sum3D — the paper's "simplest possible" benchmark, as a layout-generic Pallas kernel.

The algorithm (sum every entry) is layout-agnostic; the *kernel schedule* is derived
from the LayoutMapping at trace time:

  * LayoutRight  → physical (I, J, K); lanes run over K (fast dim last) — natural.
  * LayoutLeft   → physical (K, J, I); lanes run over I — the same kernel body with
                   a permuted grid, no transpose materialized.

This is the TPU restatement of the paper's "right layout / right loop vs left
layout / left loop" sweep: the loop structure is the BlockSpec, and matching it to
the layout is what keeps the fast dimension on the 128-wide lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pick_block, use_interpret


def _sum3d_kernel(x_ref, acc_ref, *, rows_total: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    br = x_ref.shape[0]
    # mask rows past the true extent (final partial block loads padding)
    grow = pl.program_id(0) * br + jax.lax.broadcasted_iota(jnp.int32, x_ref.shape, 0)
    vals = jnp.where(grow < rows_total, x_ref[...].astype(jnp.float32), 0.0)
    acc_ref[0, 0] += jnp.sum(vals)


def sum3d_pallas(x: jax.Array, *, block_rows: int = 8, interpret: bool | None = None) -> jax.Array:
    """Sum over a 3-D array held in its PHYSICAL layout order.

    Grid over the slowest physical dim; each step loads a (block_rows, J, K) brick
    into VMEM and accumulates into an SMEM-resident f32 scalar. Sequential grid on
    TPU makes the scalar accumulation safe (single-core revisiting semantics).
    """
    interpret = use_interpret() if interpret is None else interpret
    i, j, k = x.shape
    br = pick_block(i, block_rows)
    grid = (cdiv(i, br),)
    kern = functools.partial(_sum3d_kernel, rows_total=i)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((br, j, k), lambda g: (g, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)[0, 0]


def sum3d_mdspan(span, *, interpret: bool | None = None) -> jax.Array:
    """Layout-generic entry point: accepts an MdSpan whose layout decides the
    physical schedule. Strided row/col-major layouts reshape the codomain to the
    physical order (free) and dispatch to the same kernel body."""
    from repro.core.layouts import LayoutLeft, LayoutRight
    from repro.core.mdspan import MdSpan

    assert isinstance(span, MdSpan) and span.rank == 3
    codo = span.codomain()
    if isinstance(span.layout, LayoutRight):
        phys = codo.reshape(span.shape)
    elif isinstance(span.layout, LayoutLeft):
        phys = codo.reshape(span.shape[::-1])  # physical order: fast dim first
    else:
        # generic fallback: gather through the layout (still one pass)
        phys = span.to_dense()
    return sum3d_pallas(phys, interpret=interpret)
