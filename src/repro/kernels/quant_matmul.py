"""Quantized matmul — the QuantizedAccessor's compute backend (paper: bit-packing
accessor, generalized to intN + scales for HPC-scale weights).

y = x @ W^T where W is stored OUTPUT-MAJOR, (N, K), as int8 (or nibble-packed int4)
with per-(row, K-block) f32 scales — exactly the buffers produced by
``core.distributed.quantize_array(W_T)`` (which blocks the LAST dim). The layout
choice is itself the paper's point: (N, K) row-major puts the contraction dim K on
the 128-wide lane axis for BOTH x and W blocks, so the MXU consumes them without
transposes; the accessor's dequantize runs at the VMEM boundary so HBM traffic is
the quantized bytes.

BlockSpec scheme: grid (M/bm, N/bn, K/bk) with bk == the quantization block so one
scale column covers one k-step; accumulator scratch (bm, bn) f32 persists across
the sequential K grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import cdiv, pick_block, use_interpret


def _unpack_int4(qv: jax.Array) -> jax.Array:
    lo = (qv & 0x0F).astype(jnp.int8)
    hi = ((qv >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.float32)
    hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.float32)
    return jnp.stack([lo, hi], axis=-1).reshape(qv.shape[0], qv.shape[1] * 2)


def _qmm_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, bits: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    qv = q_ref[...]  # (bn, bk) int8  |  int4: (bn, bk//2) packed
    w = _unpack_int4(qv) if bits == 4 else qv.astype(jnp.float32)  # (bn, bk)
    w = w * s_ref[...]  # (bn, 1) scale column for this k-block
    # contract K on lanes for both operands: (bm, bk) x (bn, bk) -> (bm, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(
    x: jax.Array,
    q: jax.Array,
    scale: jax.Array,
    *,
    bits: int = 8,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """x: (M, K); q: int8 (N, K) or int4-packed (N, K//2); scale: (N, K//qblock).

    qblock (the quantization block along K) is inferred from scale's shape and
    becomes the kernel's K-step. Returns (M, N) in x.dtype.
    """
    interpret = use_interpret() if interpret is None else interpret
    m, k = x.shape
    n = q.shape[0]
    kq = q.shape[1] * 2 if bits == 4 else q.shape[1]
    assert kq == k, (kq, k)
    nblocks = scale.shape[1]
    assert scale.shape == (n, nblocks), (scale.shape, n, nblocks)
    bk = k // nblocks
    bm = pick_block(m, block_m, align=8 if m >= 8 else 1)
    bn = pick_block(n, block_n, align=128 if n >= 128 else 1)
    assert n % bn == 0 and k % bk == 0, (n, bn, k, bk)
    bk_q = bk // 2 if bits == 4 else bk
    grid = (cdiv(m, bm), n // bn, k // bk)
    kern = functools.partial(_qmm_kernel, bits=bits, nk=k // bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bn, bk_q), lambda mi, ni, ki: (ni, ki)),
            pl.BlockSpec((bn, 1), lambda mi, ni, ki: (ni, ki)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)
