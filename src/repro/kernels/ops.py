"""ops — the jit'd public API over the kernel package, with mdspan-driven dispatch.

This is where the paper's customization points become *dispatch*: the layout and
accessor of an MdSpan/TensorSpec select the kernel schedule at trace time.

  matmul(x, w)            w may be dense (jnp.dot) or quantized buffers
                          ({"q","scale"} from quantize_array) → quant_matmul kernel
                          (or its jnp twin off-TPU).
  attention(...)          train: differentiable blocked-jnp twin; serve: Pallas
                          flash kernel on TPU (jnp twin elsewhere so compiled cost
                          analysis reflects the algorithm, DESIGN.md §2).
  sum3d/matvec/...        paper-suite entries dispatching on span.layout.

Every kernel has a jnp twin of IDENTICAL semantics; `impl="pallas"|"jnp"|"auto"`
overrides for tests and benchmarks.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.accessors import QuantizedAccessor
from repro.core.distributed import dequantize_array
from repro.core.layouts import LayoutLeft, LayoutRight

from . import ref
from .common import use_interpret
from .flash_attention import flash_attention as _flash_fwd
from .flash_attention import flash_decode as _flash_decode
from .paged_attention import paged_decode_attention_jnp as _paged_decode_jnp
from .paged_attention import paged_decode_attention_quant_jnp as _paged_decode_quant_jnp
from .paged_attention import paged_flash_decode as _paged_flash_decode
from .paged_attention import paged_flash_decode_quant as _paged_flash_decode_quant
from .paged_attention import paged_flash_prefill_chunk as _paged_flash_chunk
from .paged_attention import paged_flash_prefill_chunk_quant as _paged_flash_chunk_quant
from .paged_attention import paged_prefill_chunk_jnp as _paged_chunk_jnp
from .paged_attention import paged_prefill_chunk_quant_jnp as _paged_chunk_quant_jnp
from .matvec import matvec_left, matvec_right
from .quant_matmul import quant_matmul as _qmm_pallas
from .ssd_scan import ssd_scan as _ssd_pallas
from .stencil3d import stencil3d_pallas
from .sum3d import sum3d_mdspan
from .tinymatsum import tinymatsum_dynamic, tinymatsum_static


def _want_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "jnp":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------------
# matmul with accessor dispatch
# ---------------------------------------------------------------------------------
def matmul(x: jax.Array, w, accessor: Optional[QuantizedAccessor] = None, *, impl: str = "auto"):
    """x: (..., K); w: dense (K, N) array OR quantized buffers {"q","scale"}.

    Quantized path: scales are per-(K-block, N) as produced by
    ``quantize_array(wT_blocked...)`` — see models/layers.py:QuantLinear.
    """
    if isinstance(w, dict):  # quantized buffers
        assert accessor is not None
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if _want_pallas(impl):
            y = _qmm_pallas(x2, w["q"], w["scale"], bits=accessor.bits)
        else:
            y = ref.quant_matmul(x2, w["q"], w["scale"], bits=accessor.bits)
        return y.reshape(*lead, y.shape[-1])
    return jnp.matmul(x, w)


# ---------------------------------------------------------------------------------
# attention — blocked-jnp twin (differentiable, remat-friendly) + pallas fast path
# ---------------------------------------------------------------------------------
def attention_jnp(
    q, k, v, *, causal=True, window=None, q_offset=0, scale=None, block_k: int = 512
):
    """Blocked online-softmax attention in pure jnp — semantics == ref.attention,
    memory O(Tq·Tk_block). Differentiable; used for train_step and for dry-runs."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    import numpy as np

    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    nblk = -(-tk // block_k)
    pad = nblk * block_k - tk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kf = kf.reshape(b, hkv, nblk, block_k, d)
    vf = vf.reshape(b, hkv, nblk, block_k, d)
    q_pos = jnp.arange(tq)[:, None] + q_offset

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, ki = blk
        kb = jnp.repeat(kb, group, axis=1)  # (b, hq, bk, d)
        vb = jnp.repeat(vb, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)
        k_pos = ki * block_k + jnp.arange(block_k)[None, :]
        live = k_pos < tk
        if causal:
            live = live & (k_pos <= q_pos)
        if window is not None:
            live = live & (k_pos > q_pos - window)
        s = jnp.where(live[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, tq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, tq, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, tq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kf, 2, 0), jnp.moveaxis(vf, 2, 0), jnp.arange(nblk)),
    )
    return (acc / jnp.where(l == 0, 1.0, l)).astype(q.dtype)


def attention(
    q, k, v, *, causal=True, window=None, q_offset=0, scale=None, impl: str = "auto"
):
    if _want_pallas(impl):
        return _flash_fwd(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
        )
    # differentiable flash twin with the hand-written O(T·D)-residual VJP
    from .flash_vjp import flash_attention_jnp

    return flash_attention_jnp(
        q, k, v, jnp.asarray(q_offset, jnp.int32), causal, window, scale
    )


def decode_attention(q, k_cache, v_cache, pos, *, window=None, scale=None, impl: str = "auto"):
    """One-token GQA decode against a (B, Hkv, S, D) cache; ``pos`` traced."""
    if _want_pallas(impl):
        return _flash_decode(q, k_cache, v_cache, pos, window=window, scale=scale)
    # jnp twin: mask by absolute position (identical semantics to the kernel)
    return attention_jnp(
        q, k_cache, v_cache, causal=True, window=window, q_offset=pos, scale=scale
    )


def effective_block_pages(block_pages, max_pages: int) -> int:
    """Sanitize the decode block-shape knob against a table width.

    Returns the largest divisor of ``max_pages`` that is <= ``block_pages``
    (the Pallas grid needs an exact factorization of the page axis), or 1 when
    the knob is unset (None/0) — 1 reproduces the pre-knob schedule exactly.
    Tuned values therefore degrade gracefully when an engine is sized with a
    different max_pages than the sweep used.
    """
    if not block_pages or max_pages <= 0:
        return 1
    bp = min(int(block_pages), max_pages)
    while max_pages % bp:
        bp -= 1
    return bp


def paged_decode_attention(
    q, k_pool, v_pool, block_tables, context_lens, *, scale=None,
    block_pages=None, impl: str = "auto",
):
    """One-token GQA decode against a LayoutPaged pool (num_pages, Hkv, ps, D);
    block_tables (B, max_pages) int32; context_lens (B,) int32 per-sequence.
    ``block_pages`` (pages per compute block; autotuned) is sanitized here via
    effective_block_pages, so callers pass the tuned value verbatim."""
    bp = effective_block_pages(block_pages, block_tables.shape[1])
    if _want_pallas(impl):
        return _paged_flash_decode(
            q, k_pool, v_pool, block_tables, context_lens, scale=scale,
            block_pages=bp,
        )
    return _paged_decode_jnp(
        q, k_pool, v_pool, block_tables, context_lens, scale=scale,
        block_pages=bp if bp > 1 else None,
    )


def paged_decode_attention_quant(
    q, k_q, k_scale, v_q, v_scale, block_tables, context_lens, *,
    bits: int = 8, scale=None, block_pages=None, impl: str = "auto",
):
    """One-token GQA decode against a QUANTIZED LayoutPaged pool: intN page
    bytes (num_pages, Hkv, ps, Dq) + per-(page, head) f32 scales (num_pages,
    Hkv) — the accessor customization point (PagedQuantSpec) composed with the
    layout one. Same block-table/length/block_pages contract as
    paged_decode_attention."""
    bp = effective_block_pages(block_pages, block_tables.shape[1])
    if _want_pallas(impl):
        return _paged_flash_decode_quant(
            q, k_q, k_scale, v_q, v_scale, block_tables, context_lens,
            bits=bits, scale=scale, block_pages=bp,
        )
    return _paged_decode_quant_jnp(
        q, k_q, k_scale, v_q, v_scale, block_tables, context_lens,
        bits=bits, scale=scale, block_pages=bp if bp > 1 else None,
    )


def paged_prefill_chunk_attention(
    q, chunk_k, chunk_v, k_pool, v_pool, block_tables, cursors, *,
    scale=None, impl: str = "auto",
):
    """Chunked-prefill GQA attention: a Q-chunk (B, Hq, C, D) against the
    resident PAST (pool positions < cursors[b], read through the block table)
    plus its own PRESENT (chunk_k/chunk_v, (B, Hkv, C, D) f32, intra-chunk
    causal) — one online softmax across both. The C == 1 case is
    paged_decode_attention; this is the mixed-step prefill half."""
    if _want_pallas(impl):
        return _paged_flash_chunk(
            q, chunk_k, chunk_v, k_pool, v_pool, block_tables, cursors,
            scale=scale,
        )
    return _paged_chunk_jnp(
        q, chunk_k, chunk_v, k_pool, v_pool, block_tables, cursors, scale=scale
    )


def paged_prefill_chunk_attention_quant(
    q, chunk_k, chunk_v, k_q, k_scale, v_q, v_scale, block_tables, cursors, *,
    bits: int = 8, scale=None, impl: str = "auto",
):
    """paged_prefill_chunk_attention over an intN paged pool (PagedQuantSpec):
    the past dequantizes in-kernel; the present (the chunk's own K/V) stays
    f32, so only CROSS-chunk attention pays the representation."""
    if _want_pallas(impl):
        return _paged_flash_chunk_quant(
            q, chunk_k, chunk_v, k_q, k_scale, v_q, v_scale, block_tables,
            cursors, bits=bits, scale=scale,
        )
    return _paged_chunk_quant_jnp(
        q, chunk_k, chunk_v, k_q, k_scale, v_q, v_scale, block_tables, cursors,
        bits=bits, scale=scale,
    )


# ---------------------------------------------------------------------------------
# on-device token sampling (the serving hot path's logits consumer)
# ---------------------------------------------------------------------------------

# fold_in domain tags for the speculative verify op: each (slot, position) base
# key fans out into an acceptance-uniform stream and a resample-Gumbel stream.
# Disjoint from sample_tokens' key derivation (which never folds a tag), so a
# speculative engine and a non-speculative one never reuse randomness across
# semantically different draws. serving/sampling.py documents the contract.
SPEC_ACCEPT_FOLD = 0x5ACC
SPEC_RESAMPLE_FOLD = 0x5E5A


def _filter_topk_topp(x, temperature, top_k, top_p, *, vocab: int):
    """Temperature-scale + top-k/top-p filter a batch of masked logit rows.

    x: (N, Vp) f32 with pad columns already -inf; temperature/top_k/top_p: (N,).
    Returns z (N, Vp): x / max(temperature, eps) with filtered-out entries at
    -inf — the categorical distribution Gumbel-max sampling draws from. Shared
    by sample_tokens and verify_draft_tokens so the speculative accept test and
    ordinary sampling see the SAME filtered distribution (the correctness
    precondition for unbiased rejection sampling)."""
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, vocab), 1, vocab)
    x_desc = jnp.sort(x, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(x_desc, k_eff[:, None] - 1, axis=1)
    xf = jnp.where(x >= kth, x, -jnp.inf)
    # top-p over the temperature-scaled distribution of the survivors
    t = jnp.maximum(temperature, 1e-6)[:, None]
    z = xf / t
    p_eff = jnp.where(top_p > 0, top_p, 1.0)[:, None]
    z_desc = jnp.sort(z, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(z_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < p_eff  # mass BEFORE the token; top-1 always kept
    cutoff = jnp.min(jnp.where(keep, z_desc, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(z >= cutoff, z, -jnp.inf)


def sample_tokens(logits, temperature, top_k, top_p, seed, pos, *, vocab: int,
                  mask=None):
    """Batched token selection on device: greedy / temperature / top-k / top-p.

    logits: (B, Vp) with Vp >= vocab (pad columns masked off); temperature (B,)
    f32 — 0 selects greedy argmax, EXACTLY matching host ``np.argmax`` over
    ``logits[:, :vocab]``; top_k (B,) int32 (0 = off); top_p (B,) f32 in (0, 1]
    (1 = off; non-positive values are treated as off); seed (B,) uint32 per-slot
    stream ids; pos (B,) int32 the absolute sequence index of the token being
    sampled. Returns (B,) int32 token ids.

    ``mask`` (optional, (B, vocab) f32) is an ADDITIVE logit mask applied before
    every filter and both selection paths — the constrained-decoding stage:
    grammar-disallowed tokens carry a large negative value (serving/grammar.py
    precomputes one row per grammar state on the host; the engine gathers the
    per-slot rows on device), allowed tokens carry 0, and an all-zero row is an
    exact no-op, so unconstrained slots in the same batch are unaffected. The
    mask composes BEFORE top-k/top-p: the filters then act on the constrained
    distribution, and greedy picks the best ALLOWED token.

    Determinism: the per-slot key is ``fold_in(PRNGKey(seed[b]), pos[b])`` — a
    pure function of (stream seed, position). A preempted-and-recomputed request
    therefore re-samples the identical token at every position, and two engines
    replaying the same trace agree bit-for-bit (the serving sampling contract;
    serving/sampling.py derives the stream seed).

    Filters compose in the conventional order: top-k keeps the k largest logits
    (ties at the k-th value are all kept), then top-p keeps the smallest prefix
    of the temperature-scaled distribution whose mass reaches top_p (the
    crossing token included, so at least one survives). Sampling itself is the
    Gumbel-max trick — an argmax, so the whole path stays a (B, V) map + two
    sorts with no host round-trip. When NO slot samples (all temperatures 0) a
    ``lax.cond`` skips the sort/softmax machinery at run time and the step pays
    exactly one argmax.
    """
    b, vp = logits.shape
    col = jnp.arange(vp)[None, :]
    x = jnp.where(col < vocab, logits.astype(jnp.float32), -jnp.inf)
    if mask is not None:
        # pad columns are already -inf; the mask only ever biases real tokens
        x = x + jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, vp - vocab)))
    greedy = jnp.argmax(x, axis=-1).astype(jnp.int32)

    def _sampled(_):
        z = _filter_topk_topp(x, temperature, top_k, top_p, vocab=vocab)
        keys = jax.vmap(
            lambda s, p: jax.random.fold_in(jax.random.PRNGKey(s), p)
        )(seed, pos)
        g = jax.vmap(lambda key: jax.random.gumbel(key, (vp,)))(keys)
        tok = jnp.argmax(z + g, axis=-1).astype(jnp.int32)
        return jnp.where(temperature > 0, tok, greedy)

    return jax.lax.cond(
        jnp.any(temperature > 0), _sampled, lambda _: greedy, operand=None
    )


def verify_draft_tokens(logits, draft, temperature, top_k, top_p, seed, pos0,
                        active, *, vocab: int):
    """Speculative accept/resample over one verify window's logits.

    logits: (B, C, Vp) the target model's rows for present positions
    lens..lens+K (C = K+1; row j predicts the token at absolute position
    pos0[b]+j where pos0 = lens+1); draft: (B, K) proposed tokens (clipped to
    the vocab here — a garbage proposal can only be rejected, never crash);
    temperature/top_k/top_p/seed: (B,) per-slot sampling state (the same packed
    rows sample_tokens consumes); active: (B,) phase bitmap.

    Returns (tokens_out (B, C) int32, committed (B,) int32, chosen_lp (B, C)
    f32): committed[b] = n_acc+1 tokens of tokens_out[b] are final — n_acc
    accepted draft tokens followed by one correction (first rejection) or
    bonus (all accepted) token. chosen_lp is the UNMASKED model log-prob of
    every tokens_out entry (rows past committed are dead — the caller's lens
    arithmetic never exposes them). Inactive rows commit 0.

    Greedy rows (temperature == 0): tokens_out = argmax per row and
    accept_j ⇔ argmax_j == draft_j, which makes the committed stream
    token-IDENTICAL to a one-token-at-a-time greedy decode — the correctness
    law CI pins. Sampled rows run textbook rejection sampling against the
    deterministic draft: accept d_j with prob p_j(d_j) under the SAME
    filtered/scaled distribution sample_tokens uses (_filter_topk_topp); on
    the first rejection resample from that distribution with the rejected
    token masked out (the residual max(0, p - q) for a one-point q), and when
    every draft survives the bonus row draws unconditionally. Keys derive from
    fold_in(PRNGKey(seed), pos0+j) + a domain tag (SPEC_ACCEPT_FOLD /
    SPEC_RESAMPLE_FOLD), so a given (stream, position) always consumes the
    same randomness — preemption-recompute reproducibility, same law as
    sample_tokens (though the speculative sampled stream intentionally differs
    from the non-speculative one: only GREEDY promises cross-path exactness).
    """
    b, c, vp = logits.shape
    k = c - 1
    col = jnp.arange(vp)[None, None, :]
    x = jnp.where(col < vocab, logits.astype(jnp.float32), -jnp.inf)
    greedy = jnp.argmax(x, axis=-1).astype(jnp.int32)  # (B, C)
    draft = jnp.clip(draft.astype(jnp.int32), 0, vocab - 1)
    acc_greedy = greedy[:, :k] == draft  # (B, K)

    def _sampled(_):
        z = _filter_topk_topp(
            x.reshape(b * c, vp), jnp.repeat(temperature, c),
            jnp.repeat(top_k, c), jnp.repeat(top_p, c), vocab=vocab,
        ).reshape(b, c, vp)
        pos = pos0[:, None] + jnp.arange(c)[None, :]  # (B, C)
        base = jax.vmap(jax.vmap(
            lambda s, p: jax.random.fold_in(jax.random.PRNGKey(s), p),
            in_axes=(None, 0)), in_axes=(0, 0))(seed, pos)
        u = jax.vmap(jax.vmap(
            lambda kk: jax.random.uniform(jax.random.fold_in(kk, SPEC_ACCEPT_FOLD))
        ))(base)  # (B, C)
        g = jax.vmap(jax.vmap(
            lambda kk: jax.random.gumbel(
                jax.random.fold_in(kk, SPEC_RESAMPLE_FOLD), (vp,))
        ))(base)  # (B, C, Vp)
        probs = jax.nn.softmax(z, axis=-1)
        p_draft = jnp.take_along_axis(probs[:, :k], draft[:, :, None], axis=-1)[..., 0]
        acc = u[:, :k] < p_draft  # (B, K)
        # resample with the rejected draft token excluded; the bonus row
        # (j == K) has no draft and samples from the full distribution
        rb = jnp.arange(b)[:, None]
        rj = jnp.arange(k)[None, :]
        zm = z.at[rb, rj, draft].set(-jnp.inf)
        resamp = jnp.argmax(zm + g, axis=-1).astype(jnp.int32)  # (B, C)
        acc_f = jnp.concatenate([acc, jnp.zeros((b, 1), bool)], axis=1)
        draft_f = jnp.concatenate([draft, jnp.zeros((b, 1), jnp.int32)], axis=1)
        tok = jnp.where(acc_f, draft_f, resamp)
        samp = (temperature > 0)
        return (jnp.where(samp[:, None], tok, greedy),
                jnp.where(samp[:, None], acc, acc_greedy))

    tokens_out, accept = jax.lax.cond(
        jnp.any(temperature > 0), _sampled,
        lambda _: (greedy, acc_greedy), operand=None,
    )
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)
    committed = jnp.where(active > 0, n_acc + 1, 0).astype(jnp.int32)
    lp = jax.nn.log_softmax(logits[..., :vocab].astype(jnp.float32), axis=-1)
    chosen_lp = jnp.take_along_axis(lp, tokens_out[..., None], axis=-1)[..., 0]
    return tokens_out, committed, chosen_lp


# ---------------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------------
def ssd_jnp(
    x, dt, A, B, C, *, chunk=64, initial_state=None, return_final_state=False
):
    """Chunked SSD in pure jnp (differentiable twin of the Pallas kernel; same
    chunked math, scan over chunks)."""
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert t % chunk == 0
    nc = t // chunk
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2).reshape(b, nc, chunk, h, n)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2).reshape(b, nc, chunk, h, n)
    Af = A.astype(jnp.float32)

    def chunk_step(S, blk):
        xq, dtq, Bq, Cq = blk  # (b, Q, h, p), (b, Q, h), (b, Q, h, n) ×2
        lam = dtq * Af[None, None, :]
        s = jnp.cumsum(lam, axis=1)  # (b, Q, h)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Cq, S) * jnp.exp(s)[..., None]
        cb = jnp.einsum("bqhn,buhn->bhqu", Cq, Bq)
        seg = s[:, :, None, :] - s[:, None, :, :]  # (b, t, u, h)
        q_ = xq.shape[1]
        tri = jnp.tril(jnp.ones((q_, q_), jnp.float32))
        m = (
            cb
            * jnp.exp(jnp.minimum(jnp.moveaxis(seg, 3, 1), 0.0))
            * jnp.moveaxis(dtq, 2, 1)[:, :, None, :]
            * tri[None, None]
        )  # (b, h, t, u)
        y_intra = jnp.einsum("bhtu,buhp->bthp", m, xq)
        w = jnp.exp(s[:, -1:, :] - s) * dtq  # (b, Q, h)
        upd = jnp.einsum("bqhp,bqhn->bhpn", xq * w[..., None], Bq)
        S = S * jnp.exp(s[:, -1])[:, :, None, None] + upd
        return S, (y_inter + y_intra).astype(x.dtype)

    S0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    Sf, ys = jax.lax.scan(
        chunk_step,
        S0,
        (
            jnp.moveaxis(xf, 1, 0),
            jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, p)
    if return_final_state:
        return y, Sf
    return y


def ssd(
    x, dt, A, B, C, *, chunk=64, initial_state=None, return_final_state=False,
    impl: str = "auto",
):
    if _want_pallas(impl) and B.shape[2] == 1:
        return _ssd_pallas(
            x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
            return_final_state=return_final_state,
        )
    return ssd_jnp(
        x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
        return_final_state=return_final_state,
    )


def ssd_decode_step(state, xt, dtt, A, Bt, Ct):
    """Single-token SSM state update (decode). state: (b,h,p,n); xt: (b,h,p);
    dtt: (b,h); Bt/Ct: (b,g,n)."""
    b, h, p, n = state.shape
    g = Bt.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bt, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Ct, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    upd = (dtt.astype(jnp.float32)[..., None] * xt.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return state, y.astype(xt.dtype)


# ---------------------------------------------------------------------------------
# paper-suite dispatchers (layout-generic)
# ---------------------------------------------------------------------------------
def sum3d(span, *, impl: str = "auto"):
    from repro.core.mdspan import MdSpan

    if isinstance(span, MdSpan):
        if _want_pallas(impl) or impl == "pallas":
            return sum3d_mdspan(span)
        return ref.sum3d(span.to_dense())
    return ref.sum3d(span)


def matvec(A_span, x, *, impl: str = "auto"):
    """Layout dispatch: LayoutRight → lane-contraction kernel; LayoutLeft →
    sublane-contraction kernel (honest schedules for both, paper Fig. 6)."""
    from repro.core.mdspan import MdSpan

    if not isinstance(A_span, MdSpan):
        return ref.matvec(A_span, x)
    if not _want_pallas(impl):
        return ref.matvec(A_span.to_dense(), x)
    codo = A_span.codomain()
    if isinstance(A_span.layout, LayoutRight):
        return matvec_right(codo.reshape(A_span.shape), x)
    if isinstance(A_span.layout, LayoutLeft):
        return matvec_left(codo.reshape(A_span.shape[::-1]), x)
    return ref.matvec(A_span.to_dense(), x)


def tinymatsum(o, s, *, static_extents: bool = True, impl: str = "auto", **kw):
    if not _want_pallas(impl):
        return ref.tinymatsum(o, s)
    if static_extents:
        return tinymatsum_static(o, s, **kw)
    return tinymatsum_dynamic(o, s, **kw)


def stencil3d(x, *, impl: str = "auto", **kw):
    if not _want_pallas(impl):
        return ref.stencil3d(x)
    return stencil3d_pallas(x, **kw)
