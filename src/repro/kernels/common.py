"""Shared Pallas kernel helpers: alignment, padding, interpret-mode plumbing."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

# Kernels are TPU-targeted; on CPU (this container) they execute via the Pallas
# interpreter for correctness validation. On a real TPU backend set
# REPRO_PALLAS_INTERPRET=0 (the default resolves by backend).
def use_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


LANE = 128  # TPU vector lane width
SUBLANE = {4: 8, 2: 16, 1: 32}  # sublane count per dtype itemsize (VREG geometry)


def sublane_for(dtype) -> int:
    return SUBLANE.get(jnp.dtype(dtype).itemsize, 8)


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pick_block(extent: int, target: int, align: int = 1) -> int:
    """Largest block <= target that is a multiple of ``align`` (or the whole extent
    if it is smaller). Keeps MXU/VREG dims hardware-aligned when possible."""
    if extent <= target:
        return extent
    b = (target // align) * align
    return max(b, align)


def pad_to(x: jax.Array, shape) -> jax.Array:
    pads = [(0, s - xs) for xs, s in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)
