"""Stencil3D — 27-point box stencil (paper: Stencil3D, stencil size d=1).

Halo handling without overlapping BlockSpecs: the row-block arrives three times
under shifted index_maps (previous / current / next block of rows), and the kernel
assembles the 3-row window locally. All j/k shifts happen inside the VMEM block.
Boundary output rows are zeroed (oracle semantics in ref.stencil3d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pick_block, use_interpret


def _shift_sum_jk(plane_3rows: jax.Array) -> jax.Array:
    """Given rows (3, J, K) f32, return (J, K) = sum over the 27 neighbors for the
    middle row, with j/k boundaries producing values that the caller masks."""
    acc = jnp.zeros(plane_3rows.shape[1:], jnp.float32)
    padded = jnp.pad(plane_3rows, ((0, 0), (1, 1), (1, 1)))
    j, k = plane_3rows.shape[1:]
    for di in range(3):
        for dj in range(3):
            for dk in range(3):
                acc = acc + padded[di, dj : dj + j, dk : dk + k]
    return acc


def _stencil_kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, rows_total: int):
    br = cur_ref.shape[0]
    g = pl.program_id(0)
    cur = cur_ref[...].astype(jnp.float32)
    prev = prev_ref[...].astype(jnp.float32)
    nxt = nxt_ref[...].astype(jnp.float32)
    # Window rows: [prev_last, cur..., nxt_first]; for interior blocks prev/nxt are
    # the physically adjacent blocks (index_map clamps at the ends; the clamped
    # rows only feed masked-out boundary outputs).
    win = jnp.concatenate([prev[-1:], cur, nxt[:1]], axis=0)  # (br+2, J, K)
    j, k = cur.shape[1:]
    out = jnp.zeros((br, j, k), jnp.float32)
    for r in range(br):
        out = out.at[r].set(_shift_sum_jk(win[r : r + 3]))
    # mask: global row 0 and rows_total-1, plus j/k boundaries
    grow = g * br + jax.lax.broadcasted_iota(jnp.int32, (br, j, k), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (br, j, k), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (br, j, k), 2)
    interior = (
        (grow > 0)
        & (grow < rows_total - 1)
        & (jj > 0)
        & (jj < j - 1)
        & (kk > 0)
        & (kk < k - 1)
    )
    o_ref[...] = jnp.where(interior, out, 0.0).astype(o_ref.dtype)


def stencil3d_pallas(x: jax.Array, *, block_rows: int = 8, interpret: bool | None = None) -> jax.Array:
    interpret = use_interpret() if interpret is None else interpret
    i, j, k = x.shape
    if i < 3:
        return jnp.zeros_like(x)
    br = pick_block(i, block_rows)
    if i % br != 0:  # keep the index shift logic simple: require divisibility
        br = next(b for b in range(br, 0, -1) if i % b == 0)
    grid = (i // br,)
    import functools

    kern = functools.partial(_stencil_kernel, rows_total=i)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, j, k), lambda g: (jnp.maximum(g - 1, 0), 0, 0)),
            pl.BlockSpec((br, j, k), lambda g: (g, 0, 0)),
            pl.BlockSpec((br, j, k), lambda g: (jnp.minimum(g + 1, pl.num_programs(0) - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((br, j, k), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((i, j, k), x.dtype),
        interpret=interpret,
    )(x, x, x)
