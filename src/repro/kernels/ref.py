"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests/test_kernels_*.py sweep shapes & dtypes with assert_allclose). The oracles
are deliberately naive — readability over speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# -- paper benchmark suite ------------------------------------------------------------
def sum3d(x: jax.Array) -> jax.Array:
    """Sum of all entries of a 3-D array (paper: Sum3D)."""
    return jnp.sum(x.astype(jnp.float32))


def stencil3d(x: jax.Array) -> jax.Array:
    """27-point box stencil, stencil size d=1 (paper: Stencil3D).

    out[i,j,k] = sum_{di,dj,dk in [-1,1]} x[i+di, j+dj, k+dk]  on the interior;
    boundary entries are 0.
    """
    x = x.astype(jnp.float32)
    out = jnp.zeros_like(x)
    acc = jnp.zeros_like(x[1:-1, 1:-1, 1:-1])
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                acc = acc + x[
                    1 + di : x.shape[0] - 1 + di,
                    1 + dj : x.shape[1] - 1 + dj,
                    1 + dk : x.shape[2] - 1 + dk,
                ]
    return out.at[1:-1, 1:-1, 1:-1].set(acc).astype(x.dtype)


def tinymatsum(o: jax.Array, s: jax.Array) -> jax.Array:
    """Batched accumulate o += s over (N, J, K) tiny matrices (paper: TinyMatrixSum)."""
    return (o.astype(jnp.float32) + s.astype(jnp.float32)).astype(o.dtype)


def matvec(a: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x (paper: MatVec)."""
    return (a.astype(jnp.float32) @ x.astype(jnp.float32)).astype(x.dtype)


# -- LM kernels ------------------------------------------------------------------------
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Multi-head attention oracle with GQA, causal masking and local windows.

    q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D). Hq % Hkv == 0 (GQA group = Hq // Hkv).
    ``q_offset``: absolute position of q[0] (decode: Tq=1, q_offset=pos).
    ``window``: if set, token i attends to j in (i - window, i].
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def quant_matmul(x: jax.Array, q: jax.Array, scale: jax.Array, *, bits: int = 8) -> jax.Array:
    """x @ dequant(W)^T: x (..., K); W output-major: q int8 (N, K) (int4: (N, K//2)
    nibble-packed), scale (N, K // block) per-(row, K-block) scales."""
    if bits == 4:
        lo = (q & 0x0F).astype(jnp.int8)
        hi = ((q >> 4) & 0x0F).astype(jnp.int8)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[0], q.shape[1] * 2)
    n, k = q.shape
    nb = scale.shape[1]
    blk = k // nb
    w = q.astype(jnp.float32).reshape(n, nb, blk) * scale[:, :, None]
    w = w.reshape(n, k)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 64,
    initial_state: jax.Array | None = None,
    return_final_state: bool = False,
):
    """Mamba-2 SSD (state-space dual) oracle — sequential-over-time reference.

    x: (b, t, h, p)   inputs per head
    dt: (b, t, h)     softplus-activated step sizes (already positive)
    A: (h,)           negative state decay per head (a_t = exp(dt * A))
    B: (b, t, g, n)   input projection (g groups broadcast over heads)
    C: (b, t, g, n)   output projection
    returns y: (b, t, h, p) [and final state (b, h, p, n)]

    h % g == 0; heads in the same group share B/C.
    """
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (b,t,h,n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * Af[None, :])[..., None, None]  # (b,h,1,1)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, :, None, :]  # (b,h,p,n)
        state = state * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    state0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    final, ys = jax.lax.scan(step, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if return_final_state:
        return y, final
    return y


def rglru(
    x: jax.Array, input_gate: jax.Array, a_gate: jax.Array, a_param: jax.Array,
    *, initial_state: jax.Array | None = None, return_final_state: bool = False,
    c: float = 8.0,
):
    """RG-LRU oracle (RecurrentGemma eq. 1-4), sequential reference.

    x, input_gate, a_gate: (b, t, w); a_param: (w,) pre-softplus recurrence param.
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(a_param) * sigmoid(a_gate_t)).
    """
    xf = x.astype(jnp.float32)
    it = jax.nn.sigmoid(input_gate.astype(jnp.float32))
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] * jax.nn.sigmoid(
        a_gate.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated = it * xf
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    def step(h, inp):
        at, gt, mt = inp
        h = at * h + mt * gt
        return h, h

    h0 = (
        jnp.zeros_like(xf[:, 0])
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    final, hs = jax.lax.scan(
        step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0), jnp.moveaxis(mult, 1, 0))
    )
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    if return_final_state:
        return y, final
    return y
