"""Paged-attention decode — Pallas TPU kernel over a LayoutPaged KV pool.

The KV cache is a pool of fixed-size pages, (num_pages, Hkv, page_size, D), and
each sequence owns a row of a block table mapping logical page j -> physical
page id. This is core.layouts.LayoutPaged made executable: the kernel's k/v
BlockSpec index maps read the block table through scalar prefetch
(PrefetchScalarGridSpec), so the layout's index->offset indirection runs on the
scalar core while pages DMA into VMEM — no dense (B, Hkv, S, D) cache ever
materializes and pages of different sequences can live anywhere in the pool.

Per-sequence lengths (continuous batching: every row of the batch is at a
different position) ride in through the second prefetch operand and drive both
the online-softmax masking and the page skip predicate.

``paged_decode_attention_jnp`` is the identical-semantics twin (gather pages by
table, mask by length) used off-TPU and as the differentiable/cheap fallback;
both are validated against ref.attention on densified pools in
tests/test_serving_engine.py.

Both decode paths expose one block-shape knob, ``block_pages`` (pages per
compute block), picked per (model, kv_dtype, batch bucket) by
kernels/autotune.py: the Pallas grids factor their page axis into
(compute blocks, pages per block), and the jnp twin switches to a blocked
gather (lax.scan over page blocks with an online-softmax carry) so the knob
bounds its peak gathered working set. Chunked prefill has no separate knob —
its block shape IS the chunk width, already swept by the engine's
chunk-bucket machinery.

Quantized pools (the accessor axis composed with the layout axis): the
``*_quant`` variants consume int8/int4 page pools with one f32 scale per
(physical page, kv head) — serving/engine/kvquant.PagedQuantSpec's encoding.
``paged_flash_decode_quant`` DMAs int8 page tiles and their (page, head) scale
through the SAME block-table index maps as the f32 kernel (the layout is
untouched; only the element representation changed) and dequantizes in VMEM
next to the flash update. int4 pages pack two values per byte SPLIT-HALF along
the feature dim (byte d = feature d in the lo nibble, feature d + D/2 in the
hi), so in-kernel dequant is a lane concat — never an interleave — and a
single token's scatter stays nibble-local to its own (slot, :) row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import use_interpret

NEG_INF = -1e30


# ---------------------------------------------------------------------------------
# int4 nibble packing (split-half) + page dequantization
# ---------------------------------------------------------------------------------
def pack_int4_splithalf(q: jax.Array) -> jax.Array:
    """Pack signed int4 values (last dim even) two per byte, split-half: byte
    ``d`` holds value ``d`` in the lo nibble and value ``d + D/2`` in the hi
    nibble. Unpacking is then a lane-dim concat (TPU-cheap), and any write that
    covers a full last-dim row (a token's K/V vector) maps to whole bytes."""
    d = q.shape[-1]
    lo = q[..., : d // 2] & 0x0F
    hi = (q[..., d // 2 :] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4_splithalf(b: jax.Array) -> jax.Array:
    """Inverse of pack_int4_splithalf; sign-extends via arithmetic shifts."""
    lo = (b << 4).astype(jnp.int8) >> 4
    hi = b >> 4
    return jnp.concatenate([lo, hi], axis=-1).astype(jnp.int8)


def dequantize_pages(q: jax.Array, scale: jax.Array, *, bits: int) -> jax.Array:
    """q: (..., page_size, Dq) intN bytes; scale: (...) f32 per (page, head).
    Returns f32 (..., page_size, D) — the decode half of PagedQuantSpec."""
    if bits == 4:
        q = unpack_int4_splithalf(q)
    return q.astype(jnp.float32) * scale[..., None, None]


def _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, *, scale):
    """One online-softmax accumulation step over a (page_size, D) K/V tile —
    shared by the f32 and the dequantizing kernels (identical math)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, page_size)
    s = jnp.where(live, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _paged_decode_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    len_ref,   # scalar prefetch: (B,) int32 live token counts
    q_ref,     # (1, 1, G, D)
    k_ref,     # (1, page_size, D) — physical page picked by the index map
    v_ref,     # (1, page_size, D)
    o_ref,     # (1, 1, G, D)
    acc_ref,   # (G, D) f32
    m_ref,     # (G, 1) f32
    l_ref,     # (G, 1) f32
    *,
    scale: float,
    page_size: int,
    block_pages: int,
):
    b = pl.program_id(0)
    # the page loop is structured as (compute block jb) x (page-in-block ji):
    # the pages_per_compute_block schedule knob of production paged kernels,
    # picked per (model, kv_dtype, batch bucket) by kernels/autotune.py
    jb, ji = pl.program_id(2), pl.program_id(3)
    j = jb * block_pages + ji
    last = (jb == pl.num_programs(2) - 1) & (ji == pl.num_programs(3) - 1)
    g_sz = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]
    # absolute position of slot i in logical page j is j*page_size + i
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (g_sz, page_size), 1)
    live = k_pos < seq_len

    @pl.when(j * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)     # (page_size, D)
        v = v_ref[0].astype(jnp.float32)
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(last)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    block_pages: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA decode against a paged KV pool.

    q: (B, Hq, 1, D); k_pool/v_pool: (num_pages, Hkv, page_size, D) — the
    LayoutPaged codomain factored as an ndarray (layout.pool_shape());
    block_tables: (B, max_pages) int32, row b = physical page of logical page j
    (entries past the sequence's allocation must still be valid pool indices —
    point them at a reserved null page); context_lens: (B,) int32, positions
    < context_lens[b] attend (the current token's K/V must already be written).

    ``block_pages`` (must divide max_pages; ops.effective_block_pages
    sanitizes) is the kernel's block-shape knob: the page axis of the grid is
    factored into (compute blocks, pages per block), the schedule structure
    production paged kernels use to batch page DMAs per compute block. DMA
    granularity here stays one page per grid step (scattered physical pages
    cannot share one BlockSpec window); the knob exists so configurations
    tuned on the jnp twin — where it sets the real gather granularity — carry
    through this kernel's grid unchanged.
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    num_pages, hkv, page_size, _ = k_pool.shape
    assert tq == 1 and hq % hkv == 0
    group = hq // hkv
    max_pages = block_tables.shape[1]
    bp = max(1, int(block_pages))
    if max_pages % bp:
        raise ValueError(
            f"block_pages {bp} must divide max_pages {max_pages} "
            "(ops.effective_block_pages picks a valid divisor)"
        )
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kern = functools.partial(
        _paged_decode_kernel, scale=scale, page_size=page_size, block_pages=bp
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages // bp, bp),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, h, jb, ji, bt, ln: (bb, h, 0, 0)),
            # the LayoutPaged indirection: logical page jb*bp + ji of sequence
            # bb DMAs physical page block_tables[bb, jb*bp + ji]
            pl.BlockSpec((1, None, page_size, d),
                         lambda bb, h, jb, ji, bt, ln: (bt[bb, jb * bp + ji], h, 0, 0)),
            pl.BlockSpec((1, None, page_size, d),
                         lambda bb, h, jb, ji, bt, ln: (bt[bb, jb * bp + ji], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda bb, h, jb, ji, bt, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(b, hq, 1, d)


def paged_decode_attention_jnp(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    block_pages: int | None = None,
) -> jax.Array:
    """jnp twin: gather each sequence's pages by table, mask by length.

    Identical semantics to paged_flash_decode. With ``block_pages`` unset the
    whole table is gathered at once — O(B·max_pages·page_size) peak memory.
    With ``block_pages`` set, the gather is blocked: a lax.scan over page
    blocks of that width with an online-softmax carry, so peak gathered K/V is
    O(B·block_pages·page_size) — here the knob really is the working-set
    granularity, which is what kernels/autotune.py times.
    """
    b, hq, tq, d = q.shape
    _, hkv, page_size, _ = k_pool.shape
    assert tq == 1 and hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    max_pages = block_tables.shape[1]
    if block_pages and block_pages < max_pages:
        return _paged_decode_jnp_blocked(
            q, k_pool, v_pool, block_tables, context_lens,
            scale=scale, block_pages=int(block_pages),
        )
    # (B, max_pages, Hkv, ps, D) -> (B, Hkv, max_pages*ps, D)
    k = jnp.moveaxis(k_pool[block_tables], 2, 1)
    v = jnp.moveaxis(v_pool[block_tables], 2, 1)
    s_len = k.shape[2] * page_size
    k = k.reshape(b, hkv, s_len, d).astype(jnp.float32)
    v = v.reshape(b, hkv, s_len, d).astype(jnp.float32)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k) * scale
    live = jnp.arange(s_len)[None, :] < context_lens[:, None]  # (B, S)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    # kernel-parity normalization: fully-masked rows (context_lens == 0) output
    # exact zeros, matching the Pallas safe_l path — not a softmax mean of garbage
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * live[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v) / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def _paged_decode_jnp_blocked(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float,
    block_pages: int,
) -> jax.Array:
    """Blocked twin: scan page blocks with an online-softmax (m, l, acc) carry.

    The table is padded to a whole number of blocks with page 0 (the engine's
    reserved null page — always a valid pool index); padded positions are
    masked dead, and dead scores are zeroed through the ``* live`` term rather
    than through exp() (exp(NEG_INF - NEG_INF) == 1 on an all-dead block, so
    masking must not rely on the exponent alone).
    """
    b, hq, tq, d = q.shape
    _, hkv, page_size, _ = k_pool.shape
    group = hq // hkv
    max_pages = block_tables.shape[1]
    nb = -(-max_pages // block_pages)
    pad = nb * block_pages - max_pages
    bt = jnp.pad(block_tables, ((0, 0), (0, pad)))  # null page 0 in the tail
    bt = bt.reshape(b, nb, block_pages)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    s_blk = block_pages * page_size

    def step(carry, jb):
        m, l, acc = carry
        # (B, bp, Hkv, ps, D) -> (B, Hkv, bp*ps, D): one block's working set
        k = jnp.moveaxis(k_pool[bt[:, jb]], 2, 1).reshape(b, hkv, s_blk, d)
        v = jnp.moveaxis(v_pool[bt[:, jb]], 2, 1).reshape(b, hkv, s_blk, d)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32)) * scale
        pos = jb * s_blk + jnp.arange(s_blk)
        in_table = pos < max_pages * page_size  # padded tail pages are dead
        live = (pos[None, :] < context_lens[:, None]) & in_table[None, :]
        s = jnp.where(live[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * live[:, None, None, :]
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgk,bhkd->bhgd", p, v.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, group, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------------
# quantized-pool decode: the accessor customization point inside the kernel
# ---------------------------------------------------------------------------------
def _paged_quant_decode_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    len_ref,   # scalar prefetch: (B,) int32 live token counts
    q_ref,     # (1, 1, G, D)
    kq_ref,    # (1, page_size, Dq) int8 — physical page picked by the index map
    ks_ref,    # (1,) f32 — that page's per-head K scale
    vq_ref,    # (1, page_size, Dq) int8
    vs_ref,    # (1,) f32
    o_ref,     # (1, 1, G, D)
    acc_ref,   # (G, D) f32
    m_ref,     # (G, 1) f32
    l_ref,     # (G, 1) f32
    *,
    scale: float,
    page_size: int,
    bits: int,
    block_pages: int,
):
    b = pl.program_id(0)
    jb, ji = pl.program_id(2), pl.program_id(3)
    j = jb * block_pages + ji
    last = (jb == pl.num_programs(2) - 1) & (ji == pl.num_programs(3) - 1)
    g_sz = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (g_sz, page_size), 1)
    live = k_pos < seq_len

    @pl.when(j * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        kq = kq_ref[0]                       # (page_size, Dq) int8
        vq = vq_ref[0]
        if bits == 4:
            kq = unpack_int4_splithalf(kq)   # lane concat: (page_size, D)
            vq = unpack_int4_splithalf(vq)
        k = kq.astype(jnp.float32) * ks_ref[0]
        v = vq.astype(jnp.float32) * vs_ref[0]
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(last)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_decode_quant(
    q: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
    block_pages: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA decode against an intN paged KV pool.

    q: (B, Hq, 1, D); k_q/v_q: (num_pages, Hkv, page_size, Dq) int8 with
    Dq = D (int8) or D // 2 (int4, split-half nibbles); k_scale/v_scale:
    (num_pages, Hkv) f32, one scale per (physical page, kv head) — the
    PagedQuantSpec encoding. Block table / length / ``block_pages`` semantics
    are identical to ``paged_flash_decode``: the layout indirection is
    untouched, the scales ride the same ``bt[bb, j]`` index map as the page
    tiles, and the page grid axis is factored (compute blocks, pages/block).
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    num_pages, hkv, page_size, dq = k_q.shape
    assert tq == 1 and hq % hkv == 0
    assert dq == (d if bits == 8 else d // 2)
    group = hq // hkv
    max_pages = block_tables.shape[1]
    bp = max(1, int(block_pages))
    if max_pages % bp:
        raise ValueError(
            f"block_pages {bp} must divide max_pages {max_pages} "
            "(ops.effective_block_pages picks a valid divisor)"
        )
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kern = functools.partial(
        _paged_quant_decode_kernel, scale=scale, page_size=page_size, bits=bits,
        block_pages=bp,
    )
    page_spec = pl.BlockSpec(
        (1, None, page_size, dq),
        lambda bb, h, jb, ji, bt, ln: (bt[bb, jb * bp + ji], h, 0, 0),
    )
    scale_spec = pl.BlockSpec(
        (1, None), lambda bb, h, jb, ji, bt, ln: (bt[bb, jb * bp + ji], h)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages // bp, bp),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, h, jb, ji, bt, ln: (bb, h, 0, 0)),
            page_spec,
            scale_spec,
            page_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, group, d), lambda bb, h, jb, ji, bt, ln: (bb, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
        qg, k_q, k_scale, v_q, v_scale,
    )
    return out.reshape(b, hq, 1, d)


def paged_decode_attention_quant_jnp(
    q: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
    block_pages: int | None = None,
) -> jax.Array:
    """jnp twin of paged_flash_decode_quant: dequantize the whole pool, then the
    f32 gather path — manifestly the same semantics, O(pool) extra memory."""
    k_pool = dequantize_pages(k_q, k_scale, bits=bits)
    v_pool = dequantize_pages(v_q, v_scale, bits=bits)
    return paged_decode_attention_jnp(
        q, k_pool, v_pool, block_tables, context_lens, scale=scale,
        block_pages=block_pages,
    )


# ---------------------------------------------------------------------------------
# chunked prefill: a Q-chunk against all previously resident paged KV
# ---------------------------------------------------------------------------------
# Two-part attention per chunk: (1) the PAST — pool positions < cursor, read
# through the block table exactly as decode does (dequantized in-kernel for
# intN pages); (2) the PRESENT — the chunk's own K/V, handed in as fresh f32
# tensors with intra-chunk causal masking, NEVER read back through the pool.
# Part 2 is what keeps a single-chunk prefill bit-equivalent to a monolithic
# one even over quantized pools: the chunk's own tokens attend each other at
# full precision (as monolithic prefill does), and only CROSS-chunk attention
# pays the representation — the same boundary monolithic decode pays at its
# first step. Both parts fold into one online softmax (_flash_update), with
# the chunk tile applied as the last accumulation step.


def _past_live(cursor, c: int, group: int, page_size: int, j):
    """(C*G, page_size) liveness of logical page j for the past part: every
    slot before the chunk start (causality across the boundary is automatic —
    all past positions precede every chunk row). Rows are t-major blocks of
    size G (see the reshape in the callers)."""
    rows = c * group
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1
    )
    return k_pos < cursor


def _chunk_self_live(c: int, group: int):
    """(C*G, C) intra-chunk causal mask: row t attends chunk column tk <= t."""
    rows = c * group
    t = jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) // group
    tk = jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
    return tk <= t


def _paged_chunk_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    cur_ref,   # scalar prefetch: (B,) int32 chunk start positions (resident KV)
    q_ref,     # (1, 1, C*G, D) — chunk queries, t-major rows
    ck_ref,    # (1, 1, C, D) — the chunk's own f32 K (never from the pool)
    cv_ref,    # (1, 1, C, D)
    k_ref,     # (1, page_size, D) — physical page picked by the index map
    v_ref,     # (1, page_size, D)
    o_ref,     # (1, 1, C*G, D)
    acc_ref,   # (C*G, D) f32
    m_ref,     # (C*G, 1) f32
    l_ref,     # (C*G, 1) f32
    *,
    scale: float,
    page_size: int,
    chunk: int,
    group: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j * page_size < cur_ref[b])
    def _past():
        q = q_ref[0, 0].astype(jnp.float32)  # (C*G, D)
        k = k_ref[0].astype(jnp.float32)     # (page_size, D)
        v = v_ref[0].astype(jnp.float32)
        live = _past_live(cur_ref[b], chunk, group, page_size, j)
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(j == nj - 1)
    def _present_and_finalize():
        q = q_ref[0, 0].astype(jnp.float32)
        ck = ck_ref[0, 0].astype(jnp.float32)  # (C, D)
        cv = cv_ref[0, 0].astype(jnp.float32)
        live = _chunk_self_live(chunk, group)
        _flash_update(q, ck, cv, live, acc_ref, m_ref, l_ref, scale=scale)
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_prefill_chunk(
    q: jax.Array,
    chunk_k: jax.Array,
    chunk_v: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    cursors: jax.Array,
    *,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """GQA chunked-prefill attention: past from the pool, present from f32.

    q: (B, Hq, C, D) — the chunk's queries, absolute positions
    cursors[b]..cursors[b]+C-1; chunk_k/chunk_v: (B, Hkv, C, D) the chunk's own
    freshly-projected K/V (attended intra-chunk causally at full precision);
    k_pool/v_pool: (num_pages, Hkv, page_size, D); block_tables: (B, max_pages)
    int32; cursors: (B,) int32 tokens resident BEFORE this chunk — the pool is
    read only below that bound, so the chunk's scattered pages (and anything
    past them) never feed back into its own attention. Rows past the chunk's
    valid length produce garbage the caller discards (their KV went to the
    null page, so nothing real ever attends them).
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, c, d = q.shape
    num_pages, hkv, page_size, _ = k_pool.shape
    assert hq % hkv == 0
    group = hq // hkv
    max_pages = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    # t-major rows: (B, Hkv, C*G, D) with row t*G + g — _past_live's layout
    qg = jnp.swapaxes(q.reshape(b, hkv, group, c, d), 2, 3).reshape(
        b, hkv, c * group, d
    )

    kern = functools.partial(
        _paged_chunk_kernel, scale=scale, page_size=page_size, chunk=c, group=group
    )
    rows = c * group
    chunk_spec = pl.BlockSpec(
        (1, 1, c, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0)),
            chunk_spec,
            chunk_spec,
            pl.BlockSpec(
                (1, None, page_size, d),
                lambda bb, h, j, bt, cur: (bt[bb, j], h, 0, 0),
            ),
            pl.BlockSpec(
                (1, None, page_size, d),
                lambda bb, h, j, bt, cur: (bt[bb, j], h, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rows, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((rows, d), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32), cursors.astype(jnp.int32),
        qg, chunk_k, chunk_v, k_pool, v_pool,
    )
    # rows back to (B, Hkv, C, G, D) -> (B, Hq, C, D)
    return jnp.swapaxes(out.reshape(b, hkv, c, group, d), 2, 3).reshape(b, hq, c, d)


def paged_prefill_chunk_jnp(
    q: jax.Array,
    chunk_k: jax.Array,
    chunk_v: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    cursors: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """jnp twin: concatenate [gathered past pages | the chunk's own f32 K/V]
    along the key axis, mask (past below cursor, present causally), one
    softmax — identical semantics to the kernel's two-part online update."""
    b, hq, c, d = q.shape
    _, hkv, page_size, _ = k_pool.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    k = jnp.moveaxis(k_pool[block_tables], 2, 1)
    v = jnp.moveaxis(v_pool[block_tables], 2, 1)
    s_len = k.shape[2] * page_size
    k = jnp.concatenate(
        [k.reshape(b, hkv, s_len, d), chunk_k.astype(k.dtype)], axis=2
    ).astype(jnp.float32)
    v = jnp.concatenate(
        [v.reshape(b, hkv, s_len, d), chunk_v.astype(v.dtype)], axis=2
    ).astype(jnp.float32)
    qg = q.reshape(b, hkv, group, c, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * scale
    t_q = jnp.arange(c)
    past = jnp.arange(s_len)[None, None, :] < cursors[:, None, None]  # (B, 1, S)
    past = jnp.broadcast_to(past, (b, c, s_len))
    present = (t_q[None, :] <= t_q[:, None])[None]  # (1, C, C) causal
    present = jnp.broadcast_to(present, (b, c, c))
    live = jnp.concatenate([past, present], axis=-1)[:, None, None]  # (B,1,1,C,S+C)
    s = jnp.where(live, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * live
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v) / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, c, d).astype(q.dtype)


def _paged_chunk_quant_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    cur_ref,   # scalar prefetch: (B,) int32 chunk start positions
    q_ref,     # (1, 1, C*G, D)
    ck_ref,    # (1, 1, C, D) f32 — the chunk's own K, never from the pool
    cv_ref,    # (1, 1, C, D) f32
    kq_ref,    # (1, page_size, Dq) int8 — physical page picked by the index map
    ks_ref,    # (1,) f32 — that page's per-head K scale
    vq_ref,    # (1, page_size, Dq) int8
    vs_ref,    # (1,) f32
    o_ref,     # (1, 1, C*G, D)
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    page_size: int,
    chunk: int,
    group: int,
    bits: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j * page_size < cur_ref[b])
    def _past():
        q = q_ref[0, 0].astype(jnp.float32)
        kq = kq_ref[0]
        vq = vq_ref[0]
        if bits == 4:
            kq = unpack_int4_splithalf(kq)
            vq = unpack_int4_splithalf(vq)
        k = kq.astype(jnp.float32) * ks_ref[0]
        v = vq.astype(jnp.float32) * vs_ref[0]
        live = _past_live(cur_ref[b], chunk, group, page_size, j)
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(j == nj - 1)
    def _present_and_finalize():
        q = q_ref[0, 0].astype(jnp.float32)
        ck = ck_ref[0, 0].astype(jnp.float32)
        cv = cv_ref[0, 0].astype(jnp.float32)
        live = _chunk_self_live(chunk, group)
        _flash_update(q, ck, cv, live, acc_ref, m_ref, l_ref, scale=scale)
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_prefill_chunk_quant(
    q: jax.Array,
    chunk_k: jax.Array,
    chunk_v: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    cursors: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked-prefill attention over an intN paged pool: the past part
    dequantizes page tiles through the same (page, head) scale index maps as
    paged_flash_decode_quant; the present part attends the chunk's own f32
    K/V, so intra-chunk attention never pays the representation."""
    interpret = use_interpret() if interpret is None else interpret
    b, hq, c, d = q.shape
    num_pages, hkv, page_size, dq = k_q.shape
    assert hq % hkv == 0
    assert dq == (d if bits == 8 else d // 2)
    group = hq // hkv
    max_pages = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = jnp.swapaxes(q.reshape(b, hkv, group, c, d), 2, 3).reshape(
        b, hkv, c * group, d
    )

    kern = functools.partial(
        _paged_chunk_quant_kernel, scale=scale, page_size=page_size, chunk=c,
        group=group, bits=bits,
    )
    rows = c * group
    chunk_spec = pl.BlockSpec((1, 1, c, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0))
    page_spec = pl.BlockSpec(
        (1, None, page_size, dq), lambda bb, h, j, bt, cur: (bt[bb, j], h, 0, 0)
    )
    scale_spec = pl.BlockSpec((1, None), lambda bb, h, j, bt, cur: (bt[bb, j], h))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0)),
            chunk_spec,
            chunk_spec,
            page_spec,
            scale_spec,
            page_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rows, d), lambda bb, h, j, bt, cur: (bb, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((rows, d), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32), cursors.astype(jnp.int32),
        qg, chunk_k, chunk_v, k_q, k_scale, v_q, v_scale,
    )
    return jnp.swapaxes(out.reshape(b, hkv, c, group, d), 2, 3).reshape(b, hq, c, d)


def paged_prefill_chunk_quant_jnp(
    q: jax.Array,
    chunk_k: jax.Array,
    chunk_v: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    cursors: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
) -> jax.Array:
    """jnp twin of paged_flash_prefill_chunk_quant: dequantize the whole pool,
    then the f32 chunk gather path (the chunk's own K/V stay f32 throughout)."""
    k_pool = dequantize_pages(k_q, k_scale, bits=bits)
    v_pool = dequantize_pages(v_q, v_scale, bits=bits)
    return paged_prefill_chunk_jnp(
        q, chunk_k, chunk_v, k_pool, v_pool, block_tables, cursors, scale=scale
    )
