"""Paged-attention decode — Pallas TPU kernel over a LayoutPaged KV pool.

The KV cache is a pool of fixed-size pages, (num_pages, Hkv, page_size, D), and
each sequence owns a row of a block table mapping logical page j -> physical
page id. This is core.layouts.LayoutPaged made executable: the kernel's k/v
BlockSpec index maps read the block table through scalar prefetch
(PrefetchScalarGridSpec), so the layout's index->offset indirection runs on the
scalar core while pages DMA into VMEM — no dense (B, Hkv, S, D) cache ever
materializes and pages of different sequences can live anywhere in the pool.

Per-sequence lengths (continuous batching: every row of the batch is at a
different position) ride in through the second prefetch operand and drive both
the online-softmax masking and the page skip predicate.

``paged_decode_attention_jnp`` is the identical-semantics twin (gather pages by
table, mask by length) used off-TPU and as the differentiable/cheap fallback;
both are validated against ref.attention on densified pools in
tests/test_serving_engine.py.

Quantized pools (the accessor axis composed with the layout axis): the
``*_quant`` variants consume int8/int4 page pools with one f32 scale per
(physical page, kv head) — serving/engine/kvquant.PagedQuantSpec's encoding.
``paged_flash_decode_quant`` DMAs int8 page tiles and their (page, head) scale
through the SAME block-table index maps as the f32 kernel (the layout is
untouched; only the element representation changed) and dequantizes in VMEM
next to the flash update. int4 pages pack two values per byte SPLIT-HALF along
the feature dim (byte d = feature d in the lo nibble, feature d + D/2 in the
hi), so in-kernel dequant is a lane concat — never an interleave — and a
single token's scatter stays nibble-local to its own (slot, :) row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import use_interpret

NEG_INF = -1e30


# ---------------------------------------------------------------------------------
# int4 nibble packing (split-half) + page dequantization
# ---------------------------------------------------------------------------------
def pack_int4_splithalf(q: jax.Array) -> jax.Array:
    """Pack signed int4 values (last dim even) two per byte, split-half: byte
    ``d`` holds value ``d`` in the lo nibble and value ``d + D/2`` in the hi
    nibble. Unpacking is then a lane-dim concat (TPU-cheap), and any write that
    covers a full last-dim row (a token's K/V vector) maps to whole bytes."""
    d = q.shape[-1]
    lo = q[..., : d // 2] & 0x0F
    hi = (q[..., d // 2 :] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4_splithalf(b: jax.Array) -> jax.Array:
    """Inverse of pack_int4_splithalf; sign-extends via arithmetic shifts."""
    lo = (b << 4).astype(jnp.int8) >> 4
    hi = b >> 4
    return jnp.concatenate([lo, hi], axis=-1).astype(jnp.int8)


def dequantize_pages(q: jax.Array, scale: jax.Array, *, bits: int) -> jax.Array:
    """q: (..., page_size, Dq) intN bytes; scale: (...) f32 per (page, head).
    Returns f32 (..., page_size, D) — the decode half of PagedQuantSpec."""
    if bits == 4:
        q = unpack_int4_splithalf(q)
    return q.astype(jnp.float32) * scale[..., None, None]


def _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, *, scale):
    """One online-softmax accumulation step over a (page_size, D) K/V tile —
    shared by the f32 and the dequantizing kernels (identical math)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, page_size)
    s = jnp.where(live, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _paged_decode_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    len_ref,   # scalar prefetch: (B,) int32 live token counts
    q_ref,     # (1, 1, G, D)
    k_ref,     # (1, page_size, D) — physical page picked by the index map
    v_ref,     # (1, page_size, D)
    o_ref,     # (1, 1, G, D)
    acc_ref,   # (G, D) f32
    m_ref,     # (G, 1) f32
    l_ref,     # (G, 1) f32
    *,
    scale: float,
    page_size: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g_sz = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]
    # absolute position of slot i in logical page j is j*page_size + i
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (g_sz, page_size), 1)
    live = k_pos < seq_len

    @pl.when(j * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)     # (page_size, D)
        v = v_ref[0].astype(jnp.float32)
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_decode(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA decode against a paged KV pool.

    q: (B, Hq, 1, D); k_pool/v_pool: (num_pages, Hkv, page_size, D) — the
    LayoutPaged codomain factored as an ndarray (layout.pool_shape());
    block_tables: (B, max_pages) int32, row b = physical page of logical page j
    (entries past the sequence's allocation must still be valid pool indices —
    point them at a reserved null page); context_lens: (B,) int32, positions
    < context_lens[b] attend (the current token's K/V must already be written).
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    num_pages, hkv, page_size, _ = k_pool.shape
    assert tq == 1 and hq % hkv == 0
    group = hq // hkv
    max_pages = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kern = functools.partial(_paged_decode_kernel, scale=scale, page_size=page_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
            # the LayoutPaged indirection: logical page j of sequence bb DMAs
            # physical page block_tables[bb, j]
            pl.BlockSpec((1, None, page_size, d), lambda bb, h, j, bt, ln: (bt[bb, j], h, 0, 0)),
            pl.BlockSpec((1, None, page_size, d), lambda bb, h, j, bt, ln: (bt[bb, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(b, hq, 1, d)


def paged_decode_attention_jnp(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """jnp twin: gather each sequence's pages by table, mask by length.

    Identical semantics to paged_flash_decode; O(B·max_pages·page_size) gather.
    """
    b, hq, tq, d = q.shape
    _, hkv, page_size, _ = k_pool.shape
    assert tq == 1 and hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    # (B, max_pages, Hkv, ps, D) -> (B, Hkv, max_pages*ps, D)
    k = jnp.moveaxis(k_pool[block_tables], 2, 1)
    v = jnp.moveaxis(v_pool[block_tables], 2, 1)
    s_len = k.shape[2] * page_size
    k = k.reshape(b, hkv, s_len, d).astype(jnp.float32)
    v = v.reshape(b, hkv, s_len, d).astype(jnp.float32)
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k) * scale
    live = jnp.arange(s_len)[None, :] < context_lens[:, None]  # (B, S)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    # kernel-parity normalization: fully-masked rows (context_lens == 0) output
    # exact zeros, matching the Pallas safe_l path — not a softmax mean of garbage
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * live[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v) / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------------
# quantized-pool decode: the accessor customization point inside the kernel
# ---------------------------------------------------------------------------------
def _paged_quant_decode_kernel(
    bt_ref,    # scalar prefetch: (B, max_pages) int32 block table
    len_ref,   # scalar prefetch: (B,) int32 live token counts
    q_ref,     # (1, 1, G, D)
    kq_ref,    # (1, page_size, Dq) int8 — physical page picked by the index map
    ks_ref,    # (1,) f32 — that page's per-head K scale
    vq_ref,    # (1, page_size, Dq) int8
    vs_ref,    # (1,) f32
    o_ref,     # (1, 1, G, D)
    acc_ref,   # (G, D) f32
    m_ref,     # (G, 1) f32
    l_ref,     # (G, 1) f32
    *,
    scale: float,
    page_size: int,
    bits: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    g_sz = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = len_ref[b]
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (g_sz, page_size), 1)
    live = k_pos < seq_len

    @pl.when(j * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        kq = kq_ref[0]                       # (page_size, Dq) int8
        vq = vq_ref[0]
        if bits == 4:
            kq = unpack_int4_splithalf(kq)   # lane concat: (page_size, D)
            vq = unpack_int4_splithalf(vq)
        k = kq.astype(jnp.float32) * ks_ref[0]
        v = vq.astype(jnp.float32) * vs_ref[0]
        _flash_update(q, k, v, live, acc_ref, m_ref, l_ref, scale=scale)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def paged_flash_decode_quant(
    q: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA decode against an intN paged KV pool.

    q: (B, Hq, 1, D); k_q/v_q: (num_pages, Hkv, page_size, Dq) int8 with
    Dq = D (int8) or D // 2 (int4, split-half nibbles); k_scale/v_scale:
    (num_pages, Hkv) f32, one scale per (physical page, kv head) — the
    PagedQuantSpec encoding. Block table / length semantics are identical to
    ``paged_flash_decode``: the layout indirection is untouched, the scales
    ride the same ``bt[bb, j]`` index map as the page tiles.
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    num_pages, hkv, page_size, dq = k_q.shape
    assert tq == 1 and hq % hkv == 0
    assert dq == (d if bits == 8 else d // 2)
    group = hq // hkv
    max_pages = block_tables.shape[1]
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kern = functools.partial(
        _paged_quant_decode_kernel, scale=scale, page_size=page_size, bits=bits
    )
    page_spec = pl.BlockSpec(
        (1, None, page_size, dq), lambda bb, h, j, bt, ln: (bt[bb, j], h, 0, 0)
    )
    scale_spec = pl.BlockSpec((1, None), lambda bb, h, j, bt, ln: (bt[bb, j], h))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
            page_spec,
            scale_spec,
            page_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda bb, h, j, bt, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
        qg, k_q, k_scale, v_q, v_scale,
    )
    return out.reshape(b, hq, 1, d)


def paged_decode_attention_quant_jnp(
    q: jax.Array,
    k_q: jax.Array,
    k_scale: jax.Array,
    v_q: jax.Array,
    v_scale: jax.Array,
    block_tables: jax.Array,
    context_lens: jax.Array,
    *,
    bits: int = 8,
    scale: float | None = None,
) -> jax.Array:
    """jnp twin of paged_flash_decode_quant: dequantize the whole pool, then the
    f32 gather path — manifestly the same semantics, O(pool) extra memory."""
    k_pool = dequantize_pages(k_q, k_scale, bits=bits)
    v_pool = dequantize_pages(v_q, v_scale, bits=bits)
    return paged_decode_attention_jnp(
        q, k_pool, v_pool, block_tables, context_lens, scale=scale
    )
