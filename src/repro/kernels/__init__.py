"""Pallas TPU kernels for the perf-critical compute layers + pure-jnp oracles.

Layout: per-kernel modules (pl.pallas_call + explicit BlockSpec VMEM tiling),
``ops.py`` as the jit'd dispatching wrapper layer, ``ref.py`` as the oracles.
Kernels are TPU-targeted and validated in interpret mode on CPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
