"""MatVec — y = A @ x, layout-parameterized (paper Fig. 6).

The paper's experiment: the SAME algorithm with layout_right vs layout_left A is
3–7x apart on CPU and 10x (inverted) on GPU. On TPU the mechanism is the lane
axis: a matvec wants the contraction dimension (j) on the 128-wide lanes so each
VREG load feeds the VPU multiply-accumulate directly.

  * layout_right  (A physical (I, J), j fastest): contraction on lanes — good.
  * layout_left   (A physical (J, I), i fastest): contraction on sublanes — the
    kernel must reduce across sublanes (or transpose in VMEM); we implement it
    honestly (reduce over the sublane axis) so the compiled cost difference is
    visible in the roofline terms rather than hidden by a silent transpose.

Both kernels consume the SAME MdSpan semantics; the dispatch in ops.matvec picks
the schedule from ``span.layout`` — the paper's "change the layout in the type,
not the algorithm".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pick_block, use_interpret


def _matvec_right_kernel(a_ref, x_ref, y_ref):
    a = a_ref[...].astype(jnp.float32)  # (bi, J)
    x = x_ref[...].astype(jnp.float32)  # (J,)
    y_ref[...] = (a @ x).astype(y_ref.dtype)


def matvec_right(a: jax.Array, x: jax.Array, *, block_i: int = 256, interpret: bool | None = None):
    """A physical (I, J) — contraction on lanes."""
    interpret = use_interpret() if interpret is None else interpret
    i, j = a.shape
    bi = pick_block(i, block_i, align=8)
    grid = (cdiv(i, bi),)
    return pl.pallas_call(
        _matvec_right_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, j), lambda g: (g, 0)),
            pl.BlockSpec((j,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((i,), x.dtype),
        interpret=interpret,
    )(a, x)


def _matvec_left_kernel(at_ref, x_ref, y_ref):
    at = at_ref[...].astype(jnp.float32)  # (bj, bi): contraction dim on SUBLANES
    x = x_ref[...].astype(jnp.float32)  # (bj,)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # reduce across the sublane axis — the honest cost of the "wrong" layout
    y_ref[...] += jnp.sum(at * x[:, None], axis=0).astype(y_ref.dtype)


def matvec_left(at: jax.Array, x: jax.Array, *, block_i: int = 256, block_j: int = 512,
                interpret: bool | None = None):
    """A stored column-major: ``at`` is the physical (J, I) buffer."""
    interpret = use_interpret() if interpret is None else interpret
    j, i = at.shape
    bi = pick_block(i, block_i, align=128)
    bj = pick_block(j, block_j, align=8)
    grid = (cdiv(i, bi), cdiv(j, bj))
    y32 = pl.pallas_call(
        _matvec_left_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bj, bi), lambda gi, gj: (gj, gi)),
            pl.BlockSpec((bj,), lambda gi, gj: (gj,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda gi, gj: (gi,)),
        out_shape=jax.ShapeDtypeStruct((i,), jnp.float32),
        interpret=interpret,
    )(at, x)
    return y32.astype(x.dtype)
