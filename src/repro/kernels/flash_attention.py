"""Flash attention (GQA, causal / local-window / cross) — Pallas TPU kernel.

Layout/accessor integration: q/k/v arrive in the (B, H, T, D) logical domain; the
kernel's BlockSpecs implement the LayoutTiledTPU schedule (T on sublanes, D on
lanes, online-softmax streaming over KV blocks so the T×T score matrix never
exists in memory — the layout-mapping view of flash attention is that the score
"tensor" has a layout whose codomain is O(T·D), not O(T²)).

Two entry points:
  flash_attention  — Tq×Tk blocks, causal/window masks, used for prefill.
  flash_decode     — Tq == 1 (GQA group on sublanes), one-token decode vs a long
                     KV cache with a traced length/position.

Both validated against ref.attention in interpret mode (tests/test_kernels_attn.py).
Training uses the differentiable blocked-jnp twin (models/attention.py) — see
DESIGN.md: dry-run rooflines are computed from the jnp twin so compiled cost
reflects the algorithm, not the CPU interpreter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import cdiv, pick_block, use_interpret

NEG_INF = -1e30


def _flash_kernel(
    pos_ref,  # (1,) int32: absolute position of q row 0
    q_ref,    # (1, 1, bq, D)
    k_ref,    # (1, 1, bk, D)
    v_ref,    # (1, 1, bk, D)
    o_ref,    # (1, 1, bq, D)
    acc_ref,  # scratch (bq, D) f32
    m_ref,    # scratch (bq, 1) f32
    l_ref,    # scratch (bq, 1) f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = pos_ref[0] + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    live = k_pos < kv_len
    if causal:
        live = live & (k_pos <= q_pos)
    if window is not None:
        live = live & (k_pos > q_pos - window)

    # Skip fully-masked KV blocks (causal: ki*bk > pos + (qi+1)*bq - 1).
    run = jnp.asarray(True)
    if causal:
        run = (ki * bk) <= (pos_ref[0] + (qi + 1) * bq - 1)
    if window is not None:
        run = run & ((ki + 1) * bk - 1 > pos_ref[0] + qi * bq - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D); GQA via Hq % Hkv == 0.

    ``q_offset`` may be a traced scalar (decode/chunked prefill): absolute position
    of q[..., 0, :] for causal/window masking.
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    bq = pick_block(tq, block_q, align=8 if tq >= 8 else 1)
    bk = pick_block(tk, block_k, align=128 if tk >= 128 else 1)
    grid = (b, hq, cdiv(tq, bq), cdiv(tk, bk))
    pos = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (1,))

    kern = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        kv_len=tk,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, qi, ki: (0,)),
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k, v)


def _decode_kernel(
    pos_ref,  # (1,) int32: current decode position (exclusive cache length - 1)
    q_ref,    # (1, 1, G, D)
    k_ref,    # (1, 1, bk, D)
    v_ref,    # (1, 1, bk, D)
    o_ref,    # (1, 1, G, D)
    acc_ref,  # (G, D) f32
    m_ref,    # (G, 1) f32
    l_ref,    # (G, 1) f32
    *,
    scale: float,
    bk: int,
    window: int | None,
):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    g_sz = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (g_sz, bk), 1)
    live = k_pos <= pos
    if window is not None:
        live = live & (k_pos > pos - window)

    run = (ki * bk) <= pos
    if window is not None:
        run = run & ((ki + 1) * bk - 1 > pos - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos,
    *,
    window: int | None = None,
    scale: float | None = None,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA decode. q: (B, Hq, 1, D); caches: (B, Hkv, S, D); ``pos`` is a
    traced int32 scalar — the index of the CURRENT token (cache[pos] is valid).

    The GQA group dimension rides the sublanes: q reshaped to (B, Hkv, G, D) so each
    grid step does a (G × bk) score block per kv head.
    """
    interpret = use_interpret() if interpret is None else interpret
    b, hq, tq, d = q.shape
    _, hkv, s_len, _ = k_cache.shape
    assert tq == 1 and hq % hkv == 0
    group = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, d)
    bk = pick_block(s_len, block_k, align=128 if s_len >= 128 else 1)
    grid = (b, hkv, cdiv(s_len, bk))
    pos_arr = jnp.reshape(jnp.asarray(pos, jnp.int32), (1,))
    kern = functools.partial(_decode_kernel, scale=scale, bk=bk, window=window)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, ki: (0,)),
            pl.BlockSpec((1, 1, group, d), lambda bb, h, ki: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, ki: (bb, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, ki: (bb, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda bb, h, ki: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(b, hq, 1, d)
