"""RG-LRU gated linear recurrence — Pallas TPU kernel (recurrentgemma's temporal mix).

h_t = a_t ⊙ h_{t-1} + b_t, carried across sequence chunks in VMEM scratch (the
same sequential-grid state pattern as ssd_scan). Within a chunk the recurrence is
inherently sequential in t but fully vector-parallel across the width W — a
`fori_loop` of W-wide VPU FMAs, which is exactly the hardware shape of the op.
Gate/decay computation (a = exp(-c·softplus(Λ)·σ(gate)), b = √(1-a²)·σ(i)·x)
happens OUTSIDE the kernel (it is embarrassingly parallel and XLA-fusable); the
kernel owns only the stateful part.

Validated against ref.rglru / the associative-scan twin in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import use_interpret


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hf_ref, state_ref, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (Q, W)
    b = b_ref[0].astype(jnp.float32)
    q = a.shape[0]

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, q, step, state_ref[...][0])
    state_ref[...] = h[None]

    @pl.when(ci == nc - 1)
    def _emit():
        hf_ref[0] = state_ref[...][0]


def rglru_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
    return_final_state: bool = False,
    interpret: bool | None = None,
):
    """a, b: (B, T, W) precomputed decay/input terms; returns h: (B, T, W).

    T must divide by ``chunk`` (ops-level padding handles ragged tails).
    """
    interpret = use_interpret() if interpret is None else interpret
    bsz, t, w = a.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    h0 = (
        jnp.zeros((bsz, w), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    kern = functools.partial(_rglru_kernel, nc=nc)
    y, hf = pl.pallas_call(
        kern,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, w), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, w), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((1, w), lambda bb, ci: (bb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, w), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((1, w), lambda bb, ci: (bb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    if return_final_state:
        return y, hf
    return y
