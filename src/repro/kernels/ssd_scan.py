"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSD insight: the SSM recurrence S_t = a_t S_{t-1} + dt_t x_t B_t^T factorizes
into (i) an intra-chunk part that is a masked-decay attention-like matmul (MXU
food) and (ii) an inter-chunk part that is a short recurrence over chunk states.
The kernel runs the chunk grid SEQUENTIALLY per batch element, carrying the
(H, P, N) state in VMEM scratch — the TPU-native replacement for the paper-adjacent
GPU implementation's warp-level scan: the systolic MXU does the within-chunk work,
the sequential grid does the across-chunk work, and nothing O(T^2) ever exists.

Math (per head h; a_t = exp(dt_t * A_h), s_t = cumsum(dt * A)):
  y_t      = C_t . S_t
           = exp(s_t) * (C_t . S_0)                       [inter-chunk]
           + sum_{u<=t} exp(s_t - s_u) dt_u (C_t.B_u) x_u  [intra-chunk, masked matmul]
  S_chunk  = exp(s_Q) S_0 + sum_u exp(s_Q - s_u) dt_u x_u B_u^T

ngroups == 1 (mamba2-780m's configuration); general G handled by the oracle and
the jnp twin in models/ssm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import cdiv, use_interpret


def _ssd_kernel(
    x_ref,      # (1, Q, H, P)
    dt_ref,     # (1, Q, H)
    a_ref,      # (H,)
    b_ref,      # (1, Q, N)
    c_ref,      # (1, Q, N)
    s0_ref,     # (1, H, P, N) initial state
    y_ref,      # out (1, Q, H, P)
    sf_ref,     # out (1, H, P, N) final state
    state_ref,  # scratch (H, P, N) f32
    *,
    nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)      # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q, H)
    A = a_ref[...].astype(jnp.float32)    # (H,)
    Bm = b_ref[0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)     # (Q, N)
    S0 = state_ref[...]                   # (H, P, N)

    q = x.shape[0]
    lam = dt * A[None, :]                 # (Q, H), negative
    s = jnp.cumsum(lam, axis=0)           # (Q, H)

    # inter-chunk: y_inter[t, h, p] = exp(s[t,h]) * sum_n C[t,n] S0[h,p,n]
    y_inter = jnp.einsum("qn,hpn->qhp", Cm, S0) * jnp.exp(s)[:, :, None]

    # intra-chunk: M[h, t, u] = (C_t.B_u) exp(s_t - s_u) dt_u for u <= t
    cb = jnp.einsum("qn,un->qu", Cm, Bm)  # (Q, Q)
    seg = s[:, None, :] - s[None, :, :]   # (t, u, H)
    tri = jnp.tril(jnp.ones((q, q), jnp.float32))
    m = cb[:, :, None] * jnp.exp(jnp.minimum(seg, 0.0)) * dt[None, :, :] * tri[:, :, None]
    y_intra = jnp.einsum("tuh,uhp->thp", m, x)

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: S = exp(s_Q) S0 + sum_u exp(s_Q - s_u) dt_u x_u B_u^T
    decay_all = jnp.exp(s[-1])            # (H,)
    w = jnp.exp(s[-1][None, :] - s) * dt  # (Q, H)
    upd = jnp.einsum("qhp,qn->hpn", x * w[:, :, None], Bm)
    state_ref[...] = S0 * decay_all[:, None, None] + upd

    @pl.when(ci == nc - 1)
    def _emit_state():
        sf_ref[0] = state_ref[...]


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 64,
    initial_state: jax.Array | None = None,
    return_final_state: bool = False,
    interpret: bool | None = None,
):
    """x: (b, t, h, p); dt: (b, t, h); A: (h,); B/C: (b, t, 1, n) (ngroups == 1).

    t must divide by ``chunk`` (ops-level padding handles ragged tails).
    """
    interpret = use_interpret() if interpret is None else interpret
    b, t, h, p = x.shape
    n = B.shape[-1]
    assert B.shape[2] == 1 and C.shape[2] == 1, "pallas ssd_scan supports ngroups=1"
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    kern = functools.partial(_ssd_kernel, nc=nc)
    y, sf = pl.pallas_call(
        kern,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bb, ci: (bb, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((h,), lambda bb, ci: (0,)),
            pl.BlockSpec((1, chunk, n), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, ci: (bb, ci, 0)),
            pl.BlockSpec((1, h, p, n), lambda bb, ci: (bb, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bb, ci: (bb, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bb, ci: (bb, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B.squeeze(2), C.squeeze(2), s0)
    if return_final_state:
        return y, sf
    return y
