"""Differentiable blocked flash attention with a hand-written VJP.

jax.grad through an online-softmax scan saves every KV-block's probability matrix
for the backward pass — O(T²) residuals (measured: ~17 GB/device on the 4k train
cells). The flash backward identity removes that: save only (q, k, v, out, lse)
and recompute P per block in the backward:

    P_j   = exp(q·k_jᵀ·s − lse)
    dV_j  = P_jᵀ·dO
    dP_j  = dO·v_jᵀ
    Δ     = rowsum(dO ∘ O)
    dS_j  = P_j ∘ (dP_j − Δ)
    dQ   += dS_j·k_j·s ;  dK_j = dS_jᵀ·q·s

Residuals are O(T·D); backward flops ≈ 2.5× forward (the standard flash trade).
Semantics identical to ref.attention (GQA, causal, local window, q_offset).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _mask_block(tq, bk, ki, block_k, tk, q_offset, causal, window):
    q_pos = jnp.arange(tq)[:, None] + q_offset
    k_pos = ki * block_k + jnp.arange(bk)[None, :]
    live = k_pos < tk
    if causal:
        live = live & (k_pos <= q_pos)
    if window is not None:
        live = live & (k_pos > q_pos - window)
    return live


def _fwd_impl(q, k, v, q_offset, *, causal, window, scale, block_k):
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    qf = q.astype(jnp.float32) * scale
    nblk = -(-tk // block_k)
    pad = nblk * block_k - tk
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kf.reshape(b, hkv, nblk, block_k, d)
    vb = vf.reshape(b, hkv, nblk, block_k, d)

    def body(carry, blk):
        m, l, acc = carry
        kj, vj, ki = blk
        kj = jnp.repeat(kj, group, axis=1)
        vj = jnp.repeat(vj, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj)
        live = _mask_block(tq, block_k, ki, block_k, tk, q_offset, causal, window)
        s = jnp.where(live[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, tq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, tq, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, tq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nblk))
    )
    l_safe = jnp.where(l == 0, 1.0, l)
    out = acc / l_safe
    lse = m[..., 0] + jnp.log(l_safe[..., 0])  # (b, hq, tq)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention_jnp(q, k, v, q_offset, causal=True, window=None, scale=None,
                        block_k=512):
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    out, _ = _fwd_impl(q, k, v, q_offset, causal=causal, window=window, scale=scale,
                       block_k=block_k)
    return out


def _fwd(q, k, v, q_offset, causal, window, scale, block_k):
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    out, lse = _fwd_impl(q, k, v, q_offset, causal=causal, window=window, scale=scale,
                         block_k=block_k)
    return out, (q, k, v, q_offset, out, lse)


def _bwd(causal, window, scale, block_k, res, dout):
    q, k, v, q_offset, out, lse = res
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale_v = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    of = out.astype(jnp.float32)
    delta = jnp.sum(do * of, axis=-1, keepdims=True)  # (b,hq,tq,1)

    nblk = -(-tk // block_k)
    pad = nblk * block_k - tk
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = jnp.moveaxis(kf.reshape(b, hkv, nblk, block_k, d), 2, 0)
    vb = jnp.moveaxis(vf.reshape(b, hkv, nblk, block_k, d), 2, 0)

    def body(dq, blk):
        kj, vj, ki = blk  # (b, hkv, bk, d)
        kjr = jnp.repeat(kj, group, axis=1)
        vjr = jnp.repeat(vj, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kjr) * scale_v
        live = _mask_block(tq, block_k, ki, block_k, tk, q_offset, causal, window)
        # recomputed, not stored; explicit zero where masked (s and lse are both
        # -1e30 on fully-masked rows, which would otherwise give exp(0) = 1)
        p = jnp.where(live[None, None], jnp.exp(s - lse[..., None]), 0.0)
        dv_r = jnp.einsum("bhqk,bhqd->bhkd", p, do)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, vjr)
        ds = p * (dp - delta) * scale_v
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kjr)
        dk_r = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        # GQA: fold the group axis back onto kv heads
        dk_j = dk_r.reshape(b, hkv, group, block_k, d).sum(axis=2)
        dv_j = dv_r.reshape(b, hkv, group, block_k, d).sum(axis=2)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, hq, tq, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nblk)))
    dk = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, nblk * block_k, d)[:, :, :tk]
    dv = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, nblk * block_k, d)[:, :, :tk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None


flash_attention_jnp.defvjp(_fwd, _bwd)
