"""Kernel autotuner — sweep-once block-shape selection for the paged decode path.

The paged kernels expose two block-shape knobs (the schedule half of the
paper's customization points — the layout fixes WHERE bytes live, the schedule
fixes the order the kernel walks them):

  * ``page_size``    — the LayoutPaged page extent, which is also the decode
                       kernel's K/V tile height;
  * ``block_pages``  — pages per compute block of the decode grid
                       (paged_attention.paged_flash_decode / the blocked jnp
                       twin's gather granularity);
  * ``chunk_tokens`` — the prefill block shape (a chunk IS the prefill
                       kernel's Q tile; the engine buckets widths itself).

Which values win depends on (model geometry, kv dtype, batch) and on the
machine — exactly the kind of fact that should be measured once and cached,
not hard-coded. ``resolve()`` consults a JSON tuning table on disk
(``artifacts/autotune_cache.json`` by default), keyed by

    {model_tag}/{kv_dtype}/b{batch_bucket}[/s{seq_bucket}]

(batch and sequence length bucketed to the next power of two so nearby sizes
share an entry; the seq component appears when the caller supplies its sized
max length — block shapes tuned at 16-page contexts are the wrong answer for
a 3-page engine, so the sweep shapes its pools to the regime the engine will
actually run). On a miss it runs a short microbenchmark sweep over candidate
(page_size, block_pages) points — timing the SAME ``ops.paged_decode_attention``
entry point the serving step traces — picks the fastest, then sweeps
``chunk_tokens`` INDEPENDENTLY at the winning page size against real
``ops.paged_prefill_chunk_attention`` timings (schema 2; pre-schema-2 it was
derived as 2*page_size), writes the table back, and returns. Every later
engine init with the same key is a pure table lookup (the warm path: no
sweep, no device work).

``EngineConfig(autotune=True)`` is the consumer: ServeEngine.__init__ calls
``resolve()`` before sizing the page pool, applies the tuned values to any
field the user left at its auto sentinel (page_size=0 via
``EngineConfig.sized_for``, decode_block_pages=0, chunk_tokens=0), surfaces
the decision in ``engine.metrics()`` and as a ``tuning_selected`` trace
instant, and never overrides a value the user pinned explicitly.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_CACHE_PATH = Path("artifacts/autotune_cache.json")
# schema 2: chunk_tokens is SWEPT from real prefill-chunk timings instead of
# derived as 2*page_size — v1 entries carry the derived value and reload as
# misses so every key re-tunes once under the new law
CACHE_SCHEMA = 2

# candidate grids — small on purpose: the sweep runs at engine init on a
# cache miss, so it must stay a sub-second affair on the smoke models
PAGE_SIZE_CANDIDATES = (8, 16, 32)
BLOCK_PAGES_CANDIDATES = (1, 2, 4, 8)
# chunk widths tried at the WINNING page size, as page multiples (chunk
# boundaries must stay page-aligned — the engine validates it)
CHUNK_PAGE_MULTIPLIERS = (1, 2, 4)

# sweep workload shape (per candidate): enough pages that blocking matters,
# small enough that jit + a few reps stays cheap
_SWEEP_SEQ_PAGES = 16   # logical pages per sequence in the microbench
_SWEEP_REPS = 15
_SWEEP_WARMUP = 2

# candidates within this factor of the fastest measurement count as TIES, and
# ties break toward the simplest schedule (largest page_size, then smallest
# block_pages — fewer grid steps, no blocking machinery). On dispatch-bound
# hosts every candidate lands inside the noise band and the raw argmin is a
# coin flip; without the band the "winner" flips run to run and can land on a
# schedule that is measurably worse at the engine level.
_SWEEP_TIE_X = 1.10

# ...and even the tie-broken winner only DISPLACES the default schedule
# (page_size 16, unblocked) when it measures at least this much faster than
# it. Kernel microbenches are the noisiest timing in the repo; a tuner that
# moves on a small margin regresses real engines on quiet wins and noisy
# losses alike, so the bar for leaving the default is a decisive one.
_SWEEP_DISPLACE_X = 0.7


@dataclasses.dataclass(frozen=True)
class TunedPoint:
    """One tuning-table entry: the chosen block shapes plus provenance."""

    page_size: int
    block_pages: int
    chunk_tokens: int
    source: str          # "swept" | "default" | "cached"
    us_per_step: float   # winner's median microbench step time (0 if default)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def batch_bucket(batch: int) -> int:
    """Next power of two >= batch (min 1): nearby batch sizes share a key."""
    b = max(1, int(batch))
    return 1 << (b - 1).bit_length()


def seq_bucket(seq_len: int) -> int:
    """Next power of two >= seq_len (min 1) — same sharing law as batches."""
    s = max(1, int(seq_len))
    return 1 << (s - 1).bit_length()


def tuning_key(model_tag: str, kv_dtype: str, batch: int,
               seq_len: int = 0) -> str:
    key = f"{model_tag}/{kv_dtype}/b{batch_bucket(batch)}"
    if seq_len:
        key += f"/s{seq_bucket(seq_len)}"
    return key


def load_cache(path: Path) -> dict:
    try:
        raw = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}
    if raw.get("schema") != CACHE_SCHEMA:
        return {}
    return raw.get("entries", {})


def save_cache(path: Path, entries: dict) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(
        json.dumps({"schema": CACHE_SCHEMA, "entries": entries}, indent=2,
                   sort_keys=True)
        + "\n"
    )


def default_point(page_size: int = 16) -> TunedPoint:
    """The untuned engine's implicit choices (pre-autotune behavior)."""
    return TunedPoint(
        page_size=page_size, block_pages=1, chunk_tokens=2 * page_size,
        source="default", us_per_step=0.0,
    )


def _time_decode(fn, args, reps: int = _SWEEP_REPS) -> float:
    """Min wall time (seconds) of a jitted call, post-warmup. Min, not median:
    host-timing noise only ever ADDS time, so the minimum estimates the
    schedule's capability — the quantity candidates are compared on."""
    for _ in range(_SWEEP_WARMUP):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def sweep_chunk_tokens(
    model_cfg,
    *,
    kv_dtype: str = "f32",
    batch: int = 8,
    seq_len: int = 0,
    page_size: int = 16,
    multipliers: Sequence[int] = CHUNK_PAGE_MULTIPLIERS,
) -> int:
    """Pick ``chunk_tokens`` from REAL prefill-chunk timings at a fixed page
    size, instead of deriving it from the decode winner (pre-schema-2: always
    2*page_size — but the chunk width is the prefill kernel's Q-tile height,
    a different schedule axis with its own optimum: wider chunks amortize
    dispatch, narrower ones bound the mixed step's decode-latency tax).

    Times ``ops.paged_prefill_chunk_attention`` — the exact entry the chunked
    prefill step traces — at candidate widths C = m * page_size against a
    half-resident past, and compares on time PER TOKEN (each dispatch covers C
    positions). The same tie band as the decode sweep applies, breaking toward
    the pre-schema-2 default 2*page_size so dispatch-bound hosts keep the
    engine's historical shape rather than flipping on noise."""
    from repro.kernels import ops
    from repro.serving.engine.kvquant import KV_DTYPES

    hq = max(1, int(model_cfg.n_heads))
    hkv = max(1, int(model_cfg.n_kv_heads or model_cfg.n_heads))
    d = int(model_cfg.head_dim)
    b = batch_bucket(batch)
    ps = int(page_size)
    spec = KV_DTYPES[kv_dtype]

    max_pages = -(-seq_len // ps) if seq_len else _SWEEP_SEQ_PAGES
    max_pages = max(max_pages, max(multipliers))  # a chunk must fit the table
    num_pages = b * max_pages + 1
    rng = np.random.default_rng(0)
    tables = jnp.asarray(
        1 + np.arange(b * max_pages, dtype=np.int32).reshape(b, max_pages)
    )
    # mid-prefill regime: half the context resident, the chunk is the present
    cursors = jnp.full((b,), (max_pages // 2) * ps, jnp.int32)
    pool_f32 = jnp.asarray(
        rng.standard_normal((num_pages, hkv, ps, d)), jnp.float32
    )
    timed: list = []
    for m in multipliers:
        c = m * ps
        q = jnp.asarray(rng.standard_normal((b, hq, c, d)), jnp.float32)
        pres = jnp.asarray(rng.standard_normal((b, hkv, c, d)), jnp.float32)
        if spec is None:
            fn = jax.jit(lambda q, kc, vc, kp, vp, t, cu:
                         ops.paged_prefill_chunk_attention(
                             q, kc, vc, kp, vp, t, cu))
            args = (q, pres, pres, pool_f32, pool_f32, tables, cursors)
        else:
            enc = spec.encode_pages(pool_f32)
            fn = jax.jit(lambda q, kc, vc, kq, ks, vq, vs, t, cu:
                         ops.paged_prefill_chunk_attention_quant(
                             q, kc, vc, kq, ks, vq, vs, t, cu,
                             bits=spec.bits))
            args = (q, pres, pres, enc["q"], enc["scale"], enc["q"],
                    enc["scale"], tables, cursors)
        timed.append((c, _time_decode(fn, args) / c))  # seconds per token
    t_min = min(t for _, t in timed)
    ties = [c for c, t in timed if t <= _SWEEP_TIE_X * t_min]
    return 2 * ps if 2 * ps in ties else ties[0]


def sweep(
    model_cfg,
    *,
    kv_dtype: str = "f32",
    batch: int = 8,
    seq_len: int = 0,
    page_sizes: Sequence[int] = PAGE_SIZE_CANDIDATES,
    block_pages: Sequence[int] = BLOCK_PAGES_CANDIDATES,
) -> TunedPoint:
    """Microbenchmark the decode kernel over the candidate grid; return the
    fastest (page_size, block_pages) as a TunedPoint.

    Times ``ops.paged_decode_attention`` (the exact entry the serving step
    traces) on synthetic pools shaped from the model's real attention geometry
    (Hq/Hkv/head_dim), one token per sequence, every sequence at full length —
    the steady-state decode regime the knob exists for. ``seq_len`` shapes the
    pools to the caller's sized context (pages = ceil(seq_len / page_size));
    without it the sweep uses a generic 16-page context. Quantized dtypes time
    the dequantizing path through ``paged_decode_attention_quant``.
    """
    from repro.kernels import ops
    from repro.serving.engine.kvquant import KV_DTYPES

    hq = max(1, int(model_cfg.n_heads))
    hkv = max(1, int(model_cfg.n_kv_heads or model_cfg.n_heads))
    d = int(model_cfg.head_dim)
    b = batch_bucket(batch)
    spec = KV_DTYPES[kv_dtype]

    points: list[TunedPoint] = []
    rng = np.random.default_rng(0)
    for ps in page_sizes:
        max_pages = -(-seq_len // ps) if seq_len else _SWEEP_SEQ_PAGES
        num_pages = b * max_pages + 1
        q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
        tables = jnp.asarray(
            1 + np.arange(b * max_pages, dtype=np.int32).reshape(b, max_pages)
        )
        lens = jnp.full((b,), max_pages * ps, jnp.int32)
        if spec is None:
            pool = jnp.asarray(
                rng.standard_normal((num_pages, hkv, ps, d)), jnp.float32
            )
            args = (q, pool, pool, tables, lens)

            def make(bp):
                return jax.jit(
                    lambda q, k, v, t, ln, _bp=bp: ops.paged_decode_attention(
                        q, k, v, t, ln, block_pages=_bp
                    )
                )
        else:
            enc = spec.encode_pages(
                jnp.asarray(
                    rng.standard_normal((num_pages, hkv, ps, d)), jnp.float32
                )
            )
            args = (q, enc["q"], enc["scale"], enc["q"], enc["scale"],
                    tables, lens)

            def make(bp):
                return jax.jit(
                    lambda q, kq, ks, vq, vs, t, ln, _bp=bp:
                        ops.paged_decode_attention_quant(
                            q, kq, ks, vq, vs, t, ln, bits=spec.bits,
                            block_pages=_bp,
                        )
                )

        for bp in block_pages:
            if bp > max_pages:
                continue
            t = _time_decode(make(bp), args)
            points.append(TunedPoint(
                page_size=ps, block_pages=bp, chunk_tokens=2 * ps,
                source="swept", us_per_step=t * 1e6,
            ))
    if not points:
        return default_point()
    t_min = min(p.us_per_step for p in points)
    ties = [p for p in points if p.us_per_step <= _SWEEP_TIE_X * t_min]
    best = max(ties, key=lambda p: (p.page_size, -p.block_pages))
    anchor_ps = 16 if 16 in page_sizes else page_sizes[0]
    anchor = next(
        (p for p in points
         if p.page_size == anchor_ps and p.block_pages == 1),
        None,
    )
    if anchor is not None and best.us_per_step > _SWEEP_DISPLACE_X * anchor.us_per_step:
        best = anchor
    # chunk_tokens is its own schedule axis: sweep it from real prefill-chunk
    # timings AT the winning page size (schema 2), never derived from it
    return dataclasses.replace(best, chunk_tokens=sweep_chunk_tokens(
        model_cfg, kv_dtype=kv_dtype, batch=batch, seq_len=seq_len,
        page_size=best.page_size,
    ))


def resolve(
    model_cfg,
    *,
    kv_dtype: str = "f32",
    batch: int = 8,
    seq_len: int = 0,
    page_size: Optional[int] = None,
    cache_path: Path | str | None = None,
    allow_sweep: bool = True,
) -> TunedPoint:
    """The engine-init entry point: cached lookup, sweep-once on miss.

    ``page_size`` pins the layout extent (an engine whose pool is already
    sized cannot change it): the sweep then only searches block_pages at that
    page size, and a cached entry tuned at a different page size is projected
    onto the pinned one. ``allow_sweep=False`` degrades a miss to the default
    point (no device work) — CI smoke uses it to test the cold/warm split.
    """
    path = Path(cache_path) if cache_path is not None else DEFAULT_CACHE_PATH
    tag = getattr(model_cfg, "name", "model")
    key = tuning_key(tag, kv_dtype, batch, seq_len)
    entries = load_cache(path)
    hit = entries.get(key)
    if hit is not None:
        point = TunedPoint(**{**hit, "source": "cached"})
        if page_size and point.page_size != page_size:
            # projection onto a pinned page size keeps the warm path a pure
            # file read: the cached chunk width was swept at a DIFFERENT page
            # size, so fall back to the page-aligned default rather than
            # re-timing (a fresh key sweeps chunk_tokens for real)
            point = dataclasses.replace(
                point, page_size=page_size, chunk_tokens=2 * page_size
            )
        return point
    if not allow_sweep:
        return default_point(page_size or 16)
    point = sweep(
        model_cfg, kv_dtype=kv_dtype, batch=batch, seq_len=seq_len,
        page_sizes=(page_size,) if page_size else PAGE_SIZE_CANDIDATES,
    )
    entries[key] = dataclasses.replace(point, source="swept").as_dict()
    save_cache(path, entries)
    return point
