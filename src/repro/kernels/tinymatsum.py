"""TinyMatrixSum — batched accumulate over (N, J, K) tiny matrices (paper Fig. 5).

The paper's experiment: expressing the inner extents (3, 3) statically lets the
compiler fully unroll and vectorize, ~2x on CPU. The TPU restatement:

  * STATIC inner extents (Extents.is_static → True): the kernel bakes (J, K) into
    the BlockSpec; the body is a single dense vector add over a (bn, J, K) brick —
    no loops, no masks. When J*K is lane-aligned we fold (J, K) into one lane dim.
  * DYNAMIC inner extents: the kernel is compiled for a PADDED envelope
    (Jmax, Kmax) and receives the true runtime extents as scalar-prefetch operands;
    the body masks the pad lanes on every accumulate. Same algorithm, but the
    generated code carries masks and a dynamic bound — the precise TPU analogue of
    the un-unrollable runtime-extent loop the paper measures.

The measured gap between these two compilations is our reproduction of Fig. 5.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import cdiv, pick_block, use_interpret


def _static_kernel(o_ref, s_ref, out_ref):
    out_ref[...] = (
        o_ref[...].astype(jnp.float32) + s_ref[...].astype(jnp.float32)
    ).astype(out_ref.dtype)


def tinymatsum_static(
    o: jax.Array, s: jax.Array, *, block_n: int = 512, interpret: bool | None = None
) -> jax.Array:
    """Accumulate with J, K specialized at trace time (static extents)."""
    interpret = use_interpret() if interpret is None else interpret
    n, j, k = o.shape
    bn = pick_block(n, block_n)
    grid = (cdiv(n, bn),)
    spec = pl.BlockSpec((bn, j, k), lambda g: (g, 0, 0))
    return pl.pallas_call(
        _static_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(o.shape, o.dtype),
        interpret=interpret,
    )(o, s)


def _dynamic_kernel(jk_ref, o_ref, s_ref, out_ref):
    # jk_ref: SMEM scalars (true J, true K); blocks are padded to (Jmax, Kmax).
    jtrue = jk_ref[0]
    ktrue = jk_ref[1]
    bn, jmax, kmax = o_ref.shape
    jj = jax.lax.broadcasted_iota(jnp.int32, (bn, jmax, kmax), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (bn, jmax, kmax), 2)
    live = (jj < jtrue) & (kk < ktrue)
    acc = o_ref[...].astype(jnp.float32) + s_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(live, acc, o_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def tinymatsum_dynamic(
    o: jax.Array,
    s: jax.Array,
    *,
    jmax: int = 8,
    kmax: int = 8,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Accumulate compiled for a (jmax, kmax) envelope with runtime true extents.

    o/s arrive PADDED to (N, jmax, kmax); the true (J, K) travel as scalar operands
    — the kernel cannot specialize on them (the paper's dynamic-extent case).
    """
    interpret = use_interpret() if interpret is None else interpret
    n, j, k = o.shape
    assert j <= jmax and k <= kmax
    from .common import pad_to

    op = pad_to(o, (n, jmax, kmax))
    sp = pad_to(s, (n, jmax, kmax))
    bn = pick_block(n, block_n)
    grid = (cdiv(n, bn),)
    spec = pl.BlockSpec((bn, jmax, kmax), lambda g: (g, 0, 0))
    jk = jnp.array([j, k], jnp.int32)
    out = pl.pallas_call(
        _dynamic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda g: (0,)),  # true (J, K): scalar operand, SMEM on TPU
            spec,
            spec,
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, jmax, kmax), o.dtype),
        interpret=interpret,
    )(jk, op, sp)
    return out[:, :j, :k]
