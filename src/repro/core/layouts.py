"""LayoutMapping: the paper's Table I concept, traceable in JAX.

A LayoutMapping is a pure function from a multi-index in the extents' domain to a
scalar offset in the codomain (a flat buffer), carrying queryable algebraic
properties. Algorithms (core/algorithms.py, kernels/ops.py) interrogate these
properties **at trace time** and specialize or reject — the JAX analogue of the
paper's "fail at compile time rather than run time".

Implemented mappings:
  LayoutRight           row-major (fast-running index right-most)        [paper]
  LayoutLeft            column-major (fast-running index left-most)      [paper]
  LayoutStride          arbitrary per-rank strides + base offset (BLAS LD) [paper]
  LayoutSymmetricPacked upper-triangle packed storage — NON-unique        [paper]
  LayoutTiledTPU        (8,128)-style hardware tiling with padding — the TPU-native
                        layout (VREG/MXU aligned); unique, strided per-tile but not
                        globally strided, non-contiguous when padded     [TPU adaptation]
  LayoutPaged           block-table indirection for paged KV caches: logical
                        (seq, head, pos, d) → physical (page, slot) through a
                        per-sequence page table; unique (when the table doesn't
                        alias), non-contiguous, non-strided               [extension]

All ``__call__`` implementations accept Python ints or traced jnp index arrays, so a
mapping can be used inside jit/pallas kernels and in gather-based generic fallbacks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import jax.numpy as jnp

from .extents import Extents


class LayoutError(TypeError):
    """Raised at trace time when an algorithm cannot support a layout (paper: a
    failed compile-time constraint)."""


class LayoutMapping:
    """Base class documenting the concept (paper Table I)."""

    extents: Extents

    # -- required observers ---------------------------------------------------
    def __call__(self, *idx):  # -> offset (int or traced scalar)
        raise NotImplementedError

    def required_span_size(self) -> int:
        raise NotImplementedError

    def is_unique(self) -> bool:
        raise NotImplementedError

    def is_contiguous(self) -> bool:
        raise NotImplementedError

    def is_strided(self) -> bool:
        raise NotImplementedError

    def stride(self, r: int) -> int:
        raise LayoutError(f"{type(self).__name__} is not strided")

    # -- static forms -----------------------------------------------------------
    @classmethod
    def is_always_unique(cls) -> bool:
        return False

    @classmethod
    def is_always_contiguous(cls) -> bool:
        return False

    @classmethod
    def is_always_strided(cls) -> bool:
        return False

    # -- slicing support (submdspan) ----------------------------------------------
    def slice_layout(self, starts: Sequence[int], shape_spec) -> "LayoutMapping":
        """Return the layout of a rectangular sub-view. Default: only defined for
        strided layouts (LayoutStride result); others must override or reject."""
        raise LayoutError(
            f"submdspan of {type(self).__name__} is not defined (not strided)"
        )

    # -- misc -------------------------------------------------------------------
    @property
    def rank(self) -> int:
        return self.extents.rank

    def offsets_dense(self):
        """Offsets for the whole domain as an ndarray shaped like the extents.

        Used by generic gather/scatter fallbacks and oracles. O(domain size) —
        trace-time cheap, runtime is a single gather.
        """
        idx = jnp.indices(self.extents.as_shape())
        if idx.shape[0] == 0:  # rank-0
            return jnp.zeros((), dtype=jnp.int32)
        return self(*(idx[r] for r in range(self.extents.rank)))


def _row_major_strides(sizes: Tuple[int, ...]) -> Tuple[int, ...]:
    strides = [1] * len(sizes)
    for r in range(len(sizes) - 2, -1, -1):
        strides[r] = strides[r + 1] * sizes[r + 1]
    return tuple(strides)


def _col_major_strides(sizes: Tuple[int, ...]) -> Tuple[int, ...]:
    strides = [1] * len(sizes)
    for r in range(1, len(sizes)):
        strides[r] = strides[r - 1] * sizes[r - 1]
    return tuple(strides)


@dataclasses.dataclass(frozen=True)
class _StridedBase(LayoutMapping):
    extents: Extents

    def _strides(self) -> Tuple[int, ...]:
        raise NotImplementedError

    def __call__(self, *idx):
        strides = self._strides()
        if len(idx) != len(strides):
            raise TypeError(f"rank mismatch: {len(idx)} indices for rank {len(strides)}")
        off = self._base_offset()
        for i, s in zip(idx, strides):
            off = off + i * s
        return off

    def _base_offset(self) -> int:
        return 0

    def is_unique(self) -> bool:
        return True

    def is_strided(self) -> bool:
        return True

    def stride(self, r: int) -> int:
        return self._strides()[r]

    @classmethod
    def is_always_unique(cls) -> bool:
        return True

    @classmethod
    def is_always_strided(cls) -> bool:
        return True

    def slice_layout(self, starts, shape_spec):
        strides = self._strides()
        base = self._base_offset() + sum(int(s) * int(st) for s, st in zip(starts, strides))
        kept_strides = tuple(
            strides[r] for r, keep in enumerate(shape_spec.keep) if keep
        )
        return LayoutStride(shape_spec.extents, kept_strides, base)


@dataclasses.dataclass(frozen=True)
class LayoutRight(_StridedBase):
    """Row-major; the C++ default and the paper's ``layout_right``."""

    def _strides(self):
        return _row_major_strides(self.extents.sizes)

    def required_span_size(self) -> int:
        return self.extents.size()

    def is_contiguous(self) -> bool:
        return True

    @classmethod
    def is_always_contiguous(cls) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class LayoutLeft(_StridedBase):
    """Column-major; the paper's ``layout_left`` (Fortran/BLAS default)."""

    def _strides(self):
        return _col_major_strides(self.extents.sizes)

    def required_span_size(self) -> int:
        return self.extents.size()

    def is_contiguous(self) -> bool:
        return True

    @classmethod
    def is_always_contiguous(cls) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class LayoutStride(_StridedBase):
    """Arbitrary strides + base offset (generalizes BLAS leading-dimension and every
    rectangular submdspan of a strided layout)."""

    strides: Tuple[int, ...] = ()
    offset: int = 0

    def __post_init__(self):
        if len(self.strides) != self.extents.rank:
            raise TypeError(
                f"{len(self.strides)} strides for rank-{self.extents.rank} extents"
            )

    def _strides(self):
        return self.strides

    def _base_offset(self) -> int:
        return self.offset

    def required_span_size(self) -> int:
        if self.extents.size() == 0:
            return 0
        last = self.offset
        for sz, st in zip(self.extents.sizes, self.strides):
            last += (sz - 1) * st
        return last + 1

    def is_unique(self) -> bool:
        # Sufficient check: sorted (|stride|, size) nest like a mixed-radix system.
        dims = sorted(
            (abs(st), sz) for st, sz in zip(self.strides, self.extents.sizes) if sz > 1
        )
        span = 1
        for st, sz in dims:
            if st < span:
                return False
            span = st * sz
        return True

    def is_contiguous(self) -> bool:
        return self.is_unique() and self.required_span_size() - self.offset == self.extents.size() and self.offset == 0

    @classmethod
    def is_always_unique(cls) -> bool:
        return False  # depends on instance strides

    @classmethod
    def is_always_contiguous(cls) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class LayoutSymmetricPacked(LayoutMapping):
    """Upper-triangle packed symmetric layout (paper: xSYMM / UPLO).

    Rank-2 n×n domain stored in n(n+1)/2 slots; (i,j) and (j,i) map to the SAME
    offset → **is_unique() == False**. Algorithms requiring uniqueness (e.g. `scale`
    iterating the full domain) must reject this layout at trace time; algorithms
    generic over contiguous codomains may operate on the packed buffer directly.
    """

    extents: Extents

    def __post_init__(self):
        if self.extents.rank != 2 or self.extents.extent(0) != self.extents.extent(1):
            raise TypeError("LayoutSymmetricPacked requires square rank-2 extents")

    def __call__(self, i, j):
        lo = jnp.minimum(i, j) if not (isinstance(i, int) and isinstance(j, int)) else min(i, j)
        hi = jnp.maximum(i, j) if not (isinstance(i, int) and isinstance(j, int)) else max(i, j)
        # packed upper triangle, row-major over (lo, hi): offset = lo*n - lo(lo-1)/2 + (hi-lo)
        n = self.extents.extent(0)
        return lo * n - (lo * (lo - 1)) // 2 + (hi - lo)

    def required_span_size(self) -> int:
        n = self.extents.extent(0)
        return n * (n + 1) // 2

    def is_unique(self) -> bool:
        return self.extents.extent(0) <= 1

    def is_contiguous(self) -> bool:
        return True  # codomain is exactly [0, n(n+1)/2)

    def is_strided(self) -> bool:
        return False

    @classmethod
    def is_always_contiguous(cls) -> bool:
        return True


# Hardware tile shapes per element byte-width (sublane × lane), TPU VREG geometry.
_TPU_TILE_BY_ITEMSIZE = {4: (8, 128), 2: (16, 128), 1: (32, 128)}


@dataclasses.dataclass(frozen=True)
class LayoutTiledTPU(LayoutMapping):
    """TPU-native tiled layout: last two dims blocked into (sublane, lane) tiles.

    This is the adaptation target of the paper's layout abstraction: on TPU the
    "good" layout is not merely row- vs column-major but *(8,128)-tiled* so that VMEM
    loads fill vector registers and MXU operands are aligned. Logical (i, j) maps to

        tile = (i // ts) * ceil(J/tl) + (j // tl)
        offset = tile * ts * tl + (i % ts) * tl + (j % tl)

    Padding tiles at the edges makes the codomain larger than the domain →
    ``is_contiguous() == False`` unless the extents divide the tile exactly; the map
    stays unique. It is NOT globally strided (stride between (i,j)->(i,j+1) changes
    at tile boundaries) → kernels requiring `is_strided` reject it; tile-aware Pallas
    kernels consume it natively via BlockSpecs.

    Leading dims (rank > 2) are row-major over whole tiled planes.
    """

    extents: Extents
    tile: Tuple[int, int] = (8, 128)

    def __post_init__(self):
        if self.extents.rank < 2:
            raise TypeError("LayoutTiledTPU requires rank >= 2")

    @staticmethod
    def for_dtype(extents: Extents, dtype) -> "LayoutTiledTPU":
        itemsize = jnp.dtype(dtype).itemsize
        return LayoutTiledTPU(extents, _TPU_TILE_BY_ITEMSIZE.get(itemsize, (8, 128)))

    def _tiles(self):
        I, J = self.extents.sizes[-2:]
        ts, tl = self.tile
        return -(-I // ts), -(-J // tl)  # ceil-div

    def plane_span(self) -> int:
        ti, tj = self._tiles()
        return ti * tj * self.tile[0] * self.tile[1]

    def __call__(self, *idx):
        *lead, i, j = idx
        ts, tl = self.tile
        ti, tj = self._tiles()
        off = (i // ts) * (tj * ts * tl) + (j // tl) * (ts * tl) + (i % ts) * tl + (j % tl)
        plane = self.plane_span()
        lead_sizes = self.extents.sizes[:-2]
        lead_strides = _row_major_strides(lead_sizes) if lead_sizes else ()
        for l, s in zip(lead, lead_strides):
            off = off + l * s * plane
        return off

    def required_span_size(self) -> int:
        n_planes = 1
        for s in self.extents.sizes[:-2]:
            n_planes *= s
        return n_planes * self.plane_span()

    def is_unique(self) -> bool:
        return True

    def is_contiguous(self) -> bool:
        I, J = self.extents.sizes[-2:]
        return I % self.tile[0] == 0 and J % self.tile[1] == 0

    def is_strided(self) -> bool:
        # Conservative type-level answer: tile-boundary jumps break global strides
        # (degenerate single-tile instances are not special-cased).
        return False

    @classmethod
    def is_always_unique(cls) -> bool:
        return True

    def padded_shape(self) -> Tuple[int, ...]:
        ti, tj = self._tiles()
        return self.extents.sizes[:-2] + (ti * self.tile[0], tj * self.tile[1])


@dataclasses.dataclass(frozen=True)
class LayoutPaged(LayoutMapping):
    """Paged KV-cache layout: logical positions reach physical storage through a
    block table (vLLM-style PagedAttention, restated as a layout mapping).

    The domain is rank-4 ``(seq, head, pos, d)``. Physical storage is a pool of
    ``num_pages`` fixed-size pages, each holding ``page_size`` positions for all
    heads — pool shape ``(num_pages, n_heads, page_size, d)`` flattened row-major
    (page_size on sublanes, d on lanes: the LayoutTiledTPU-friendly orientation).

        page   = block_table[seq][pos // page_size]
        slot   = pos %  page_size
        offset = ((page * n_heads + head) * page_size + slot) * d + d_idx

    This is the layout the C++ committee never shipped: the indirection through
    ``block_table`` makes the map non-affine, so it is NOT strided and (because
    the pool is over-provisioned) NOT contiguous, yet it IS unique whenever the
    table doesn't alias pages — exactly the Table I observer combination that
    distinguishes it from every standard layout. Consumers that interrogate
    ``is_strided()`` (BLAS-style kernels) reject it at trace time; the paged
    flash-decode kernel (kernels/paged_attention.py) consumes the block table
    natively via scalar-prefetch BlockSpecs.

    ``block_table`` is a tuple-of-tuples (hashable, trace-time constant); rows are
    logical pages in order. Entries must be in ``[0, num_pages)`` — use a reserved
    null page for unallocated tail entries and keep those positions masked.

    Composing with accessors (paper §customization points): this mapping never
    inspects element bytes, so the pool behind it can change representation
    freely — serving/engine/kvquant.PagedQuantSpec stores the SAME codomain as
    block-scaled int8/int4 (one scale per (page, head), i.e. per contiguous
    ``page_size * d`` offset range), and every law below — uniqueness, fork,
    cow_slice, the shared_pages bookkeeping — holds identically over the
    quantized pool because all of them quantify over offsets, not values.

    ``shared_pages`` names physical pages referenced by block tables OUTSIDE this
    instance (prefix sharing: the allocator's refcount for them exceeds this
    layout's own references). The map stays injective on its domain, but the
    one-writer-per-offset property mdspan uniqueness promises is gone — so
    ``is_unique()`` reports False exactly when the table references a shared page
    (or aliases a page internally). ``fork()`` builds the aliased regime
    explicitly; ``cow_slice()`` is the copy-on-write swap that re-privatizes one
    logical page.

    Slicing (submdspan — the chunked-prefill view): a ``(a, b)`` slice of the
    pos rank yields another LayoutPaged whose block-table rows are trimmed to
    exactly the pages covering ``[a, b)`` and whose ``pos_offset`` records where
    inside the first page the chunk begins — so a prefill chunk's unit of work
    is LITERALLY a submdspan of the pool, sharing storage with the parent and
    costing only index arithmetic. ``shared_pages`` is filtered to the pages the
    chunk still references: a chunk that starts past a shared prefix is
    ``is_unique()`` even when its parent is not — the formal statement of the
    shared-prefix compute-skip regime (the skipped pages are someone else's to
    read, the chunk's own pages are private to write). See core/submdspan.py
    §"chunk views are submdspans" for the laws.

    Device-resident layout state (the serving hot path): a LayoutPaged mapping
    is DATA — (block_table, lens) — not code, so where that data lives decides
    what the indirection costs. The paged kernels already consume the tables
    on device (scalar-prefetch BlockSpecs); the serving engine extends the
    same discipline to the engine loop AROUND the kernels: PagedKVCache keeps
    persistent device mirrors of every slot's table row and length beside the
    page pool they index, allocator events (allocation, CoW, page append,
    preemption) patch exactly the affected rows via ``dynamic_update_slice``
    deltas, and routine decode appends advance the lengths ON DEVICE inside
    the fused serve step (donated in place, no host round-trip). The mapping
    state therefore lives where its codomain lives, and the host's copy is a
    scheduling-side mirror — the paper's zero-overhead claim applied to the
    layout's runtime representation, not just its index arithmetic
    (serving/engine/cache.py §device-resident layout state).
    """

    extents: Extents
    block_table: Tuple[Tuple[int, ...], ...] = ()
    page_size: int = 16
    num_pages: int = 0
    shared_pages: Tuple[int, ...] = ()
    pos_offset: int = 0  # physical position of logical pos 0 within the first page
    host_pages: Tuple[int, ...] = ()  # physical pages whose storage is currently
    # host-resident (the hierarchical-KV tier): the per-page residency set that
    # makes index -> (space, page, slot) a TOTAL map (space_for /
    # space_for_offset). Orthogonal to the offset algebra — migration moves a
    # page's bytes and flips its membership here, never an offset

    def __post_init__(self):
        if self.extents.rank != 4:
            raise TypeError("LayoutPaged requires rank-4 (seq, head, pos, d) extents")
        n_seq, _, max_pos, _ = self.extents.sizes
        if self.page_size <= 0:
            raise ValueError("page_size must be positive")
        if not (0 <= self.pos_offset < self.page_size):
            raise ValueError(
                f"pos_offset {self.pos_offset} outside [0, page_size {self.page_size})"
            )
        table = tuple(tuple(int(p) for p in row) for row in self.block_table)
        object.__setattr__(self, "block_table", table)
        if len(table) != n_seq:
            raise TypeError(f"{len(table)} block-table rows for {n_seq} sequences")
        # rows must cover the (offset-shifted) pos domain exactly: full coverage
        # of whole pages when pos_offset == 0 and max_pos is a page multiple
        # (the allocator's full-sequence views), a partial first/last page
        # otherwise (chunk submdspans)
        pages_per_seq = -(-(self.pos_offset + max_pos) // self.page_size)
        for row in table:
            if len(row) != pages_per_seq:
                raise TypeError(
                    f"block-table row of {len(row)} entries; need {pages_per_seq}"
                )
            for p in row:
                if not (0 <= p < self.num_pages):
                    raise ValueError(f"page id {p} outside pool [0, {self.num_pages})")
        shared = tuple(sorted({int(p) for p in self.shared_pages}))
        object.__setattr__(self, "shared_pages", shared)
        for p in shared:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"shared page id {p} outside pool [0, {self.num_pages})")
        host = tuple(sorted({int(p) for p in self.host_pages}))
        object.__setattr__(self, "host_pages", host)
        for p in host:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"host page id {p} outside pool [0, {self.num_pages})")

    @staticmethod
    def dense(n_seq: int, n_heads: int, max_pos: int, d: int, page_size: int) -> "LayoutPaged":
        """Identity block table covering the domain exactly (the LayoutRight
        cross-check instance: no over-provisioning, pages in logical order)."""
        pages_per_seq = max_pos // page_size
        table = tuple(
            tuple(s * pages_per_seq + j for j in range(pages_per_seq))
            for s in range(n_seq)
        )
        return LayoutPaged(
            Extents.fully_dynamic(n_seq, n_heads, max_pos, d),
            table, page_size, n_seq * pages_per_seq,
        )

    # -- mapping ------------------------------------------------------------------
    def _table_array(self):
        return jnp.asarray(self.block_table, dtype=jnp.int32)

    def __call__(self, s, h, p, d):
        _, n_heads, _, d_sz = self.extents.sizes
        ps = self.page_size
        phys = p + self.pos_offset
        if all(isinstance(i, int) for i in (s, h, p, d)):
            page = self.block_table[s][phys // ps]
        else:
            page = self._table_array()[s, phys // ps]
        slot = phys % ps
        return ((page * n_heads + h) * ps + slot) * d_sz + d

    def pool_shape(self) -> Tuple[int, int, int, int]:
        """The codomain factored as an ndarray: (num_pages, n_heads, page_size, d)."""
        return (self.num_pages, self.extents.extent(1), self.page_size, self.extents.extent(3))

    # -- memory spaces (hierarchical KV) -------------------------------------------
    def space_for(self, s: int, h: int, p: int, d: int):
        """The memory space holding index (s, h, p, d) — HOST when the page the
        position maps to is in the residency set, HBM otherwise. Together with
        __call__ this makes index -> (space, page, slot) a TOTAL map: every
        domain index answers both WHERE in the flat codomain it lives and WHICH
        tier holds those bytes (accessors.HostTierAccessor answers the same
        question from the accessor axis; the two agree by construction when
        built over the same residency set)."""
        from .accessors import MemorySpace

        page = self.block_table[s][(p + self.pos_offset) // self.page_size]
        return (
            MemorySpace.HOST if page in set(self.host_pages) else MemorySpace.HBM
        )

    def space_for_offset(self, offset: int):
        """The memory space holding flat codomain ``offset`` (total over the
        span: offsets factor through pages, and residency is per page)."""
        from .accessors import MemorySpace

        page_elems = (
            self.extents.extent(1) * self.page_size * self.extents.extent(3)
        )
        page = int(offset) // page_elems
        if not (0 <= page < self.num_pages):
            raise ValueError(
                f"offset {offset} outside the pool span "
                f"[0, {self.required_span_size()})"
            )
        return (
            MemorySpace.HOST if page in set(self.host_pages) else MemorySpace.HBM
        )

    # -- observers ----------------------------------------------------------------
    def required_span_size(self) -> int:
        return self.num_pages * self.extents.extent(1) * self.page_size * self.extents.extent(3)

    def is_unique(self) -> bool:
        entries = [p for row in self.block_table for p in row]
        if len(entries) != len(set(entries)):
            return False  # two logical positions alias one (page, slot) internally
        shared = set(self.shared_pages)
        return not any(p in shared for p in entries)

    def is_contiguous(self) -> bool:
        if self.pos_offset != 0 or (
            self.extents.extent(2) % self.page_size != 0
        ):
            return False  # a chunk view leaves page slack around its boundaries
        entries = sorted(p for row in self.block_table for p in row)
        return entries == list(range(self.num_pages))

    def is_strided(self) -> bool:
        # Type-level answer: the table indirection breaks affine strides
        # (identity-table instances are not special-cased).
        return False

    # -- slicing (submdspan): chunk views -----------------------------------------
    def slice_layout(self, starts: Sequence[int], shape_spec) -> "LayoutPaged":
        """The layout of a rectangular sub-view — the chunked-prefill law.

        Only seq and pos may be restricted (``all_`` or ``(a, b)`` ranges): the
        head and d ranks are interleaved INSIDE each page by the offset formula,
        so restricting them would need a different pool geometry, and integer
        specifiers would drop the rank-4 structure the block table addresses —
        both are rejected at trace time (paper: a failed compile-time
        constraint). A pos slice trims each row to exactly the pages covering
        ``[a, b)`` and records the in-page start as ``pos_offset``; the result
        is again a LayoutPaged over the SAME pool, and composing slices is
        associative (slicing the slice == slicing once with the composed range).
        """
        if len(shape_spec.keep) != 4 or not all(shape_spec.keep):
            raise LayoutError(
                "submdspan of LayoutPaged must keep all four ranks "
                "(integer specifiers would drop the block-table structure)"
            )
        s0, h0, p0, _d0 = (int(s) for s in starts)
        sizes = shape_spec.extents.sizes
        if h0 != 0 or sizes[1] != self.extents.extent(1):
            raise LayoutError(
                "LayoutPaged head rank only slices with all_ (heads interleave "
                "inside each physical page)"
            )
        if sizes[3] != self.extents.extent(3):
            raise LayoutError(
                "LayoutPaged d rank only slices with all_ (d is innermost in "
                "each page)"
            )
        rows = self.block_table[s0 : s0 + sizes[0]]
        phys0 = self.pos_offset + p0
        first_page = phys0 // self.page_size
        last_page = -(-(phys0 + sizes[2]) // self.page_size)  # exclusive
        new_rows = tuple(r[first_page:last_page] for r in rows)
        referenced = {p for r in new_rows for p in r}
        shared = tuple(p for p in self.shared_pages if p in referenced)
        return LayoutPaged(
            shape_spec.extents,
            new_rows,
            self.page_size,
            self.num_pages,
            shared,
            phys0 - first_page * self.page_size,
            host_pages=tuple(p for p in self.host_pages if p in referenced),
        )

    # -- prefix sharing / copy-on-write / parallel generation ----------------------
    #
    # Parallel generation as layout forks (the serving engine's n-best / beam
    # regime, serving/engine/*): the paper's thesis is that a layout is a
    # CUSTOMIZATION POINT — new storage policies are new mappings, not new
    # special cases in every consumer. Parallel decoding is exactly such a
    # policy, and it needs no new kernel:
    #
    #   - best-of-n: ``fork_group(seq, n)`` adds n rows aliasing row ``seq``'s
    #     pages — N decode branches read one prompt's KV at ~1x storage cost.
    #     The aliasing is VISIBLE in the observers: ``is_unique()`` goes False
    #     the moment two rows reference one page, and flips back exactly when
    #     ``cow_slice`` has privatized every doubly-referenced page (the
    #     allocator's copy-on-write discharge of the write obligation).
    #   - beam search: a beam step that keeps every hypothesis alive exactly
    #     once is ``permute_rows`` — a pure relabeling of which sequence index
    #     owns which row. The offset image of the mapping is unchanged (no
    #     page is copied, no entry rewritten), which is why the engine can
    #     realize a beam reorder as row patches of its device-resident table
    #     mirror and nothing else. Only a DIVERGING step (one parent, two
    #     children) re-enters the fork/cow regime above.
    #
    # The laws tests pin down (tests/test_parallel_generation.py): fork_group
    # conserves the set of referenced pages; permute_rows composes like the
    # permutation group and preserves the offset image; is_unique() is False
    # on a forked layout and True again after cow_slice resolves each alias.
    def fork(self, seq: int, fresh_pages: Sequence[int] = ()) -> "LayoutPaged":
        """A new layout with one more sequence row that shares row ``seq``'s
        leading pages (prefix sharing). The forked row reuses row ``seq``'s first
        ``pages_per_seq - len(fresh_pages)`` entries and takes ``fresh_pages``
        (private storage for where the fork diverges) as its tail. The shared
        entries now appear in two rows — aliasing INTERNAL to the table — so
        ``is_unique()`` goes False until copy-on-write (``cow_slice``) resolves
        every doubly-referenced page. ``shared_pages`` (external refcounts) is
        carried through unchanged."""
        rows = list(self.block_table)
        if not (0 <= seq < len(rows)):
            raise ValueError(f"no sequence {seq} to fork (have {len(rows)} rows)")
        row = rows[seq]
        fresh = tuple(int(p) for p in fresh_pages)
        if len(fresh) > len(row):
            raise ValueError(f"{len(fresh)} fresh pages for a {len(row)}-page row")
        upto = len(row) - len(fresh)
        rows.append(row[:upto] + fresh)
        sizes = self.extents.sizes
        return LayoutPaged(
            Extents.fully_dynamic(sizes[0] + 1, *sizes[1:]),
            tuple(rows),
            self.page_size,
            self.num_pages,
            self.shared_pages,
            self.pos_offset,
            host_pages=self.host_pages,
        )

    def fork_group(self, seq: int, n: int,
                   fresh_pages: Sequence[Sequence[int]] = ()) -> "LayoutPaged":
        """``n`` forks of row ``seq`` in one step — the branch-group fork of
        best-of-n / beam-search admission. Each new row shares row ``seq``'s
        leading pages; ``fresh_pages`` (optional, one tuple per branch) gives
        branch ``i`` its private tail where it will diverge. Equivalent to
        ``n`` successive ``fork(seq, ...)`` calls; a single helper because the
        engine admits and preempts a branch group as a UNIT, and the layout
        algebra should state the group operation the allocator performs."""
        if n < 1:
            raise ValueError(f"fork_group needs n >= 1, got {n}")
        fresh = list(fresh_pages) or [()] * n
        if len(fresh) != n:
            raise ValueError(f"{len(fresh)} fresh-page tails for {n} branches")
        out = self
        for tail in fresh:
            out = out.fork(seq, tail)
        return out

    def permute_rows(self, perm: Sequence[int]) -> "LayoutPaged":
        """The layout after a beam-search reorder step: row ``i`` of the result
        is row ``perm[i]`` of this layout. ``perm`` must be a permutation of
        ``range(n_seq)`` — every hypothesis keeps exactly one owner — so the
        mapping's OFFSET IMAGE is unchanged: no page is copied, no entry
        rewritten, uniqueness/contiguity observers are invariant. This is the
        formal statement of the engine's zero-copy beam reorder (a device-
        mirror row patch); a non-permutation beam step (a parent with two
        children) must go through fork + cow_slice instead."""
        rows = self.block_table
        if sorted(int(p) for p in perm) != list(range(len(rows))):
            raise ValueError(
                f"perm {tuple(perm)} is not a permutation of range({len(rows)})"
            )
        return LayoutPaged(
            self.extents,
            tuple(rows[int(p)] for p in perm),
            self.page_size,
            self.num_pages,
            self.shared_pages,
            self.pos_offset,
            host_pages=self.host_pages,
        )

    def cow_slice(self, seq: int, logical_page: int, new_page: int) -> "LayoutPaged":
        """The layout after a copy-on-write: row ``seq``'s ``logical_page`` entry
        is swapped for the freshly copied ``new_page`` (private, so not shared).
        The donor page leaves ``shared_pages`` once no row references it."""
        rows = [list(r) for r in self.block_table]
        if not (0 <= seq < len(rows)):
            raise ValueError(f"no sequence {seq} to cow (have {len(rows)} rows)")
        if not (0 <= logical_page < len(rows[seq])):
            raise ValueError(
                f"no logical page {logical_page} in a {len(rows[seq])}-page row"
            )
        old = rows[seq][logical_page]
        rows[seq][logical_page] = int(new_page)
        table = tuple(tuple(r) for r in rows)
        still_referenced = {p for row in table for p in row}
        shared = tuple(
            p for p in self.shared_pages if p != old or p in still_referenced
        )
        return LayoutPaged(
            self.extents, table, self.page_size, self.num_pages, shared,
            self.pos_offset,
            # the fresh CoW target is HBM by construction (cow copies through
            # the device pool); the donor keeps whatever residency it had
            host_pages=tuple(p for p in self.host_pages if p != new_page),
        )


def layout_of_dense(arr_shape: Sequence[int], order: str = "right") -> LayoutMapping:
    e = Extents.fully_dynamic(*arr_shape)
    return LayoutRight(e) if order == "right" else LayoutLeft(e)
