"""Extents: the multi-index domain of an mdspan, mixing static and dynamic sizes.

Paper mapping (mdspan §Extents Class Template):
  C++ ``extents<20, dynamic_extent>`` binds one extent into the *type* and defers the
  other to the constructor. In JAX the analogue of "in the type" is "a Python int the
  tracer specializes on" vs "a value the program must stay generic over". Both static
  and dynamic extents here are concrete by the time a program is lowered (XLA shapes
  are static), but the *staticness flag* is preserved and queried by kernels and
  algorithms to decide whether they may specialize: unroll loops, bake grids and
  BlockSpecs, assume MXU alignment. Dynamic extents get ``lax.fori_loop`` bodies and
  padded/masked blocks instead. This reproduces the mechanism behind the paper's
  Fig. 5 (~2x from static inner extents) in TPU terms.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence, Union


class _DynamicExtent:
    """Sentinel mirroring C++ ``std::dynamic_extent``. Singleton."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "dynamic_extent"

    def __reduce__(self):
        return (_DynamicExtent, ())


#: The sentinel users write in extent lists, e.g. ``Extents(20, dynamic_extent)``.
dynamic_extent = _DynamicExtent()

ExtentLike = Union[int, _DynamicExtent]


@dataclasses.dataclass(frozen=True)
class Extents:
    """A rank-R multi-index domain with per-rank static/dynamic marking.

    ``statics[r]`` is the compile-time extent (int) or None when rank r is dynamic.
    ``sizes[r]`` is the bound size of every rank (static ranks repeat their static
    value). Construction mirrors C++: static extents come from the "type" (the
    ``statics`` tuple), dynamic ones from constructor arguments, in order.

    >>> e = Extents.of(20, dynamic_extent)(40)
    >>> e.extent(0), e.extent(1), e.static_extent(1)
    (20, 40, None)
    """

    statics: tuple  # tuple[int | None, ...]
    sizes: tuple    # tuple[int, ...]

    # -- constructors ------------------------------------------------------------
    @staticmethod
    def of(*spec: ExtentLike) -> "_ExtentsFactory":
        """Partially-applied constructor mirroring the C++ template-parameter split."""
        return _ExtentsFactory(tuple(spec))

    @staticmethod
    def make(spec: Sequence[ExtentLike], dynamic_sizes: Sequence[int] = ()) -> "Extents":
        statics = tuple(None if isinstance(s, _DynamicExtent) else int(s) for s in spec)
        dyn = list(dynamic_sizes)
        sizes = []
        for s in statics:
            if s is None:
                if not dyn:
                    raise TypeError(
                        f"Extents{tuple(spec)} needs {sum(x is None for x in statics)} "
                        f"dynamic size(s), got {len(dynamic_sizes)}"
                    )
                sizes.append(int(dyn.pop(0)))
            else:
                if s < 0:
                    raise ValueError(f"negative static extent {s}")
                sizes.append(s)
        if dyn:
            raise TypeError(f"too many dynamic sizes for spec {tuple(spec)}")
        if any(x < 0 for x in sizes):
            raise ValueError(f"negative extent in {sizes}")
        return Extents(statics, tuple(sizes))

    @staticmethod
    def fully_static(*sizes: int) -> "Extents":
        if any(int(s) < 0 for s in sizes):
            raise ValueError(f"negative extent in {sizes}")
        return Extents(tuple(int(s) for s in sizes), tuple(int(s) for s in sizes))

    @staticmethod
    def fully_dynamic(*sizes: int) -> "Extents":
        if any(int(s) < 0 for s in sizes):
            raise ValueError(f"negative extent in {sizes}")
        return Extents(tuple(None for _ in sizes), tuple(int(s) for s in sizes))

    # -- observers (paper Table I names) ------------------------------------------
    @property
    def rank(self) -> int:
        return len(self.statics)

    @property
    def rank_dynamic(self) -> int:
        return sum(1 for s in self.statics if s is None)

    def extent(self, r: int) -> int:
        return self.sizes[r]

    def static_extent(self, r: int):
        """The compile-time extent of rank r, or None (C++: dynamic_extent)."""
        return self.statics[r]

    def is_static(self, r: int) -> bool:
        return self.statics[r] is not None

    @property
    def is_fully_static(self) -> bool:
        return all(s is not None for s in self.statics)

    def size(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    # -- utilities ----------------------------------------------------------------
    def as_shape(self) -> tuple:
        return self.sizes

    def with_extent(self, r: int, size: int, static: bool = False) -> "Extents":
        statics = list(self.statics)
        sizes = list(self.sizes)
        statics[r] = int(size) if static else None
        sizes[r] = int(size)
        return Extents(tuple(statics), tuple(sizes))

    def indices(self) -> Iterator[tuple]:
        """Iterate the whole multi-index domain (test-sized extents only)."""
        import itertools

        return itertools.product(*(range(s) for s in self.sizes))

    def contains(self, idx: Sequence[int]) -> bool:
        return len(idx) == self.rank and all(
            0 <= i < s for i, s in zip(idx, self.sizes)
        )

    def __repr__(self) -> str:
        parts = [
            (str(st) if st is not None else f"dyn({sz})")
            for st, sz in zip(self.statics, self.sizes)
        ]
        return f"Extents<{', '.join(parts)}>"


@dataclasses.dataclass(frozen=True)
class _ExtentsFactory:
    spec: tuple

    def __call__(self, *dynamic_sizes: int) -> Extents:
        return Extents.make(self.spec, dynamic_sizes)
