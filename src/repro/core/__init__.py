"""repro.core — the paper's contribution: mdspan (extents × layout × accessor) in JAX.

Public surface mirrors P0009 where JAX semantics allow; see DESIGN.md §2/§8 for the
TPU adaptation and documented deviations.
"""
from .extents import Extents, dynamic_extent
from .layouts import (
    LayoutError,
    LayoutLeft,
    LayoutMapping,
    LayoutPaged,
    LayoutRight,
    LayoutStride,
    LayoutSymmetricPacked,
    LayoutTiledTPU,
)
from .accessors import (
    Accessor,
    AccumulateAccessor,
    BasicAccessor,
    BitPackedAccessor,
    HostTierAccessor,
    MemorySpace,
    MemorySpaceAccessor,
    QuantizedAccessor,
    RestrictAccessor,
    require_same_space,
)
from .mdspan import MdSpan, mdspan
from .submdspan import SliceShape, all_, submdspan
from . import algorithms

__all__ = [
    "Extents",
    "dynamic_extent",
    "LayoutError",
    "LayoutLeft",
    "LayoutMapping",
    "LayoutPaged",
    "LayoutRight",
    "LayoutStride",
    "LayoutSymmetricPacked",
    "LayoutTiledTPU",
    "Accessor",
    "AccumulateAccessor",
    "BasicAccessor",
    "BitPackedAccessor",
    "HostTierAccessor",
    "MemorySpace",
    "MemorySpaceAccessor",
    "QuantizedAccessor",
    "RestrictAccessor",
    "require_same_space",
    "MdSpan",
    "mdspan",
    "SliceShape",
    "all_",
    "submdspan",
    "algorithms",
]
