"""CountingAccessor: the paper's accessor hook used FOR observability.

The mdspan accessor policy is usually pitched as changing what an element IS
(atomic, restrict, quantized). This module uses the same customization point
to change what an access REPORTS: ``CountingAccessor`` wraps any accessor in
this repo — BasicAccessor f32, QuantizedAccessor intN, BitPackedAccessor —
and forwards every operation unchanged while tallying loads/stores and the
representation-true bytes behind them (each wrapped accessor prices its own
``bytes_for_offsets``; the wrapper never looks inside buffers).

Because accessors see only flat codomain offsets, the wrapper composes with
any layout. ``counted_paged_decode`` is the payoff: it drives LayoutPaged's
offset formula

    ((page * Hkv + head) * page_size + slot) * D + d

through a counted accessor and replays the paged-decode jnp twin's math on
the gathered values — same output as ``kernels.ops.paged_decode_attention``,
plus a measured bytes-moved figure that ``benchmarks/roofline.py``'s analytic
model must reproduce (tests pin agreement within 10% for the f32, int8 and
int4 paths). Page skipping mirrors the kernel: only pages with
``j * page_size < context_len`` are gathered, so the tally reflects the
traffic the kernel actually schedules, not the dense worst case.

int4 pages count through ``accessors.Int4SplitHalfAccessor`` (row =
head_dim), the flat accessor that speaks the pages' split-half nibble order —
``kvquant.as_flat_accessor`` returns it for 4-bit specs, so all three kv
dtypes (f32, int8, int4) are measurable and tests pin measured-vs-analytic
agreement for each.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.accessors import Accessor

NEG_INF = -1e30


@dataclasses.dataclass
class TrafficTally:
    """Running totals of accessor traffic (host-side ints, O(1) memory)."""

    loads: int = 0          # offsets read
    stores: int = 0         # offsets written
    bytes_loaded: int = 0   # storage bytes behind the reads
    bytes_stored: int = 0   # storage bytes behind the writes

    @property
    def bytes_moved(self) -> int:
        return self.bytes_loaded + self.bytes_stored

    def reset(self) -> None:
        self.loads = self.stores = 0
        self.bytes_loaded = self.bytes_stored = 0


class CountingAccessor(Accessor):
    """Wrap ``inner``, forwarding everything and counting traffic into
    ``tally``. Offsets must be host-concrete (numpy / python ints) so the
    count happens at call time — this is an instrumentation twin for the jnp
    paths, not something to close a jit over."""

    def __init__(self, inner: Accessor, tally: TrafficTally | None = None):
        self.inner = inner
        self.tally = tally if tally is not None else TrafficTally()

    @property
    def element_type(self) -> Any:  # type: ignore[override]
        return self.inner.element_type

    def storage_dtype(self):
        return self.inner.storage_dtype()

    def alloc(self, span_size: int):
        return self.inner.alloc(span_size)

    def from_codomain(self, dense):
        return self.inner.from_codomain(dense)

    def access(self, buffers, i):
        self.tally.loads += int(np.size(i))
        self.tally.bytes_loaded += self.inner.bytes_for_offsets(i)
        return self.inner.access(buffers, i)

    def store(self, buffers, i, value):
        self.tally.stores += int(np.size(i))
        self.tally.bytes_stored += self.inner.bytes_for_offsets(i)
        return self.inner.store(buffers, i, value)

    def decay(self, buffers):
        return self.inner.decay(buffers)

    @property
    def offset_policy(self) -> "Accessor":
        # rebased views keep counting into the SAME tally
        return self

    def offset(self, buffers, i):
        return self.inner.offset(buffers, i)

    def bytes_for_offsets(self, i) -> int:
        return self.inner.bytes_for_offsets(i)


def flat_pool_offsets(phys_pages, hkv: int, page_size: int, head_dim: int):
    """Flat codomain offsets of whole pages: LayoutPaged's offset formula
    vectorized over (n_pages, Hkv, page_size, D). ``phys_pages`` is a 1-D
    array of physical page ids."""
    p = np.asarray(phys_pages, np.int64)
    h = np.arange(hkv, dtype=np.int64)
    s = np.arange(page_size, dtype=np.int64)
    d = np.arange(head_dim, dtype=np.int64)
    return (
        ((p[:, None, None, None] * hkv + h[None, :, None, None]) * page_size
         + s[None, None, :, None]) * head_dim + d[None, None, None, :]
    )


def counted_paged_decode(
    q,
    k_buffers,
    v_buffers,
    accessor: CountingAccessor,
    block_tables,
    context_lens,
    *,
    pool_shape,
    scale: float | None = None,
):
    """Paged GQA decode through a counted accessor over the FLAT pool codomain.

    q: (B, Hq, 1, D); k_buffers/v_buffers: ``accessor``-encoded buffers of the
    flattened (num_pages, Hkv, page_size, D) pool (f32: the pool reshaped to
    1-D; int8: kvquant's flat bytes + (page*head) scales —
    ``PagedQuantSpec.as_flat_accessor`` buffers); block_tables: (B, max_pages);
    context_lens: (B,); pool_shape: the 4-tuple above. Returns (out, tally)
    where ``out`` matches ``ops.paged_decode_attention`` on the equivalent
    dense pool and ``tally`` is the accessor's traffic after this call.

    Per-row math is the jnp twin's, restricted to the live pages the kernel
    DMAs (masked tail positions inside the last live page are exactly zeroed
    by the ``* live`` term, so dropping fully-dead pages is value-identical).
    """
    q = np.asarray(q, np.float32)
    b, hq, tq, d = q.shape
    num_pages, hkv, page_size, d_pool = pool_shape
    assert tq == 1 and hq % hkv == 0 and d == d_pool
    group = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / float(np.sqrt(d))
    block_tables = np.asarray(block_tables)
    context_lens = np.asarray(context_lens)

    out = np.zeros((b, hq, 1, d), np.float32)
    for row in range(b):
        n_tok = int(context_lens[row])
        if n_tok <= 0:
            continue  # kernel parity: fully-masked rows output exact zeros
        n_live = -(-n_tok // page_size)
        offs = flat_pool_offsets(
            block_tables[row, :n_live], hkv, page_size, d
        )  # (n_live, Hkv, ps, D)
        k = np.asarray(accessor.access(k_buffers, offs), np.float32)
        v = np.asarray(accessor.access(v_buffers, offs), np.float32)
        s_len = n_live * page_size
        # (n_live, Hkv, ps, D) -> (Hkv, n_live*ps, D)
        k = np.moveaxis(k, 1, 0).reshape(hkv, s_len, d)
        v = np.moveaxis(v, 1, 0).reshape(hkv, s_len, d)
        qg = q[row].reshape(hkv, group, d)
        s = np.einsum("hgd,hkd->hgk", qg, k) * scale
        live = np.arange(s_len) < n_tok
        s = np.where(live[None, None, :], s, NEG_INF)
        m = np.max(s, axis=-1, keepdims=True)
        p = np.exp(s - m) * live[None, None, :]
        ell = np.sum(p, axis=-1, keepdims=True)
        o = np.einsum("hgk,hkd->hgd", p, v) / np.where(ell == 0.0, 1.0, ell)
        out[row] = o.reshape(hq, 1, d)
    return jnp.asarray(out), accessor.tally
