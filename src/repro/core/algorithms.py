"""Layout-generic algorithms over MdSpans, with trace-time property gating.

This module reproduces the paper's algorithm-design discussion (§Layout
abstraction): an algorithm states its layout requirements through the Table I
property queries and either specializes or rejects **while tracing** — the JAX
analogue of failing at compile time.

  scale(s, a)   needs every multi-index to alias a distinct offset (is_unique) OR a
                contiguous codomain it can treat as 1-D (is_contiguous) — the paper's
                exact example, including why symmetric-packed storage would
                double-scale off-diagonals under the naive loop.
  dot(a, b)     needs NO uniqueness (paper's counter-example): reads only.
  fill / copy / sum / iota — further consumers of the same gates.

Accessor-aware fast paths: scaling a contiguous QuantizedAccessor view multiplies
only the per-block scales (bytes touched: nblocks, not span) — the abstraction is
not just zero-overhead but *negative*-overhead where the access path exposes
structure, which is the paper's deeper argument for accessors as customization
points.
"""
from __future__ import annotations

import jax.numpy as jnp

from .accessors import AccumulateAccessor, BasicAccessor, QuantizedAccessor
from .layouts import LayoutError
from .mdspan import MdSpan


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise LayoutError(msg)


def scale(span: MdSpan, alpha) -> MdSpan:
    """span *= alpha, layout-generically. Paper §Layout abstraction."""
    if span.is_contiguous():
        # Operate on the codomain as a 1-D mdspan (paper's contiguous fast path).
        acc = span.accessor
        if isinstance(acc, QuantizedAccessor):
            # Accessor-aware: scaling commutes with dequantization.
            bufs = dict(span.buffers)
            bufs["scale"] = bufs["scale"] * jnp.asarray(alpha, jnp.float32)
            return span.with_buffers(bufs)
        if isinstance(acc, (BasicAccessor,)):
            return span.with_buffers(span.buffers * jnp.asarray(alpha, acc.element_type))
        # generic contiguous: decay -> scale -> re-encode
        return span.with_buffers(acc.from_codomain(acc.decay(span.buffers) * alpha))
    _require(
        span.is_unique(),
        "scale() over the index domain requires a unique layout (symmetric-packed "
        "storage would double-scale off-diagonal entries) or a contiguous codomain",
    )
    offs = span.layout.offsets_dense().reshape(-1)
    vals = span.accessor.access(span.buffers, offs)
    return span.with_buffers(span.accessor.store(span.buffers, offs, vals * alpha))


def fill(span: MdSpan, value) -> MdSpan:
    if span.is_contiguous():
        acc = span.accessor
        codo = jnp.full((span.layout.required_span_size(),), value, acc.element_type)
        return span.with_buffers(acc.from_codomain(codo))
    _require(span.is_unique() or True, "")  # fill is idempotent: non-unique is fine
    offs = span.layout.offsets_dense().reshape(-1)
    return span.with_buffers(span.accessor.store(span.buffers, offs, value))


def copy(dst: MdSpan, src: MdSpan) -> MdSpan:
    """dst[i...] = src[i...] over the common domain. Needs unique dst."""
    _require(dst.shape == src.shape, f"shape mismatch {dst.shape} vs {src.shape}")
    _require(
        dst.is_unique() or isinstance(dst.accessor, AccumulateAccessor),
        "copy() into a non-unique layout is ill-defined",
    )
    offs = dst.layout.offsets_dense().reshape(-1)
    vals = src.to_dense().reshape(-1)
    return dst.with_buffers(dst.accessor.store(dst.buffers, offs, vals))


def reduce_sum(span: MdSpan):
    """Sum over the INDEX DOMAIN (not the codomain): symmetric-packed counts
    off-diagonals twice, as the math requires. No uniqueness needed (read-only)."""
    return jnp.sum(span.to_dense())


def dot(a: MdSpan, b: MdSpan):
    """Paper's example of an algorithm with no uniqueness requirement."""
    _require(a.shape == b.shape, f"shape mismatch {a.shape} vs {b.shape}")
    return jnp.sum(a.to_dense() * b.to_dense())


def matvec(A: MdSpan, x: MdSpan):
    """y = A @ x, layout-generically (the MatVec benchmark's semantic spec).

    kernels/ops.py overrides this with layout-specialized Pallas kernels; this body
    is the semantics-only fallback every layout must satisfy.
    """
    _require(A.rank == 2 and x.rank == 1, "matvec needs rank-2 A, rank-1 x")
    _require(A.extent(1) == x.extent(0), "inner extent mismatch")
    return A.to_dense() @ x.to_dense()


def add_into(dst: MdSpan, src: MdSpan) -> MdSpan:
    """dst += src. For non-unique dst layouts this requires accumulate semantics
    (the atomic-accessor use case, TPU-adapted)."""
    _require(dst.shape == src.shape, "shape mismatch")
    if not dst.is_unique():
        _require(
            isinstance(dst.accessor, AccumulateAccessor),
            "accumulation into a non-unique layout requires AccumulateAccessor "
            "(the paper's atomic use case)",
        )
        # Each codomain slot must receive the sum of ALL domain contributions.
        offs = dst.layout.offsets_dense().reshape(-1)
        return dst.with_buffers(
            dst.accessor.store(dst.buffers, offs, src.to_dense().reshape(-1))
        )
    offs = dst.layout.offsets_dense().reshape(-1)
    cur = dst.accessor.access(dst.buffers, offs)
    return dst.with_buffers(
        dst.accessor.store(dst.buffers, offs, cur + src.to_dense().reshape(-1))
    )
