"""submdspan — the paper's ``subspan``: arbitrary rectangular slices of an MdSpan.

Slice specifiers (P0009's verbose-but-composable model):
  * an integer  — fix that rank (rank is dropped from the result)
  * ``all``     — keep the whole rank (static extent is preserved)
  * ``(a, b)``  — the half-open range [a, b)  (C++ ``pair{a, b}``; extent becomes
                  dynamic, matching P0009)

The result SHARES the parent's buffers — a subspan is pure index arithmetic that
folds into the layout (a ``LayoutStride`` with a base offset). Zero cost: the
Subspan3D benchmark asserts the optimized HLO of subspan-composed loops is identical
to direct indexing (paper Figs. 7/8).

Chunk views are submdspans (the paged regime)
---------------------------------------------
The serving engine's chunked prefill is this module applied to ``LayoutPaged``:
a prefill chunk — the tokens one mixed engine step computes for one sequence —
is the pos-range slice ``submdspan(seq_view, all_, all_, (a, b), all_)`` of that
sequence's paged cache view, and ``LayoutPaged.slice_layout`` makes the result
a LayoutPaged again: rows trimmed to exactly the pages covering ``[a, b)``,
with ``pos_offset`` recording where inside the first page the chunk begins.
No bytes move; the chunk is index arithmetic over the same pool, exactly as a
``LayoutStride`` subspan is over a dense buffer.

The laws (tests/test_submdspan_paged.py):
  * pointwise:  ``sub(s, h, p, d) == parent(s, h, a + p, d)`` for every index —
    including partial-page boundaries, where ``a % page_size != 0`` shifts the
    slot arithmetic by ``pos_offset`` instead of re-tiling anything;
  * composition: slicing a slice equals one slice with the composed range
    (``(a, b)`` then ``(c, d)`` == ``(a + c, a + d)``), the P0009 subspan law;
  * aliasing:   ``shared_pages`` filters to the pages the chunk references, so
    a chunk lying entirely past a shared prefix is ``is_unique()`` even when
    the parent view is not. This is the formal shape of the shared-prefix
    compute skip: the engine may start a request's first chunk at the first
    non-shared token precisely because that chunk's view owns its pages — the
    skipped prefix stays a read-only alias of the donor's;
  * accessor orthogonality (paper Table II, as in PR 3's accessor∘layout
    sections): the slice transforms only the LAYOUT; reading a chunk of a
    quantized pool decodes through the same accessor and then gathers through
    the sliced offsets, so chunk reads commute with dequantization.

Verification is a chunk (the speculative regime)
------------------------------------------------
Speculative decoding (serving/speculative.py) adds NO new view machinery —
the verify step of a K-token draft window is the same pos-range submdspan the
chunked prefill already compiles, at width K+1. Presenting [current token,
draft] to the model is the slice ``(L, L + K + 1)`` of the sequence's paged
view: one causal chunk whose logits score every draft position in a single
kernel dispatch, exactly as a prefill chunk scores its prompt positions.
Acceptance then moves the OTHER direction along the same arithmetic: rolling
back the ``K + 1 - a`` rejected tokens never touches pool bytes, it shrinks
the view — the per-sequence length (the lens) retreats to ``L + a``, and the
garbage KV left past the lens is dead by construction because every later
slice, chunk, and decode step reads through lens-bounded layouts. Draft,
verify, and rollback are all index arithmetic over one pool: speculation is
submdspan applied to time, as chunking is submdspan applied to prefill.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from .extents import Extents
from .layouts import LayoutError, LayoutMapping
from .mdspan import MdSpan


class _All:
    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):  # pragma: no cover
        return "all"


#: slice-everything sentinel (paper: ``std::full_extent`` / Kokkos ``ALL``)
all_ = _All()


@dataclasses.dataclass(frozen=True)
class SliceShape:
    """Resolved slice geometry handed to LayoutMapping.slice_layout."""

    extents: Extents          # extents of the sub-view (kept ranks only)
    keep: Tuple[bool, ...]    # per-parent-rank: does it survive into the sub-view?


def _resolve(spec, parent: Extents):
    if len(spec) != parent.rank:
        raise TypeError(f"{len(spec)} slice specifiers for rank-{parent.rank} mdspan")
    starts, keep, new_statics, new_sizes = [], [], [], []
    for r, s in enumerate(spec):
        psize = parent.extent(r)
        if isinstance(s, _All):
            starts.append(0)
            keep.append(True)
            new_statics.append(parent.static_extent(r))
            new_sizes.append(psize)
        elif isinstance(s, tuple) and len(s) == 2:
            a, b = int(s[0]), int(s[1])
            if not (0 <= a <= b <= psize):
                raise IndexError(f"slice ({a},{b}) out of bounds for extent {psize}")
            starts.append(a)
            keep.append(True)
            new_statics.append(None)  # P0009: pair slices yield dynamic extents
            new_sizes.append(b - a)
        elif isinstance(s, int):
            if not (0 <= s < psize) and psize > 0:
                raise IndexError(f"index {s} out of bounds for extent {psize}")
            starts.append(int(s))
            keep.append(False)
        else:
            raise TypeError(f"bad slice specifier {s!r}")
    sub_ext = Extents(tuple(new_statics), tuple(new_sizes))
    return starts, SliceShape(sub_ext, tuple(keep))


def submdspan(span: MdSpan, *spec) -> MdSpan:
    """Slice an MdSpan. Shares buffers; composes layouts; zero runtime cost."""
    starts, shape = _resolve(spec, span.extents)
    try:
        sub_layout: LayoutMapping = span.layout.slice_layout(starts, shape)
    except LayoutError:
        raise
    # Accessor offset policy (paper Table II): rebasing may change the accessor
    # type (e.g. alignment-carrying spaces decay). We keep the base offset inside
    # the layout, so only the *policy* transition applies, not a buffer rebase.
    accessor = span.accessor.offset_policy
    return MdSpan(span.buffers, sub_layout, accessor)
