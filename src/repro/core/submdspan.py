"""submdspan — the paper's ``subspan``: arbitrary rectangular slices of an MdSpan.

Slice specifiers (P0009's verbose-but-composable model):
  * an integer  — fix that rank (rank is dropped from the result)
  * ``all``     — keep the whole rank (static extent is preserved)
  * ``(a, b)``  — the half-open range [a, b)  (C++ ``pair{a, b}``; extent becomes
                  dynamic, matching P0009)

The result SHARES the parent's buffers — a subspan is pure index arithmetic that
folds into the layout (a ``LayoutStride`` with a base offset). Zero cost: the
Subspan3D benchmark asserts the optimized HLO of subspan-composed loops is identical
to direct indexing (paper Figs. 7/8).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from .extents import Extents
from .layouts import LayoutError, LayoutMapping
from .mdspan import MdSpan


class _All:
    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):  # pragma: no cover
        return "all"


#: slice-everything sentinel (paper: ``std::full_extent`` / Kokkos ``ALL``)
all_ = _All()


@dataclasses.dataclass(frozen=True)
class SliceShape:
    """Resolved slice geometry handed to LayoutMapping.slice_layout."""

    extents: Extents          # extents of the sub-view (kept ranks only)
    keep: Tuple[bool, ...]    # per-parent-rank: does it survive into the sub-view?


def _resolve(spec, parent: Extents):
    if len(spec) != parent.rank:
        raise TypeError(f"{len(spec)} slice specifiers for rank-{parent.rank} mdspan")
    starts, keep, new_statics, new_sizes = [], [], [], []
    for r, s in enumerate(spec):
        psize = parent.extent(r)
        if isinstance(s, _All):
            starts.append(0)
            keep.append(True)
            new_statics.append(parent.static_extent(r))
            new_sizes.append(psize)
        elif isinstance(s, tuple) and len(s) == 2:
            a, b = int(s[0]), int(s[1])
            if not (0 <= a <= b <= psize):
                raise IndexError(f"slice ({a},{b}) out of bounds for extent {psize}")
            starts.append(a)
            keep.append(True)
            new_statics.append(None)  # P0009: pair slices yield dynamic extents
            new_sizes.append(b - a)
        elif isinstance(s, int):
            if not (0 <= s < psize) and psize > 0:
                raise IndexError(f"index {s} out of bounds for extent {psize}")
            starts.append(int(s))
            keep.append(False)
        else:
            raise TypeError(f"bad slice specifier {s!r}")
    sub_ext = Extents(tuple(new_statics), tuple(new_sizes))
    return starts, SliceShape(sub_ext, tuple(keep))


def submdspan(span: MdSpan, *spec) -> MdSpan:
    """Slice an MdSpan. Shares buffers; composes layouts; zero runtime cost."""
    starts, shape = _resolve(spec, span.extents)
    try:
        sub_layout: LayoutMapping = span.layout.slice_layout(starts, shape)
    except LayoutError:
        raise
    # Accessor offset policy (paper Table II): rebasing may change the accessor
    # type (e.g. alignment-carrying spaces decay). We keep the base offset inside
    # the layout, so only the *policy* transition applies, not a buffer rebase.
    accessor = span.accessor.offset_policy
    return MdSpan(span.buffers, sub_layout, accessor)
