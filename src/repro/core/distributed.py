"""DistributedLayout & TensorSpec: the paper's LayoutMapping promoted to a 512-chip mesh.

The central observation (DESIGN.md §3): GSPMD sharding *is* a layout mapping — a
strided-block map from the logical multi-index domain onto
(device-grid coordinates) × (local offsets). We make it a first-class
``LayoutMapping`` subclass so the paper's Table I property algebra (uniqueness,
stridedness, contiguity *per shard*) applies verbatim, and derive JAX
``NamedSharding``s from it. One mechanism then expresses DP / FSDP / TP / EP / SP.

``TensorSpec`` is the framework's universal tensor descriptor — the mdspan "type":

    TensorSpec(extents, logical_axes, dtype, accessor, init)

Every parameter, activation boundary, optimizer slot and cache in the model zoo is
declared as a TensorSpec; shardings, dry-run ShapeDtypeStructs, initializers and
quantized-kernel dispatch all derive from it. Logical axis names are bound to mesh
axes by a ``ShardingRules`` table (per architecture × per shape), so re-targeting
parallelism = swapping a rules table, never touching model code — the paper's
"change the layout in the type of A without changing the algorithm" at cluster scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .accessors import Accessor, BasicAccessor, QuantizedAccessor
from .extents import Extents
from .layouts import LayoutMapping, LayoutRight, _row_major_strides

AxisBinding = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------------
# DistributedLayout: a real LayoutMapping over (devices × local memory)
# ---------------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DistributedLayout(LayoutMapping):
    """Block map: logical index -> (device coordinate per sharded dim, local offset).

    ``mesh_axes[r]`` gives the mesh-axis name(s) dim r is sharded over (None =
    replicated in that dim). ``axis_sizes`` maps axis name -> size. The codomain is
    linearized as device_id * local_span + local_offset, making this a genuine
    single-offset LayoutMapping whose Table I properties are testable:

      is_unique()      True  (block sharding never aliases)
      is_contiguous()  True iff every sharded dim divides evenly AND sharded dims
                       are a prefix of the dim order (device blocks tile the domain)
      is_strided()     True per-shard; globally only when one dim is sharded and it
                       is the outermost — matches GSPMD reality.
    """

    extents: Extents
    mesh_axes: Tuple[AxisBinding, ...]
    axis_sizes: Dict[str, int]

    def __post_init__(self):
        if len(self.mesh_axes) != self.extents.rank:
            raise TypeError("mesh_axes rank mismatch")

    # -- geometry -----------------------------------------------------------------
    def dim_shards(self, r: int) -> int:
        b = self.mesh_axes[r]
        if b is None:
            return 1
        names = (b,) if isinstance(b, str) else b
        n = 1
        for nm in names:
            n *= self.axis_sizes[nm]
        return n

    def local_shape(self) -> Tuple[int, ...]:
        return tuple(
            -(-self.extents.extent(r) // self.dim_shards(r))
            for r in range(self.extents.rank)
        )

    def num_devices_used(self) -> int:
        n = 1
        for r in range(self.extents.rank):
            n *= self.dim_shards(r)
        return n

    def local_span(self) -> int:
        n = 1
        for s in self.local_shape():
            n *= s
        return n

    # -- LayoutMapping ------------------------------------------------------------
    def __call__(self, *idx):
        local = self.local_shape()
        lstr = _row_major_strides(local)
        shard_counts = [self.dim_shards(r) for r in range(self.extents.rank)]
        dstr = _row_major_strides(tuple(shard_counts))
        dev = 0
        loc = 0
        for r, i in enumerate(idx):
            dev = dev + (i // local[r]) * dstr[r]
            loc = loc + (i % local[r]) * lstr[r]
        return dev * self.local_span() + loc

    def device_of(self, *idx):
        local = self.local_shape()
        shard_counts = tuple(self.dim_shards(r) for r in range(self.extents.rank))
        dstr = _row_major_strides(shard_counts)
        dev = 0
        for r, i in enumerate(idx):
            dev = dev + (i // local[r]) * dstr[r]
        return dev

    def local_offset(self, *idx):
        local = self.local_shape()
        lstr = _row_major_strides(local)
        loc = 0
        for r, i in enumerate(idx):
            loc = loc + (i % local[r]) * lstr[r]
        return loc

    def required_span_size(self) -> int:
        return self.num_devices_used() * self.local_span()

    def is_unique(self) -> bool:
        return True

    @classmethod
    def is_always_unique(cls) -> bool:
        return True

    def is_contiguous(self) -> bool:
        # no padding and device-major order coincides with row-major nesting
        for r in range(self.extents.rank):
            if self.extents.extent(r) % self.dim_shards(r) != 0:
                return False
        sharded = [r for r in range(self.extents.rank) if self.dim_shards(r) > 1]
        return sharded == list(range(len(sharded)))

    def is_strided(self) -> bool:
        sharded = [r for r in range(self.extents.rank) if self.dim_shards(r) > 1]
        return len(sharded) == 0 or (sharded == [0] and self.extents.extent(0) % self.dim_shards(0) == 0)

    def stride(self, r: int) -> int:
        if not self.is_strided():
            from .layouts import LayoutError

            raise LayoutError("DistributedLayout is not globally strided here")
        # Strided only when the single sharded dim is outermost and divides evenly;
        # then the boundary hop equals the within-shard step (= the local stride):
        #   local_span - (local[r]-1)*lstr[r] == lstr[r]  for row-major local layouts.
        # (Found by the hypothesis Table-I law tests — see tests/test_layouts.py.)
        local = self.local_shape()
        lstr = _row_major_strides(local)
        return lstr[r]

    # -- JAX binding ----------------------------------------------------------------
    def pspec(self) -> PartitionSpec:
        return PartitionSpec(*self.mesh_axes)

    def sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.pspec())


# ---------------------------------------------------------------------------------
# ShardingRules: logical axis name -> mesh axis binding
# ---------------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names to mesh axes; the per-(arch × shape) layout policy.

    ``rules["embed"] = "model"`` etc. Unknown names are replicated. A dim is only
    sharded if its size divides the product of the bound mesh axes — otherwise the
    binding is dropped for that tensor (e.g. kv_heads=8 with a 16-way model axis →
    replicated KV, the Megatron fallback), keeping every spec lowerable.
    """

    rules: Dict[str, AxisBinding]
    strict_divisibility: bool = True

    def binding_for(
        self, logical_axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh
    ) -> Tuple[AxisBinding, ...]:
        used: set = set()
        out = []
        for name, size in zip(logical_axes, shape):
            b = self.rules.get(name) if name is not None else None
            if b is None:
                out.append(None)
                continue
            names = (b,) if isinstance(b, str) else tuple(b)
            # drop axes already consumed by an earlier dim of this tensor
            names = tuple(n for n in names if n not in used and n in mesh.shape)
            if not names:
                out.append(None)
                continue
            nshards = math.prod(mesh.shape[n] for n in names)
            if self.strict_divisibility and size % nshards != 0:
                out.append(None)  # divisibility fallback (replicate)
                continue
            used.update(names)
            out.append(names[0] if len(names) == 1 else names)
        return tuple(out)

    def pspec(self, logical_axes, shape, mesh) -> PartitionSpec:
        return PartitionSpec(*self.binding_for(logical_axes, shape, mesh))

    def sharding(self, logical_axes, shape, mesh) -> NamedSharding:
        return NamedSharding(mesh, self.pspec(logical_axes, shape, mesh))


# ---------------------------------------------------------------------------------
# TensorSpec: the universal mdspan-style descriptor
# ---------------------------------------------------------------------------------
InitFn = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def _init_zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _init_ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


def _init_normal(stddev: float) -> InitFn:
    def f(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return f


def _init_fan_in(key, shape, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


INITS: Dict[str, Any] = {
    "zeros": _init_zeros,
    "ones": _init_ones,
    "fan_in": _init_fan_in,
    "embed": _init_normal(0.02),
    "normal": _init_normal(0.02),
}


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """extents × logical axes × dtype × accessor: a distributed mdspan descriptor."""

    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"
    static: Tuple[bool, ...] = ()  # per-dim compile-time-specializable flag
    accessor: Optional[Accessor] = None  # None -> BasicAccessor(dtype)

    def __post_init__(self):
        if len(self.logical_axes) != len(self.shape):
            raise TypeError(f"axes/shape rank mismatch: {self}")

    # -- mdspan views -------------------------------------------------------------
    def extents(self) -> Extents:
        static = self.static if self.static else tuple(True for _ in self.shape)
        return Extents(
            tuple(s if st else None for s, st in zip(self.shape, static)), tuple(self.shape)
        )

    def the_accessor(self) -> Accessor:
        return self.accessor if self.accessor is not None else BasicAccessor(self.dtype)

    def distributed_layout(self, mesh: Mesh, rules: ShardingRules) -> DistributedLayout:
        binding = rules.binding_for(self.logical_axes, self.shape, mesh)
        return DistributedLayout(self.extents(), binding, dict(mesh.shape))

    # -- JAX binding ----------------------------------------------------------------
    def sharding(self, mesh: Mesh, rules: ShardingRules) -> NamedSharding:
        return rules.sharding(self.logical_axes, self.shape, mesh)

    def shape_struct(self, mesh: Optional[Mesh] = None, rules: Optional[ShardingRules] = None):
        if self.is_quantized():
            acc = self.the_accessor()
            tree = self._quantized_struct_tree()
            if mesh is not None:
                shard = self.sharding(mesh, rules)
                tree = {
                    k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=self._q_sharding(k, mesh, rules))
                    for k, v in tree.items()
                }
            return tree
        if mesh is None:
            return jax.ShapeDtypeStruct(self.shape, self.dtype)
        return jax.ShapeDtypeStruct(self.shape, self.dtype, sharding=self.sharding(mesh, rules))

    # -- quantized storage ----------------------------------------------------------
    def is_quantized(self) -> bool:
        return isinstance(self.accessor, QuantizedAccessor)

    def _q_shapes(self):
        acc = self.accessor
        *lead, last = self.shape
        if last % acc.block != 0:
            raise ValueError(f"quantized last dim {last} must divide block {acc.block}")
        qlast = last if acc.bits == 8 else last // 2
        return tuple(lead) + (qlast,), tuple(lead) + (last // acc.block,)

    def _quantized_struct_tree(self):
        qs, ss = self._q_shapes()
        return {
            "q": jax.ShapeDtypeStruct(qs, jnp.int8),
            "scale": jax.ShapeDtypeStruct(ss, jnp.float32),
        }

    def _q_sharding(self, part: str, mesh, rules):
        # scales inherit the q sharding on all but the (blocked) last dim
        binding = rules.binding_for(self.logical_axes, self.shape, mesh)
        if part == "scale":
            *lead, last = binding
            qshape, sshape = self._q_shapes()
            nblocks = sshape[-1]
            if last is not None:
                names = (last,) if isinstance(last, str) else last
                n = math.prod(mesh.shape[x] for x in names)
                if nblocks % n != 0:
                    last = None
            binding = tuple(lead) + (last,)
        return NamedSharding(mesh, PartitionSpec(*binding))

    # -- init ------------------------------------------------------------------------
    def initialize(self, key: jax.Array):
        init = INITS[self.init]
        dense = init(key, self.shape, jnp.float32 if self.is_quantized() else self.dtype)
        if self.is_quantized():
            return quantize_array(dense, self.accessor)
        return dense

    def mdspan_over(self, buffers) -> "Any":
        from .mdspan import MdSpan

        return MdSpan(buffers, LayoutRight(self.extents()), self.the_accessor())


def quantize_array(dense: jax.Array, acc: QuantizedAccessor):
    """Quantize along the LAST dim in blocks of ``acc.block``; returns {"q","scale"}.

    The N-D batched form of ``QuantizedAccessor.from_codomain`` (same math,
    vectorized over leading dims) — used for weights and optimizer state.
    """
    *lead, last = dense.shape
    if last % acc.block != 0:
        raise ValueError(f"last dim {last} % block {acc.block} != 0")
    nb = last // acc.block
    x = jnp.asarray(dense, jnp.float32).reshape(*lead, nb, acc.block)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(absmax > 0, absmax / acc.qmax, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale[..., None]), -acc.qmax, acc.qmax).astype(jnp.int8)
    q = q.reshape(*lead, last)
    if acc.bits == 4:
        q2 = q.reshape(*lead, last // 2, 2)
        q = ((q2[..., 0] & 0x0F) | ((q2[..., 1] & 0x0F) << 4)).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_array(bufs, acc: QuantizedAccessor) -> jax.Array:
    q = bufs["q"]
    scale = bufs["scale"]
    if acc.bits == 4:
        lo = (q & 0x0F).astype(jnp.int8)
        hi = ((q >> 4) & 0x0F).astype(jnp.int8)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1], q.shape[-1] * 2)
    *lead, last = q.shape
    nb = scale.shape[-1]
    x = q.astype(jnp.float32).reshape(*lead, nb, last // nb) * scale[..., None]
    return x.reshape(*lead, last).astype(acc.element_type)


# ---------------------------------------------------------------------------------
# pytree-of-spec helpers
# ---------------------------------------------------------------------------------
def is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def tree_shardings(specs, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: (
            {k: s._q_sharding(k, mesh, rules) for k in ("q", "scale")}
            if s.is_quantized()
            else s.sharding(mesh, rules)
        ),
        specs,
        is_leaf=is_spec,
    )


def tree_shape_structs(specs, mesh: Optional[Mesh] = None, rules: Optional[ShardingRules] = None):
    return jax.tree.map(lambda s: s.shape_struct(mesh, rules), specs, is_leaf=is_spec)


def tree_initialize(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [s.initialize(k) for s, k in zip(leaves, keys)])


def tree_param_bytes(specs) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        if s.is_quantized():
            qs, ss = s._q_shapes()
            total += math.prod(qs) + math.prod(ss) * 4
        else:
            total += math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
    return total


def tree_param_count(specs) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(specs, is_leaf=is_spec))
