"""Accessors: the paper's Table II concept, functionally restated for JAX.

C++ signature                      JAX restatement (documented deviation, DESIGN.md §8)
--------------------------------   ---------------------------------------------------
A::pointer                         a pytree of buffers (main storage + auxiliaries,
                                   e.g. quantization scales)
A::reference (lvalue)              a get/set pair:
a.access(p, i) -> reference          access(buffers, i) -> value  (read)
                                     store(buffers, i, v) -> buffers  (functional write)
a.offset(p, i) -> pointer          offset(buffers, i) -> buffers rebased at i
A::offset_policy                   offset_policy property (type of the rebased view)
decay to ordinary pointer          decay(buffers) -> plain jnp codomain array

Accessors implemented:
  BasicAccessor        the default (std::accessor_basic); identity access
  RestrictAccessor     identity — XLA IR is alias-free by construction; kept for API
                       parity with the paper's Fig. 1 (the annotation is subsumed)
  AccumulateAccessor   TPU-idiomatic analogue of the paper's AtomicAccessor: stores
                       are sum-combined (scatter-add); safe on NON-unique layouts
  BitPackedAccessor    bools packed 8-per-byte (the vector<bool> use case, Fig. §)
  QuantizedAccessor    intN storage + per-block scales, dequantize on access — the
                       HPC-scale generalization of bit-packing; backs int8 serving
                       weights and 8-bit optimizer state
  MemorySpaceAccessor  strong memory-space types (HBM/VMEM/SMEM/HOST) — the paper's
                       "strong pointer types for heterogeneous memory"; the tag flows
                       into Pallas BlockSpec memory_space and sharding memory_kind
  HostTierAccessor     TWO-space composition: wraps any element accessor over an
                       {hbm, host} buffer pair and routes offsets by page residency —
                       the hierarchical-KV customization point (see the "accessors as
                       memory spaces" section at the bottom of this module)

All access/store implementations are vectorized: ``i`` may be a scalar or an ndarray
of offsets (gather/scatter semantics), so whole-domain reads cost one gather.

Composing accessors with layouts (paper §customization points)
--------------------------------------------------------------
The paper's central design claim is that the layout and accessor policies are
ORTHOGONAL: an accessor sees only flat codomain offsets, so any layout can feed
it and neither policy knows the other exists. This repo exercises the
composition at serving scale: the paged KV cache keeps its index->offset map in
``layouts.LayoutPaged`` (block-table indirection, CoW/refcount laws) while the
element representation is swapped underneath it by
``serving.engine.kvquant.PagedQuantSpec`` — block-scaled intN storage whose
(page, head) scales are exactly ``QuantizedAccessor`` block scales with
``block = page_size * head_dim`` over the paged codomain (for int8 the pool's
bytes ARE valid QuantizedAccessor buffers; tests assert access-equivalence
through LayoutPaged offsets). Layout laws — ``is_unique()``, ``fork``,
``cow_slice`` — hold identically over quantized pools because they reason about
offsets, never bytes.

Offsets are FRONT-INDEXED: packed representations (BitPacked nibble/bit parity,
Quantized block scales) cannot recover the true span from their buffers (an odd
span leaves a pad nibble), so pythonic negative offsets are ambiguous and the
packed accessors reject static negative ``i`` rather than silently reading the
wrong nibble or block scale.

Instrumentation via accessor composition (observability as a policy)
--------------------------------------------------------------------
The same customization point that swaps the element REPRESENTATION (the
quantization section above) can swap the element OBSERVATION:
``core.instrument.CountingAccessor`` wraps any accessor here and forwards
every operation to it unchanged while tallying loads/stores and the bytes
they touch. Because an accessor sees only flat codomain offsets, the wrapper
composes with any layout — the instrumented paged-decode twin in
``core.instrument.counted_paged_decode`` drives LayoutPaged's offset formula
through a counted f32 or quantized accessor and gets measured bytes-moved
that ``benchmarks/roofline.py`` checks against its analytic model.

The byte accounting lives HERE, not in the wrapper, because only the accessor
knows its representation's cost: ``bytes_for_offsets(i)`` returns the storage
bytes behind a batch of offsets — ``n * itemsize`` for dense accessors,
``n`` int8 bytes (+ 4 per distinct block scale) for QuantizedAccessor at 8
bits, half that for int4 nibbles, ``n/8`` for BitPackedAccessor. The wrapper
never inspects buffers; it just asks the policy it wraps.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Accessor:
    """Base documenting the concept; see module docstring."""

    element_type: Any  # logical dtype exposed to algorithms

    # storage ------------------------------------------------------------------
    def storage_dtype(self):
        return self.element_type

    def alloc(self, span_size: int):
        """Allocate zeroed buffers for a codomain of ``span_size`` elements."""
        raise NotImplementedError

    def from_codomain(self, dense_codomain):
        """Encode a plain codomain array (element_type) into buffers."""
        raise NotImplementedError

    # access -------------------------------------------------------------------
    def access(self, buffers, i):
        raise NotImplementedError

    def store(self, buffers, i, value):
        raise NotImplementedError

    def decay(self, buffers):
        """Plain jnp array over the codomain (C++: decay to ordinary pointer)."""
        raise NotImplementedError

    @property
    def offset_policy(self) -> "Accessor":
        return self

    def offset(self, buffers, i):
        """Rebase buffers at offset i (C++ a.offset(p, i)); returns buffers usable
        with ``self.offset_policy`` such that access(offset(p,i), 0) == access(p,i)."""
        raise NotImplementedError

    # instrumentation ----------------------------------------------------------
    def bytes_for_offsets(self, i) -> int:
        """Storage bytes behind a batch of offsets ``i`` (scalar or ndarray) —
        the representation-specific cost model ``core.instrument``'s
        CountingAccessor charges per access/store. Dense default: one storage
        element per offset."""
        n = int(np.size(i))
        return n * jnp.dtype(self.storage_dtype()).itemsize


@dataclasses.dataclass(frozen=True)
class BasicAccessor(Accessor):
    element_type: Any = jnp.float32

    def alloc(self, span_size: int):
        return jnp.zeros((span_size,), dtype=self.element_type)

    def from_codomain(self, dense):
        return jnp.asarray(dense, dtype=self.element_type)

    def access(self, buffers, i):
        return buffers[i]

    def store(self, buffers, i, value):
        return buffers.at[i].set(jnp.asarray(value, dtype=self.element_type))

    def decay(self, buffers):
        return buffers

    def offset(self, buffers, i):
        return buffers[i:]


@dataclasses.dataclass(frozen=True)
class RestrictAccessor(BasicAccessor):
    """Paper Fig. 1. In XLA there is no aliasing to annotate away (functional IR);
    this accessor exists to keep the concept surface complete and is the identity."""


@dataclasses.dataclass(frozen=True)
class AccumulateAccessor(Accessor):
    """Stores ACCUMULATE (scatter-add) instead of overwrite.

    TPU adaptation of the paper's AtomicAccessor: the dominant HPC use of atomics is
    concurrent accumulation; on TPU that is expressed as a sum-combining scatter
    (unique or non-unique layouts both well-defined) or a cross-replica psum. The
    linearity law replaces the atomicity law: storing v1 then v2 at the same offset
    yields +v1+v2 regardless of order.
    """

    element_type: Any = jnp.float32

    def alloc(self, span_size: int):
        return jnp.zeros((span_size,), dtype=self.element_type)

    def from_codomain(self, dense):
        return jnp.asarray(dense, dtype=self.element_type)

    def access(self, buffers, i):
        return buffers[i]

    def store(self, buffers, i, value):
        return buffers.at[i].add(jnp.asarray(value, dtype=self.element_type))

    def decay(self, buffers):
        return buffers

    def offset(self, buffers, i):
        return buffers[i:]


@dataclasses.dataclass(frozen=True)
class BitPackedAccessor(Accessor):
    """bool elements packed 8-per-uint8 (paper: the std::vector<bool> use case)."""

    element_type: Any = jnp.bool_

    def storage_dtype(self):
        return jnp.uint8

    @staticmethod
    def packed_size(span_size: int) -> int:
        return -(-span_size // 8)

    def alloc(self, span_size: int):
        return jnp.zeros((self.packed_size(span_size),), dtype=jnp.uint8)

    def from_codomain(self, dense):
        dense = jnp.asarray(dense, dtype=jnp.bool_)
        pad = (-dense.shape[0]) % 8
        bits = jnp.concatenate([dense, jnp.zeros((pad,), jnp.bool_)]).reshape(-1, 8)
        weights = (1 << jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
        return (bits.astype(jnp.uint8) * weights).sum(axis=1).astype(jnp.uint8)

    @staticmethod
    def _check_offset(i):
        # bit parity of a negative offset depends on the true span, which the
        # byte buffer does not record (see QuantizedAccessor._check_offset)
        if isinstance(i, (int, np.integer)) and i < 0:
            raise TypeError("BitPackedAccessor offsets must be non-negative")

    def access(self, buffers, i):
        self._check_offset(i)
        byte = buffers[i // 8]
        return ((byte >> (jnp.asarray(i) % 8).astype(jnp.uint8)) & 1).astype(jnp.bool_)

    def store(self, buffers, i, value):
        self._check_offset(i)
        i = jnp.asarray(i)
        bit = (jnp.asarray(1, jnp.uint8) << (i % 8).astype(jnp.uint8))
        byte_idx = i // 8
        cleared = buffers.at[byte_idx].min(buffers[byte_idx] & (~bit))
        # set-or-clear functionally: clear the bit, then OR value back in
        cur = buffers[byte_idx]
        newbyte = jnp.where(
            jnp.asarray(value, jnp.bool_), cur | bit, cur & (~bit)
        ).astype(jnp.uint8)
        del cleared
        return buffers.at[byte_idx].set(newbyte)

    def decay(self, buffers):
        weights = (1 << jnp.arange(8, dtype=jnp.uint8)).astype(jnp.uint8)
        bits = (buffers[:, None] & weights[None, :]) != 0
        return bits.reshape(-1)

    def offset(self, buffers, i):
        if isinstance(i, int) and i % 8 == 0:
            return buffers[i // 8:]
        raise TypeError("BitPackedAccessor.offset requires byte-aligned offsets")

    def bytes_for_offsets(self, i) -> int:
        # distinct bytes touched: offsets sharing a byte cost it once
        self._check_offset(i)
        return int(np.unique(np.asarray(i) // 8).size)


@dataclasses.dataclass(frozen=True)
class QuantizedAccessor(Accessor):
    """intN storage with per-block scales; dequantize on access.

    buffers = {"q": int8[ceil(span/block)*block or span], "scale": f32[nblocks]}
    For int4, two nibbles per int8 byte.

    ``store`` re-quantizes with the EXISTING block scale (clipped): scales are data
    statistics computed at encode time (``from_codomain`` / ``quantize``); a scattered
    functional write cannot cheaply recompute them. This matches how quantized
    buffers are used in practice (write-once weights / running optimizer state with
    periodic rescale via ``requantize``).
    """

    element_type: Any = jnp.float32
    bits: int = 8
    block: int = 64

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError("QuantizedAccessor supports bits in {4, 8}")

    def storage_dtype(self):
        return jnp.int8

    @property
    def qmax(self) -> int:
        return 7 if self.bits == 4 else 127

    def _nblocks(self, span: int) -> int:
        return -(-span // self.block)

    def alloc(self, span_size: int):
        nb = self._nblocks(span_size)
        qlen = span_size if self.bits == 8 else -(-span_size // 2)
        return {
            "q": jnp.zeros((qlen,), dtype=jnp.int8),
            "scale": jnp.ones((nb,), dtype=jnp.float32),
        }

    def from_codomain(self, dense):
        dense = jnp.asarray(dense, dtype=jnp.float32)
        span = dense.shape[0]
        nb = self._nblocks(span)
        pad = nb * self.block - span
        padded = jnp.concatenate([dense, jnp.zeros((pad,), jnp.float32)]).reshape(nb, self.block)
        absmax = jnp.max(jnp.abs(padded), axis=1)
        scale = jnp.where(absmax > 0, absmax / self.qmax, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(padded / scale[:, None]), -self.qmax, self.qmax).astype(jnp.int8)
        q = q.reshape(-1)[:span]
        if self.bits == 4:
            qpad = (-span) % 2
            qq = jnp.concatenate([q, jnp.zeros((qpad,), jnp.int8)]).reshape(-1, 2)
            lo = (qq[:, 0] & 0x0F).astype(jnp.int8)
            hi = ((qq[:, 1] & 0x0F) << 4).astype(jnp.int8)
            q = (lo | hi).astype(jnp.int8)
        return {"q": q, "scale": scale}

    @staticmethod
    def _check_offset(i):
        """Packed storage is front-indexed: a negative offset's byte/nibble
        parity and block-scale index depend on the TRUE span, which the buffers
        do not record (an odd span leaves a pad nibble; a partial last block
        shifts every block boundary). Before this check, ``access(bufs, -1)``
        on an odd-span int4 buffer silently read the pad nibble (always 0) and
        ``store(bufs, -1, v)`` corrupted it."""
        if isinstance(i, (int, np.integer)) and i < 0:
            raise TypeError(
                "QuantizedAccessor offsets must be non-negative: negative "
                "offsets are ambiguous for block-scaled/nibble-packed storage "
                "(the true span is not recoverable from the buffers)"
            )

    def _load_q(self, buffers, i):
        self._check_offset(i)
        if self.bits == 8:
            return buffers["q"][i].astype(jnp.int8)
        byte = buffers["q"][jnp.asarray(i) // 2]
        nib = jnp.where(jnp.asarray(i) % 2 == 0, byte & 0x0F, (byte >> 4) & 0x0F)
        # sign-extend 4-bit
        return jnp.where(nib >= 8, nib - 16, nib).astype(jnp.int8)

    def access(self, buffers, i):
        q = self._load_q(buffers, i).astype(jnp.float32)
        s = buffers["scale"][jnp.asarray(i) // self.block]
        return (q * s).astype(self.element_type)

    def store(self, buffers, i, value):
        self._check_offset(i)
        s = buffers["scale"][jnp.asarray(i) // self.block]
        q = jnp.clip(jnp.round(jnp.asarray(value, jnp.float32) / s), -self.qmax, self.qmax).astype(jnp.int8)
        if self.bits == 8:
            return {**buffers, "q": buffers["q"].at[i].set(q)}
        i = jnp.asarray(i)
        byte_idx = i // 2
        old = buffers["q"][byte_idx]
        qn = (q & 0x0F).astype(jnp.int8)
        new = jnp.where(
            i % 2 == 0, (old & ~0x0F) | qn, (old & 0x0F) | (qn << 4)
        ).astype(jnp.int8)
        return {**buffers, "q": buffers["q"].at[byte_idx].set(new)}

    def span_of(self, buffers) -> int:
        n = buffers["q"].shape[0]
        return n if self.bits == 8 else n * 2

    def decay(self, buffers, span=None):
        span = self.span_of(buffers) if span is None else span
        return self.access(buffers, jnp.arange(span))

    def offset(self, buffers, i):
        if isinstance(i, int) and i % self.block == 0 and (self.bits == 8 or i % 2 == 0):
            qi = i if self.bits == 8 else i // 2
            return {
                "q": buffers["q"][qi:],
                "scale": buffers["scale"][i // self.block:],
            }
        raise TypeError("QuantizedAccessor.offset requires block-aligned offsets")

    def requantize(self, buffers, span=None):
        """Recompute block scales from current contents (periodic optimizer rescale)."""
        return self.from_codomain(self.decay(buffers, span))

    def bytes_for_offsets(self, i) -> int:
        """intN payload bytes + one f32 scale per DISTINCT block touched —
        the bandwidth a quantized gather actually moves (block scales are
        reused across the offsets inside a block). Needs concrete offsets
        (numpy/host) to count distinct blocks."""
        self._check_offset(i)
        arr = np.asarray(i)
        n = int(arr.size)
        payload = n if self.bits == 8 else int(np.unique(arr // 2).size)
        scales = int(np.unique(arr // self.block).size) * 4
        return payload + scales


@dataclasses.dataclass(frozen=True)
class Int4SplitHalfAccessor(QuantizedAccessor):
    """int4 storage packed SPLIT-HALF per fixed-width row (the KV-page order).

    ``QuantizedAccessor`` at 4 bits packs ADJACENT offset pairs into a byte;
    quantized KV pages pack each width-``row`` span (a token's head vector)
    with byte ``b`` holding element ``b`` in the lo nibble and element
    ``b + row/2`` in the hi nibble (kernels/paged_attention.py:
    pack_int4_splithalf — the order that makes in-kernel dequant a lane
    concat). This accessor speaks that byte layout over the flat codomain, so
    ``kvquant.PagedQuantSpec.as_flat_accessor`` can return a real accessor for
    int4 pools too and the CountingAccessor instrumentation path covers all
    three kv dtypes: element offset ``o`` lives at byte
    ``(o // row) * row/2 + (o % row) % (row/2)``, hi nibble iff
    ``o % row >= row/2``. The scale algebra is untouched (inherited block
    scales; ``block`` must cover whole rows).
    """

    row: int = 2  # split-half span width; head_dim for KV pages

    def __post_init__(self):
        if self.bits != 4:
            raise ValueError("Int4SplitHalfAccessor is the 4-bit packing")
        if self.row % 2:
            raise ValueError("split-half packing needs an even row width")
        if self.block % self.row:
            raise ValueError(
                f"block {self.block} must cover whole rows of {self.row} "
                "(a block scale may not split a packed row)"
            )

    def _byte_and_hi(self, i):
        half = self.row // 2
        d = jnp.asarray(i) % self.row
        return (jnp.asarray(i) // self.row) * half + d % half, d >= half

    def alloc(self, span_size: int):
        if span_size % self.row:
            raise ValueError("span must be a whole number of rows")
        nb = self._nblocks(span_size)
        return {
            "q": jnp.zeros((span_size // 2,), dtype=jnp.int8),
            "scale": jnp.ones((nb,), dtype=jnp.float32),
        }

    def from_codomain(self, dense):
        dense = jnp.asarray(dense, dtype=jnp.float32)
        span = dense.shape[0]
        if span % self.row:
            raise ValueError("span must be a whole number of rows")
        nb = self._nblocks(span)
        blocked = dense.reshape(nb, self.block)
        absmax = jnp.max(jnp.abs(blocked), axis=1)
        scale = jnp.where(absmax > 0, absmax / self.qmax, 1.0).astype(jnp.float32)
        q = jnp.clip(
            jnp.round(blocked / scale[:, None]), -self.qmax, self.qmax
        ).astype(jnp.int8)
        rows = q.reshape(-1, self.row)
        half = self.row // 2
        packed = ((rows[:, :half] & 0x0F) | ((rows[:, half:] & 0x0F) << 4))
        return {"q": packed.astype(jnp.int8).reshape(-1), "scale": scale}

    def _load_q(self, buffers, i):
        self._check_offset(i)
        byte_idx, hi = self._byte_and_hi(i)
        byte = buffers["q"][byte_idx]
        nib = jnp.where(hi, (byte >> 4) & 0x0F, byte & 0x0F)
        return jnp.where(nib >= 8, nib - 16, nib).astype(jnp.int8)

    def store(self, buffers, i, value):
        self._check_offset(i)
        s = buffers["scale"][jnp.asarray(i) // self.block]
        q = jnp.clip(
            jnp.round(jnp.asarray(value, jnp.float32) / s), -self.qmax, self.qmax
        ).astype(jnp.int8)
        byte_idx, hi = self._byte_and_hi(i)
        old = buffers["q"][byte_idx]
        qn = (q & 0x0F).astype(jnp.int8)
        new = jnp.where(
            hi, (old & 0x0F) | (qn << 4), (old & ~0x0F) | qn
        ).astype(jnp.int8)
        return {**buffers, "q": buffers["q"].at[byte_idx].set(new)}

    # offset(): inherited — block-aligned i is row-aligned (block % row == 0),
    # and a row-aligned element offset's byte is exactly i // 2 because rows
    # pack contiguously at row/2 bytes each.

    def bytes_for_offsets(self, i) -> int:
        """Distinct PACKED bytes touched (split-half indexing) + one f32 scale
        per distinct block — same pricing law as the adjacent-pair int4, but
        byte identity follows this accessor's own layout."""
        self._check_offset(i)
        arr = np.asarray(i)
        half = self.row // 2
        byte = (arr // self.row) * half + (arr % self.row) % half
        payload = int(np.unique(byte).size)
        scales = int(np.unique(arr // self.block).size) * 4
        return payload + scales


class MemorySpace(enum.Enum):
    """Strong memory-space types (paper: strong pointer types for heterogeneous
    memory). ANY/HBM/VMEM/SMEM map to Pallas memory spaces; HOST maps to
    ``memory_kind='pinned_host'`` shardings (optimizer-state offload)."""

    ANY = "any"
    HBM = "hbm"
    VMEM = "vmem"
    SMEM = "smem"
    HOST = "host"


@dataclasses.dataclass(frozen=True)
class MemorySpaceAccessor(BasicAccessor):
    """BasicAccessor + a strong space tag. Mixing spaces is a trace-time error in
    algorithms that require same-space operands — the strong-typing safety argument
    of the paper, enforced by ``require_same_space``."""

    space: MemorySpace = MemorySpace.ANY

    @property
    def offset_policy(self) -> "Accessor":
        # Offsetting can break alignment guarantees tied to a space (paper's
        # over-aligned pointer example): rebased views decay to ANY.
        if self.space == MemorySpace.VMEM:
            return MemorySpaceAccessor(self.element_type, MemorySpace.ANY)
        return self


def require_same_space(*accessors: Accessor) -> None:
    spaces = {
        a.space for a in accessors if isinstance(a, MemorySpaceAccessor)
    } - {MemorySpace.ANY}
    if len(spaces) > 1:
        raise TypeError(f"operands live in incompatible memory spaces: {spaces}")


# -- accessors as memory spaces (the hierarchical-KV customization point) --------
#
# The paper's accessor policy is explicitly the hook for HETEROGENEOUS MEMORY:
# one view type spans HBM, host RAM, and beyond without the layout or the
# algorithm changing, because only the accessor resolves an offset to storage
# (PAPER §IV — "strong pointer types for heterogeneous memory", the same
# argument MemorySpaceAccessor makes for single-space tagging). HostTierAccessor
# makes the MULTI-space case concrete: it wraps ANY element accessor (f32 /
# int8 / int4 pages keep their representation in either space — the inner
# policy is untouched) and routes each offset to an HBM or a host buffer set by
# PAGE residency. The page granularity matches LayoutPaged's codomain: every
# offset inside one physical page's ``page_elems``-sized range lives in one
# space, so ``space_for_offset`` is a total map and migration is invisible to
# the layout — exactly the property the serving tier (serving/engine/cache.py
# TierManager) exploits when it demotes cold pages to host RAM and promotes
# them back: the block table keeps its page ids, only the residency set (and
# the bytes) move. LayoutPaged.space_for / space_for_offset report the same
# classification from the layout side, so index -> (space, page, slot) is
# answerable from either policy axis.


@dataclasses.dataclass(frozen=True)
class HostTierAccessor(Accessor):
    """Two-space accessor: ``inner`` applied over {"hbm": ..., "host": ...}
    buffer sets, with each offset routed by the page residency set.

    ``page_elems`` is the codomain extent of one physical page
    (n_heads * page_size * d for KV pools); ``host_pages`` names the page ids
    whose storage currently lives in the host tier. Both buffer sets are full
    inner-accessor buffers over the SAME span, so a page's bytes keep their
    representation (including quantization scales) wherever they live, and
    migration is a pure content copy plus a residency-set update — no offset
    changes, no re-encoding."""

    inner: Accessor = dataclasses.field(default_factory=lambda: BasicAccessor())
    page_elems: int = 1
    host_pages: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.page_elems <= 0:
            raise ValueError("page_elems must be positive")
        object.__setattr__(
            self, "host_pages", tuple(sorted({int(p) for p in self.host_pages}))
        )

    @property
    def element_type(self):
        return self.inner.element_type

    def storage_dtype(self):
        return self.inner.storage_dtype()

    def space_for_offset(self, i) -> MemorySpace:
        """The memory space holding offset ``i`` — total over the span."""
        page = int(np.asarray(i)) // self.page_elems
        return (
            MemorySpace.HOST if page in set(self.host_pages) else MemorySpace.HBM
        )

    def _route(self, i):
        pages = jnp.asarray(i) // self.page_elems
        if not self.host_pages:
            return jnp.zeros_like(pages, dtype=bool)
        host = jnp.asarray(np.asarray(self.host_pages, np.int64))
        return jnp.isin(pages, host)

    def alloc(self, span_size: int):
        return {
            "hbm": self.inner.alloc(span_size),
            "host": self.inner.alloc(span_size),
        }

    def from_codomain(self, dense):
        """Encode into the HBM set; the host set starts cold (zeroed)."""
        dense = jnp.asarray(dense)
        return {
            "hbm": self.inner.from_codomain(dense),
            "host": self.inner.alloc(int(dense.shape[0])),
        }

    def access(self, buffers, i):
        in_host = self._route(i)
        hbm = self.inner.access(buffers["hbm"], i)
        host = self.inner.access(buffers["host"], i)
        return jnp.where(in_host, host, hbm)

    def store(self, buffers, i, value):
        """Route each store to the space holding its page. Mixed batches write
        both sets with the complementary halves masked to their old values —
        the functional-update analogue of two partial scatters."""
        in_host = self._route(i)
        old_h = self.inner.access(buffers["host"], i)
        old_b = self.inner.access(buffers["hbm"], i)
        value = jnp.asarray(value)
        return {
            "hbm": self.inner.store(
                buffers["hbm"], i, jnp.where(in_host, old_b, value)
            ),
            "host": self.inner.store(
                buffers["host"], i, jnp.where(in_host, value, old_h)
            ),
        }

    def decay(self, buffers):
        """Flatten to one plain codomain: each page read from its residency."""
        hbm = self.inner.decay(buffers["hbm"])
        host = self.inner.decay(buffers["host"])
        idx = jnp.arange(hbm.shape[0])
        return jnp.where(self._route(idx), host, hbm)

    def bytes_for_offsets(self, i) -> int:
        return self.inner.bytes_for_offsets(i)

    def migrate(self, buffers, page: int, to: MemorySpace):
        """Move one page's content between spaces: copy its ``page_elems``
        offsets through the inner accessor, return (buffers, accessor) with the
        residency set updated. The offsets never change — only which buffer set
        answers them (the block-table-invariance law the serving tier relies
        on)."""
        here = self.space_for_offset(page * self.page_elems)
        if to == here:
            return buffers, self
        src, dst = ("host", "hbm") if to == MemorySpace.HBM else ("hbm", "host")
        offs = jnp.arange(page * self.page_elems, (page + 1) * self.page_elems)
        vals = self.inner.access(buffers[src], offs)
        buffers = {**buffers, dst: self.inner.store(buffers[dst], offs, vals)}
        pages = set(self.host_pages)
        (pages.discard if to == MemorySpace.HBM else pages.add)(page)
        return buffers, dataclasses.replace(self, host_pages=tuple(sorted(pages)))
