"""MdSpan: a non-owning multi-dimensional view = buffers × layout × accessor.

The JAX restatement of ``std::basic_mdspan<T, Extents, Layout, Accessor>``:

  * ``buffers``  — pytree of jax Arrays (the "pointer"; main storage + accessor
                   auxiliaries such as quantization scales). Non-owning in the JAX
                   sense: an MdSpan is index arithmetic over buffers whose lifetime
                   the runtime manages, exactly as C++ mdspan defers ownership.
  * ``layout``   — LayoutMapping: multi-index → codomain offset (trace-time object).
  * ``accessor`` — Accessor: (buffers, offset) → value / functional store.

MdSpan is a registered pytree: it passes through jit/grad/vmap/scan transparently,
with layout+accessor as static aux data — the moral equivalent of them living in the
C++ *type*. Two MdSpans with different layouts are different "types" to the tracer
and produce independently-specialized compilations, mirroring template instantiation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .accessors import Accessor, BasicAccessor
from .extents import Extents
from .layouts import LayoutMapping, LayoutRight, LayoutError


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MdSpan:
    buffers: Any
    layout: LayoutMapping
    accessor: Accessor

    # -- pytree ------------------------------------------------------------------
    def tree_flatten(self):
        return (self.buffers,), (self.layout, self.accessor)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, accessor = aux
        return cls(children[0], layout, accessor)

    # -- observers ----------------------------------------------------------------
    @property
    def extents(self) -> Extents:
        return self.layout.extents

    @property
    def rank(self) -> int:
        return self.extents.rank

    def extent(self, r: int) -> int:
        return self.extents.extent(r)

    @property
    def element_type(self):
        return self.accessor.element_type

    @property
    def shape(self):
        return self.extents.as_shape()

    def size(self) -> int:
        return self.extents.size()

    def is_unique(self) -> bool:
        return self.layout.is_unique()

    def is_contiguous(self) -> bool:
        return self.layout.is_contiguous()

    def is_strided(self) -> bool:
        return self.layout.is_strided()

    def stride(self, r: int) -> int:
        return self.layout.stride(r)

    # -- element access (the paper's operator()) -----------------------------------
    def __call__(self, *idx):
        return self.accessor.access(self.buffers, self.layout(*idx))

    def __getitem__(self, idx):
        if not isinstance(idx, tuple):
            idx = (idx,)
        return self(*idx)

    def get(self, *idx):
        return self(*idx)

    def set(self, idx, value) -> "MdSpan":
        """Functional store: returns a new MdSpan over updated buffers."""
        if not isinstance(idx, tuple):
            idx = (idx,)
        new_buffers = self.accessor.store(self.buffers, self.layout(*idx), value)
        return MdSpan(new_buffers, self.layout, self.accessor)

    # -- whole-view conversion -------------------------------------------------------
    def to_dense(self):
        """Materialize the logical array (shape = extents).

        Zero-overhead fast paths (the paper's compile-away requirement): identity
        layouts become reshapes, column-major becomes a transpose — XLA folds both
        into layout assignment, so the view costs nothing. Generic layouts fall
        back to one gather.
        """
        from .accessors import BasicAccessor as _BA
        from .layouts import LayoutLeft as _LL, LayoutRight as _LR, LayoutStride as _LS
        from .layouts import _row_major_strides

        if isinstance(self.accessor, _BA):
            if isinstance(self.layout, _LR):
                return self.buffers.reshape(self.shape)
            if isinstance(self.layout, _LL):
                return self.buffers.reshape(self.shape[::-1]).transpose(
                    tuple(range(self.rank - 1, -1, -1))
                )
            if isinstance(self.layout, _LS) and self.layout.strides == _row_major_strides(
                self.extents.sizes
            ):
                # contiguous row-major sub-block (every `all`-suffixed submdspan):
                # a slice + reshape — no gather, the view costs nothing
                off = self.layout.offset
                return jax.lax.slice(
                    self.buffers, (off,), (off + self.extents.size(),)
                ).reshape(self.shape)
        offs = self.layout.offsets_dense()
        vals = self.accessor.access(self.buffers, offs.reshape(-1))
        return vals.reshape(self.shape)

    def scatter_from_dense(self, dense) -> "MdSpan":
        """Functional whole-domain store. Requires a unique layout (trace-time check
        — the paper's compile-time gating) unless the accessor accumulates."""
        from .accessors import AccumulateAccessor

        if not self.layout.is_unique() and not isinstance(self.accessor, AccumulateAccessor):
            raise LayoutError(
                "whole-domain overwrite of a non-unique layout is ill-defined; "
                "use an AccumulateAccessor or a unique layout"
            )
        offs = self.layout.offsets_dense().reshape(-1)
        new_buffers = self.accessor.store(
            self.buffers, offs, jnp.asarray(dense).reshape(-1)
        )
        return MdSpan(new_buffers, self.layout, self.accessor)

    def codomain(self):
        """The flat codomain as a plain array (decayed pointer)."""
        return self.accessor.decay(self.buffers)

    def with_buffers(self, buffers) -> "MdSpan":
        return MdSpan(buffers, self.layout, self.accessor)

    # -- constructors --------------------------------------------------------------
    @staticmethod
    def from_dense(
        dense,
        layout: LayoutMapping | None = None,
        accessor: Accessor | None = None,
        static: bool = False,
    ) -> "MdSpan":
        """Encode a dense logical array into an MdSpan with the given layout/accessor.

        ``static=True`` marks every extent static (trace-time specializable).
        """
        dense = jnp.asarray(dense)
        ext = (
            Extents.fully_static(*dense.shape)
            if static
            else Extents.fully_dynamic(*dense.shape)
        )
        layout = layout if layout is not None else LayoutRight(ext)
        accessor = accessor if accessor is not None else BasicAccessor(dense.dtype)
        if layout.extents.as_shape() != dense.shape:
            raise TypeError(
                f"layout extents {layout.extents} do not match array shape {dense.shape}"
            )
        from .layouts import LayoutLeft as _LL, LayoutRight as _LR

        # zero-overhead encode paths: identity layouts never scatter
        if isinstance(layout, _LR):
            codomain = dense.reshape(-1)
        elif isinstance(layout, _LL):
            codomain = dense.transpose(tuple(range(dense.ndim - 1, -1, -1))).reshape(-1)
        else:
            span = layout.required_span_size()
            offs = layout.offsets_dense().reshape(-1)
            codomain = jnp.zeros((span,), dtype=dense.dtype)
            # Non-unique layouts: later writes win (C++: UB; we pick determinism).
            codomain = codomain.at[offs].set(dense.reshape(-1).astype(dense.dtype))
        buffers = accessor.from_codomain(codomain)
        return MdSpan(buffers, layout, accessor)

    @staticmethod
    def over(buffer, layout: LayoutMapping, accessor: Accessor | None = None) -> "MdSpan":
        """View EXISTING storage (the paper's primary use: interpret memory)."""
        accessor = accessor if accessor is not None else BasicAccessor(
            buffer.dtype if hasattr(buffer, "dtype") else jnp.float32
        )
        return MdSpan(buffer, layout, accessor)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"MdSpan(extents={self.extents}, layout={type(self.layout).__name__}, "
            f"accessor={type(self.accessor).__name__})"
        )


def mdspan(data, *extent_spec, accessor: Accessor | None = None) -> MdSpan:
    """The convenience alias mirroring ``std::mdspan<T, E0, E1, ...>(ptr, dyn...)``:
    interpret a flat buffer as a multi-dimensional entity.

    >>> m = mdspan(buf, 20, dynamic_extent, dyn_sizes=(40,))   # C++ example 1
    """
    from .extents import _DynamicExtent

    statics = [e for e in extent_spec if not isinstance(e, _DynamicExtent)]
    dynamic_needed = sum(isinstance(e, _DynamicExtent) for e in extent_spec)
    del statics
    data = jnp.asarray(data)
    if dynamic_needed:
        raise TypeError(
            "pass dynamic sizes by constructing Extents explicitly: "
            "MdSpan.over(buf, LayoutRight(Extents.of(...)(sizes)))"
        )
    ext = Extents.make(extent_spec)
    if ext.size() > data.size:
        raise ValueError(f"buffer of {data.size} elements too small for {ext}")
    acc = accessor if accessor is not None else BasicAccessor(data.dtype)
    return MdSpan(data.reshape(-1), LayoutRight(ext), acc)
