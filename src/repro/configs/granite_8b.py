"""granite-8b — llama-architecture code model. [arXiv:2405.04324]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, vocab=49152,
        n_heads=32, n_kv_heads=8, d_ff=14336,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=128,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=10000.0,
    )
