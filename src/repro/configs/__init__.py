"""Assigned architecture configs (one module per arch) + input-shape definitions."""
from .shapes import SHAPES, Shape, applicable_shapes, cell_is_applicable
