"""qwen2-0.5b — GQA with QKV bias. [arXiv:2407.10671]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, vocab=151936,
        n_heads=14, n_kv_heads=2, d_ff=4864,
        qkv_bias=True, mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True, rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=128,
        qkv_bias=True, mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True, rope_theta=1000000.0,
    )
