"""recurrentgemma-2b — RG-LRU + local attention, 1:2 pattern (26 layers =
(rec,rec,local_attn) x 8 + rec x 2). MQA (kv=1), head_dim 256, GeGLU MLP.
[arXiv:2402.19427]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, vocab=256000,
        n_heads=10, n_kv_heads=1, d_head=256, d_ff=7680,
        pattern=("rec", "rec", "local_attn"), lru_width=2560, window=2048,
        conv_kernel=4,
        mlp_act="geglu", norm="rmsnorm", tie_embeddings=True, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rg-smoke", family="hybrid",
        n_layers=5, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=1, d_head=16, d_ff=128,
        pattern=("rec", "rec", "local_attn"), lru_width=64, window=8,
        conv_kernel=4,
        mlp_act="geglu", norm="rmsnorm", tie_embeddings=True, rope_theta=10000.0,
    )
