"""The assigned input-shape set. Every (arch x shape) pair is one dry-run cell.

train_*   lowers train_step (fwd+bwd+optimizer update)
prefill_* lowers the prefill forward (logits + populated caches)
decode_*  / long_* lower serve_step (one new token against a seq_len KV cache)

long_500k requires sub-quadratic attention: runs for ssm/hybrid families only
(full-attention archs are skipped — see DESIGN.md SS6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def cell_is_applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.is_subquadratic()
    return True


def applicable_shapes(cfg):
    return [s for n, s in SHAPES.items() if cell_is_applicable(cfg, n)]
