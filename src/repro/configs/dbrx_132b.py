"""dbrx-132b — 16-expert top-4 fine-grained MoE. [hf:databricks/dbrx-base]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, vocab=100352,
        n_heads=48, n_kv_heads=8, d_ff=10752,
        n_experts=16, top_k=4,
        mlp_act="swiglu", norm="layernorm", rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=96,
        n_experts=4, top_k=2,
        mlp_act="swiglu", norm="layernorm", rope_theta=500000.0,
    )
