"""llama3.2-1b — small llama3 (head_dim 64). [hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense",
        n_layers=16, d_model=2048, vocab=128256,
        n_heads=32, n_kv_heads=8, d_ff=8192,
        mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True, rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke", family="dense",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=128,
        mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True, rope_theta=500000.0,
    )
