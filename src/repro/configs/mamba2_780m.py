"""mamba2-780m — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, vocab=50280,
        d_ff=0, n_heads=0, n_kv_heads=0,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        ssm_chunk=128, conv_kernel=4,
        norm="rmsnorm", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        d_ff=0, n_heads=0, n_kv_heads=0,
        ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_ngroups=1,
        ssm_chunk=8, conv_kernel=4,
        norm="rmsnorm", tie_embeddings=True,
    )
