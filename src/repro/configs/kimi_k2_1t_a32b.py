"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8, fine-grained
(d_ff=2048 per expert). [arXiv:2501.kimi2 per assignment table]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, vocab=163840,
        n_heads=64, n_kv_heads=8, d_head=112, d_ff=2048,
        n_experts=384, top_k=8,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=50000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke", family="moe",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=32,
        n_experts=8, top_k=2,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=50000.0,
    )
