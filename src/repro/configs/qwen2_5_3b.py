"""qwen2.5-3b — GQA with QKV bias. [hf:Qwen/Qwen2.5-3B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, vocab=151936,
        n_heads=16, n_kv_heads=2, d_ff=11008,
        qkv_bias=True, mlp_act="swiglu", norm="rmsnorm", rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family="dense",
        n_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=128,
        qkv_bias=True, mlp_act="swiglu", norm="rmsnorm", rope_theta=1000000.0,
    )
