"""whisper-large-v3 — enc-dec audio backbone; conv frontend STUBBED: input_specs()
feeds precomputed 1500-frame embeddings. [arXiv:2212.04356]

Deviations noted in DESIGN.md: RoPE replaces whisper's learned positional
embeddings (the assigned 32k decoder shapes exceed whisper's 448-position table).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, n_enc_layers=32, d_model=1280, vocab=51866,
        n_heads=20, n_kv_heads=20, d_ff=5120,
        mlp_act="gelu", norm="layernorm",
        enc_seq=1500, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=4, d_ff=128,
        mlp_act="gelu", norm="layernorm",
        enc_seq=12, rope_theta=10000.0,
    )
