"""llama-3.2-vision-90b — text backbone with gated cross-attention image layers
(every 5th layer); patch-embedding frontend STUBBED: input_specs() feeds
precomputed image-token embeddings. [hf:meta-llama/Llama-3.2-90B-Vision]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, vocab=128256,
        n_heads=64, n_kv_heads=8, d_ff=28672,
        cross_every=5, n_img_tokens=6404,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="vision-smoke", family="vlm",
        n_layers=5, d_model=64, vocab=512, vocab_pad_to=128,
        n_heads=4, n_kv_heads=2, d_ff=128,
        cross_every=5, n_img_tokens=8,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=500000.0,
    )
