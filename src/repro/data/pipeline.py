"""Deterministic, shardable data pipeline.

Two sources:
  SyntheticLM        — seeded Markov-ish token stream (no I/O; used by tests,
                       examples and the e2e training run). Deterministic in
                       (seed, step, host) so restarts resume bit-identically and
                       every data-parallel host draws a disjoint slice.
  BinaryTokenDataset — packed uint16/uint32 token files (memory-mapped), sequence-
                       chunked, host-sharded. The "real data" path.

Both yield global batches as host-local numpy (per-host slice) plus the
make_array_from_process_local_data plumbing for multi-host; on single-process
CPU they just return the full batch.

Prefetching: a one-slot double buffer on a background thread (keeps the host busy
while the device runs the step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | binary
    path: Optional[str] = None
    dtype: str = "uint16"


class SyntheticLM:
    """Deterministic pseudo-natural token stream.

    Tokens follow a power-law unigram mixed with a shift-register "grammar" so a
    model can actually reduce loss (tests assert learning works). Batch at step t
    on host h depends only on (seed, t, h).
    """

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.batch // num_hosts
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks**1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id])
        )
        b, s = self.local_batch, self.cfg.seq
        base = rng.choice(self.cfg.vocab, size=(b, s + 1), p=self._probs)
        # inject learnable structure: token[t] == token[t-3] with prob .5
        copy_mask = rng.random((b, s + 1)) < 0.5
        for t in range(3, s + 1):
            base[:, t] = np.where(copy_mask[:, t], base[:, t - 3], base[:, t])
        return {"tokens": base.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class BinaryTokenDataset:
    """Memory-mapped packed token file → (batch, seq+1) windows, host-sharded."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.path is not None
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.batch // num_hosts
        self.tokens = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype), mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id])
        )
        idx = rng.integers(0, self.n_windows, size=(self.local_batch,))
        rows = np.stack(
            [self.tokens[i * self.cfg.seq : i * self.cfg.seq + self.cfg.seq + 1] for i in idx]
        )
        return {"tokens": rows.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class _Prefetcher:
    def __init__(self, src, start_step: int = 0, depth: int = 2):
        self.src = src
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.src.batch_at(s)
            self.q.put((s, batch))
            s += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def make_pipeline(cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1,
                  start_step: int = 0, prefetch: bool = True):
    src = (
        SyntheticLM(cfg, host_id, num_hosts)
        if cfg.source == "synthetic"
        else BinaryTokenDataset(cfg, host_id, num_hosts)
    )
    if prefetch:
        return _Prefetcher(src, start_step=start_step)
    def gen():
        s = start_step
        while True:
            yield s, src.batch_at(s)
            s += 1
    return gen()
