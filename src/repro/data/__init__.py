from .pipeline import BinaryTokenDataset, DataConfig, SyntheticLM, make_pipeline

__all__ = ["BinaryTokenDataset", "DataConfig", "SyntheticLM", "make_pipeline"]
