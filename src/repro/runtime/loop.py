"""TrainerLoop: the production run loop — checkpoint/restart, auto-resume,
heartbeat + straggler hooks, and ELASTIC re-meshing after device loss.

Flow per run():
  mesh → rules → model → jit(train_step) → [restore latest ckpt] →
  step loop { data, step, health, ckpt } → on failure: shrink mesh, restore, go on.

Elasticity model: the global batch is invariant; device loss rebuilds the mesh
over the surviving devices (data axis shrinks), re-jits against the new
shardings, and reshard-on-load restores the last committed checkpoint. This is
exactly the multi-host story (coordinator re-forms the job) executed over the
local device pool, and is driven end-to-end by tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.distributed import tree_initialize, tree_shape_structs
from repro.data import DataConfig, make_pipeline
from repro.launch.sharding import train_rules
from repro.models import build_model, get_config
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainProfile, make_train_step

from .health import HeartbeatMonitor, StragglerPolicy


@dataclasses.dataclass
class RunConfig:
    arch: str = "llama3.2-1b"
    smoke: bool = True
    steps: int = 100
    batch: int = 8
    seq: int = 64
    peak_lr: float = 1e-3
    warmup: int = 20
    ckpt_dir: str = "checkpoints/run"
    ckpt_every: int = 25
    log_every: int = 10
    model_axis: int = 1
    seed: int = 0
    num_microbatches: int = 1
    int8_opt: bool = False
    resume: bool = True


class TrainerLoop:
    def __init__(self, run: RunConfig, devices: Optional[List] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.run = run
        self.devices = devices if devices is not None else list(jax.devices())
        self.failure_hook = failure_hook
        self.cfg = get_config(run.arch, smoke=run.smoke)
        self.model = build_model(self.cfg)
        self.ckpt = CheckpointManager(run.ckpt_dir, keep=3)
        self.history: List[Dict[str, float]] = []
        self.straggler = StragglerPolicy()
        self._build(self.devices)

    # ------------------------------------------------------------------
    def _build(self, devices: List):
        """(Re)build mesh + jitted step for the given device set."""
        n = len(devices)
        model_axis = self.run.model_axis
        assert n % model_axis == 0
        dp = n // model_axis
        assert self.run.batch % dp == 0, (self.run.batch, dp)
        dev_grid = np.array(devices).reshape(dp, model_axis)
        self.mesh = jax.sharding.Mesh(dev_grid, ("data", "model"))
        self.rules = train_rules(self.cfg)
        opt = AdamWConfig(
            lr=warmup_cosine(self.run.peak_lr, self.run.warmup, self.run.steps),
            int8_state=self.run.int8_opt,
        )
        profile = TrainProfile(num_microbatches=self.run.num_microbatches)
        step_fn, self.param_specs, self.state_specs = make_train_step(
            self.model, opt, profile, mesh=self.mesh, rules=self.rules
        )
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.monitor = HeartbeatMonitor(num_hosts=dp, timeout_s=300)

    def _init_state(self):
        params = tree_initialize(self.param_specs, jax.random.key(self.run.seed))
        opt_state = tree_initialize(self.state_specs, jax.random.key(self.run.seed + 1))
        return self._place(params), self._place(opt_state)

    def _place(self, tree):
        from repro.core.distributed import tree_shardings

        sh = None
        try:
            sh = {
                "params": tree_shardings(self.param_specs, self.mesh, self.rules),
                "state": tree_shardings(self.state_specs, self.mesh, self.rules),
            }
        except Exception:
            pass
        return jax.device_put(tree) if sh is None else tree

    def _targets(self):
        params_t = tree_shape_structs(self.param_specs, self.mesh, self.rules)
        state_t = tree_shape_structs(self.state_specs, self.mesh, self.rules)
        return {"params": params_t, "opt": state_t}

    # ------------------------------------------------------------------
    def run_loop(self) -> Dict[str, Any]:
        r = self.run
        data_cfg = DataConfig(batch=r.batch, seq=r.seq, vocab=self.cfg.vocab, seed=r.seed)
        start = 0
        params = opt_state = None
        if r.resume and self.ckpt.latest() is not None:
            start = self.ckpt.latest()
            tgt = self._targets()
            restored = self.ckpt.restore(start, tgt)
            params, opt_state = restored["params"], restored["opt"]
            print(f"[loop] resumed from step {start}")
        if params is None:
            params, opt_state = self._init_state()

        pipeline = make_pipeline(data_cfg, start_step=start, prefetch=False)
        step = start
        for step, batch in pipeline:
            if step >= r.steps:
                break
            t0 = time.monotonic()
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
            except Exception as e:
                print(f"[loop] step {step} failed ({e}); elastic restart")
                params, opt_state, start = self._elastic_restart()
                pipeline = make_pipeline(data_cfg, start_step=start, prefetch=False)
                continue
            dt = time.monotonic() - t0
            for h in range(self.monitor.num_hosts):
                self.monitor.beat(h)
            verdict = self.straggler.observe(dt)
            if verdict == "rebalance":
                print(f"[loop] persistent straggler at step {step}; would re-mesh")
            self.history.append({"step": step, "loss": loss, "time_s": dt})
            if step % r.log_every == 0:
                print(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if step > 0 and step % r.ckpt_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        self.ckpt.save(min(step + 1, r.steps), {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return {"history": self.history, "final_step": min(step + 1, r.steps)}

    # ------------------------------------------------------------------
    def _elastic_restart(self):
        """Drop the failed device(s), rebuild mesh/step, restore latest ckpt."""
        self.failure_hook = None  # the failed node is gone, not failing again
        survivors = self._surviving_devices()
        print(f"[loop] re-meshing onto {len(survivors)} devices")
        self._build(survivors)
        latest = self.ckpt.latest()
        if latest is None:
            params, opt_state = self._init_state()
            return params, opt_state, 0
        tgt = self._targets()
        restored = self.ckpt.restore(latest, tgt)
        return restored["params"], restored["opt"], latest

    def _surviving_devices(self) -> List:
        n = len(self.devices)
        # shrink the data axis by one full model-axis row (a "node")
        keep = n - self.run.model_axis
        dp_new = keep // self.run.model_axis
        while dp_new > 0 and self.run.batch % dp_new != 0:
            keep -= self.run.model_axis
            dp_new = keep // self.run.model_axis
        assert keep >= self.run.model_axis, "no viable surviving mesh"
        self.devices = self.devices[:keep]
        return self.devices
