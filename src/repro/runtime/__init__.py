from .loop import RunConfig, TrainerLoop
from .health import HeartbeatMonitor, StragglerPolicy, simulate_failure

__all__ = [
    "RunConfig",
    "TrainerLoop",
    "HeartbeatMonitor",
    "StragglerPolicy",
    "simulate_failure",
]
