"""Fault-tolerance primitives: heartbeats, straggler detection, failure injection.

On a real multi-host cluster these hooks wrap jax.distributed + the coordinator:
each host heartbeats; the coordinator declares a host dead after
``timeout_s`` and the runner re-meshes (ELASTIC path in runtime/loop.py). In this
single-process container the same state machine runs with simulated reports —
tests/test_runtime.py drives node-loss and straggler scenarios through it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host liveness; declares failure after ``timeout_s`` silence."""

    num_hosts: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_seen: Dict[int, float] = {h: now for h in range(self.num_hosts)}

    def beat(self, host: int, at: Optional[float] = None):
        self.last_seen[host] = self.clock() if at is None else at

    def dead_hosts(self, at: Optional[float] = None) -> List[int]:
        now = self.clock() if at is None else at
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


@dataclasses.dataclass
class StragglerPolicy:
    """Step-time based straggler mitigation.

    Keeps an EMA of step wall-time; a step slower than ``threshold``× the EMA
    marks the step 'straggled'. After ``patience`` consecutive straggles the
    policy recommends action:
      * "rebalance" — reshard/re-mesh excluding the slow host (elastic path)
      * at the data level the runner may also skip the laggard's contribution
        for one step (bounded-staleness gradient, standard straggler trick).
    """

    threshold: float = 2.0
    patience: int = 3
    ema_decay: float = 0.9

    def __post_init__(self):
        self.ema: Optional[float] = None
        self.strikes = 0

    def observe(self, step_time_s: float) -> str:
        if self.ema is None:
            self.ema = step_time_s
            return "ok"
        slow = step_time_s > self.threshold * self.ema
        # EMA tracks only non-outlier steps so one straggler can't poison it
        if not slow:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * step_time_s
            self.strikes = 0
            return "ok"
        self.strikes += 1
        if self.strikes >= self.patience:
            self.strikes = 0
            return "rebalance"
        return "straggle"


class simulate_failure:
    """Context helper for tests: raises the given exception at a chosen step."""

    def __init__(self, at_step: int, exc: Exception | None = None):
        self.at_step = at_step
        self.exc = exc or RuntimeError("simulated node failure")

    def maybe_fail(self, step: int):
        if step == self.at_step:
            raise self.exc
