from .step import TrainProfile, make_train_step

__all__ = ["TrainProfile", "make_train_step"]
