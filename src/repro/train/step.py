"""train_step factory: fwd+bwd (+microbatch gradient accumulation) + AdamW update.

Distributed-optimization features:
  * microbatching — grad accumulation over a lax.scan keeps per-chip activation
    memory ~ 1/k (required for the 90B/1T train cells);
  * configurable accumulation dtype (bf16 for the 1T cell — grads stay sharded
    FSDP-style, halving accumulation memory);
  * per-layer remat with a configurable XLA policy (hillclimb knob: §Perf);
  * compute/comm overlap falls out of XLA latency-hiding once grads are
    reduce-scattered by the FSDP sharding — no manual bucketing needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Sharder
from repro.optim import AdamWConfig, adamw_init_specs, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainProfile:
    num_microbatches: int = 1
    accum_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: Optional[str] = None  # None|"dots"|"nothing"
    aux_weight: float = 0.01


def _policy(name):
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    return None


def make_train_step(model, opt: AdamWConfig, profile: TrainProfile = TrainProfile(),
                    mesh=None, rules=None):
    """Returns (train_step, param_specs, opt_state_specs).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    shard = Sharder(mesh, rules)
    param_specs = model.param_specs()
    state_specs = adamw_init_specs(param_specs, opt)

    def loss_fn(params, batch):
        return model.loss_fn(
            params, batch, shard=shard, remat=profile.remat,
            remat_policy=_policy(profile.remat_policy), aux_weight=profile.aux_weight,
        )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        k = profile.num_microbatches
        if k <= 1:
            (l, aux), grads = grad_fn(params, batch)
            return l, grads

        def split(x):
            return x.reshape(k, x.shape[0] // k, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            g_acc, l_acc = carry
            (l, aux), g = grad_fn(params, mbatch)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(profile.accum_dtype), g_acc, g
            )
            return (g_acc, l_acc + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, profile.accum_dtype), params)
        (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / k), g_sum)
        return l_sum / k, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, param_specs, state_specs, opt
        )
        return params, opt_state, {"loss": loss, **om}

    return train_step, param_specs, state_specs
