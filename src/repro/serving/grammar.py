"""Token-level grammars for constrained decoding (the host-side compiler).

Constrained decoding is a LOGIT-MASK stage fused into the on-device sampler
(kernels/ops.sample_tokens ``mask=``): the host precomputes, once per grammar,
one additive mask row per automaton state (0 = allowed, ``MASK_OFF`` =
disallowed) plus an int32 transition table, uploads both as fixed-shape device
arrays, and the fused serve step gathers the per-slot rows and advances the
per-slot state with the token it just sampled — entirely on device. The decode
loop's zero-D2H property survives: the only recurring transfer stays the
sampled ids, and the grammar state rides the fused lax.scan carry like the
lengths do (serving/step.py).

A grammar here is a ``TokenDFA`` — a deterministic automaton over TOKEN IDS.
That is deliberately the lowest-level representation: anything that compiles
to "which tokens may follow, given a state" (JSON schemas, regexes, choice
lists) can target it, and the engine only ever sees the two tables. Every
state must allow at least one token (a stuck automaton would mask the whole
vocabulary); termination is expressed in-band by accepting states that allow
ONLY the eos token, so a grammar-complete sequence finishes through the
ordinary per-branch EOS path (finish_reason == "eos").

``json_array_dfa`` / ``fixed_json_array_dfa`` are the reference grammars the
tests and bench drive: JSON arrays of (single-digit-safe) integers over a
caller-supplied char->token map. They exist to pin the end-to-end law —
every constrained output parses — not to be a production JSON compiler.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

# additive logit penalty for disallowed tokens: large and finite (a -inf mask
# could meet a -inf pad column and make softmax arithmetic produce NaNs; at
# -1e30 the token simply never wins an argmax or survives a softmax)
MASK_OFF = -1.0e30


class TokenDFA:
    """A deterministic finite automaton over token ids.

    ``transitions`` is one dict per state mapping allowed token id -> next
    state; a token absent from the dict is DISALLOWED in that state. State 0 is
    the initial state. ``vocab`` bounds the token alphabet (ids must be < vocab
    — the model's true vocabulary, before any padding).
    """

    def __init__(self, vocab: int, transitions: Sequence[Dict[int, int]]):
        n_states = len(transitions)
        if n_states < 1:
            raise ValueError("a TokenDFA needs at least one state")
        if vocab < 1:
            raise ValueError(f"vocab must be >= 1, got {vocab}")
        self.vocab = int(vocab)
        self.n_states = n_states
        # mask rows (S, vocab) f32 and transition table (S, vocab) i32; the
        # transition of a disallowed token is a self-loop (never taken — the
        # mask keeps the sampler from ever choosing it)
        self.mask = np.full((n_states, vocab), MASK_OFF, np.float32)
        self.next_state = np.tile(
            np.arange(n_states, dtype=np.int32)[:, None], (1, vocab)
        )
        for s, row in enumerate(transitions):
            if not row:
                raise ValueError(
                    f"state {s} allows no tokens — it would mask the whole vocab"
                )
            for tok, nxt in row.items():
                if not 0 <= int(tok) < vocab:
                    raise ValueError(f"token {tok} outside vocab [0, {vocab})")
                if not 0 <= int(nxt) < n_states:
                    raise ValueError(
                        f"state {s}: transition on {tok} -> {nxt} outside "
                        f"[0, {n_states})"
                    )
                self.mask[s, int(tok)] = 0.0
                self.next_state[s, int(tok)] = int(nxt)

    def allows(self, state: int, token: int) -> bool:
        return bool(self.mask[state, token] == 0.0)

    def step(self, state: int, token: int) -> int:
        """Host-side transition (mirrors the device gather bit-for-bit)."""
        return int(self.next_state[state, token])

    def state_after(self, tokens: Sequence[int]) -> int:
        """Replay a generated sequence from the initial state — how the engine
        reconstructs a branch's grammar state after preemption-recompute."""
        s = 0
        for t in tokens:
            s = self.step(s, int(t))
        return s

    def valid_prefix(self, tokens: Sequence[int]) -> bool:
        """True when every token was allowed by the state it was emitted from
        — the invariant a masked sampler can never violate."""
        s = 0
        for t in tokens:
            if not self.allows(s, int(t)):
                return False
            s = self.step(s, int(t))
        return True


JSON_ARRAY_CHARS = "[],0123456789"


def json_array_dfa(charmap: Dict[str, int], eos_id: int, vocab: int) -> TokenDFA:
    """Arrays of non-negative integers — ``[]``, ``[7]``, ``[10,0,42]`` — with
    JSON's no-leading-zero number rule. ``charmap`` maps each char of
    ``JSON_ARRAY_CHARS`` to a token id. Unbounded: a sampled walk may run to
    the length cap mid-array (finish_reason "length"); any walk that reaches
    eos parses. States: 0 start, 1 after '[', 2 in a multi-digit number,
    3 after ',', 4 after a lone '0', 5 accept (eos only)."""
    c = {ch: int(charmap[ch]) for ch in JSON_ARRAY_CHARS}
    digits19 = {c[d]: 2 for d in "123456789"}
    t: List[Dict[int, int]] = [
        {c["["]: 1},                                     # 0: start
        {**digits19, c["0"]: 4, c["]"]: 5},              # 1: after '['
        {**{c[d]: 2 for d in "0123456789"},              # 2: in a number
         c[","]: 3, c["]"]: 5},
        {**digits19, c["0"]: 4},                         # 3: after ','
        {c[","]: 3, c["]"]: 5},                          # 4: lone '0'
        {int(eos_id): 5},                                # 5: accept -> eos
    ]
    return TokenDFA(vocab, t)


def fixed_json_array_dfa(charmap: Dict[str, int], eos_id: int, vocab: int,
                         n_items: int = 3) -> TokenDFA:
    """Exactly ``n_items`` single-digit integers — a BOUNDED language, so every
    constrained generation with budget >= 2*n_items + 2 tokens terminates at
    eos and parses. The tests' 100%-valid-JSON law uses this grammar."""
    if n_items < 1:
        raise ValueError("n_items must be >= 1")
    c = {ch: int(charmap[ch]) for ch in JSON_ARRAY_CHARS}
    digits = {c[d] for d in "0123456789"}
    t: List[Dict[int, int]] = [{c["["]: 1}]
    for i in range(n_items):
        after_digit = len(t) + 1
        t.append({d: after_digit for d in digits})       # expect digit i
        if i < n_items - 1:
            t.append({c[","]: after_digit + 1})          # expect ','
        else:
            t.append({c["]"]: after_digit + 1})          # expect ']'
    t.append({int(eos_id): len(t)})                      # accept -> eos
    return TokenDFA(vocab, t)
