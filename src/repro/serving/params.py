"""GenerationParams / Sequence / RequestHandle — the generation API.

One validated record replaces the per-request surface that accreted across
PRs 1-6 (``Request.max_new_tokens``, ``.eos_id``, ``.sampling``, ``.logprobs``)
and carries the parallel-generation axes it was redesigned for:

  - ``n`` > 1: best-of-n parallel sampling. The engine admits N branches as a
    group whose block-table rows FORK the prompt's pages (LayoutPaged.fork_group
    — ~1x prompt KV cost, copy-on-write privatizes on divergence). Branch ``b``
    draws from the stream of ``seed + b``: branch b of an n-branch request is
    token-exact with a serial n=1 request using seed+b and the same rid.
  - ``beam_width`` >= 2: beam search. Deterministic (temperature/top-k/top-p
    must stay at their defaults — validated HERE, at construction, never
    mid-step); each step reorders block-table rows (a pure device-mirror
    permutation when no branch diverges) and hypotheses ending in eos move to
    the finished pool. The best ``n`` hypotheses come back.
  - ``grammar``: constrained decoding (serving/grammar.TokenDFA) as an on-device
    logit-mask stage — see serving/grammar.py.

Results are ``Sequence`` objects — per branch: tokens, logprobs, cumulative
score, and an explicit ``finish_reason`` ("eos" | "length" | "error") replacing
the old implicit hit-max-tokens inference. ``n=1`` callers see a one-element
list. ``submit()`` returns a ``RequestHandle``.

Incompatible combinations fail at ENQUEUE (``GenerationParams.__post_init__``
plus the engine's capacity checks in ``submit``), so a mid-step scheduler never
discovers an impossible request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.grammar import TokenDFA
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class GenerationParams:
    """Everything a client says about HOW to generate (the what — the prompt —
    stays on the Request). Frozen and validated at construction."""

    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # token selection (device-side, serving/sampling.py): temperature 0 =
    # greedy argmax; top_k/top_p filter the sampled distribution; seed names
    # the PRNG stream (branch b of a parallel request uses seed + b)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    # top-k logprobs returned per generated token (<= EngineConfig.logprobs_k)
    logprobs: int = 0
    # parallel generation
    n: int = 1              # sequences to return (sampling: branch count)
    beam_width: int = 0     # 0 = off; >= 2 = beam search width
    grammar: Optional[TokenDFA] = None  # constrained decoding automaton
    # per-request logits recording: None follows EngineConfig.record_logits,
    # True requires it, False opts this request out of an enabled engine
    record_logits: Optional[bool] = None
    # speculative decoding: None follows EngineConfig.spec_tokens, True
    # requires a speculation-enabled engine (submit() checks), False opts this
    # request out — any non-eligible slot in the batch makes the whole step
    # fall back to plain decode (speculation is a batch-wide window)
    speculative: Optional[bool] = None

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.logprobs < 0:
            raise ValueError(f"logprobs must be >= 0, got {self.logprobs}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")
        # SamplingParams re-validates temperature/top_k/top_p ranges
        _ = self.sampling
        if self.beam_width == 1:
            raise ValueError(
                "beam_width=1 is greedy decoding — use n=1, temperature=0"
            )
        if self.beam_width:
            if self.beam_width < 0:
                raise ValueError(f"beam_width must be >= 0, got {self.beam_width}")
            if self.temperature != 0.0 or self.top_k != 0 or self.top_p != 1.0:
                raise ValueError(
                    "beam search is deterministic: temperature/top_k/top_p "
                    "must stay at their defaults with beam_width > 0"
                )
            if self.n > self.beam_width:
                raise ValueError(
                    f"n={self.n} sequences from a beam of {self.beam_width} — "
                    f"n must be <= beam_width"
                )
            if self.grammar is not None:
                raise ValueError(
                    "grammar-constrained beam search is not supported "
                    "(beam candidates come from the unmasked top-k)"
                )
            if self.logprobs:
                raise ValueError(
                    "per-position logprobs are not recorded under beam search "
                    "(hypothesis histories permute across steps); use the "
                    "returned cumulative_logprob"
                )
        elif self.n > 1 and self.temperature == 0.0:
            raise ValueError(
                "n>1 with temperature=0 would generate n identical greedy "
                "branches — set temperature > 0 or use beam_width"
            )
        if self.speculative:
            if self.beam_width:
                raise ValueError(
                    "speculative decoding does not compose with beam search "
                    "(survivor reorders break the event-free window); "
                    "speculative=True cannot force it — beam requests opt "
                    "out automatically under speculative=None"
                )
            if self.grammar is not None:
                raise ValueError(
                    "speculative decoding does not compose with "
                    "grammar-constrained decoding (draft tokens would need "
                    "the automaton advanced per candidate); grammar requests "
                    "opt out automatically under speculative=None"
                )

    @property
    def sampling(self) -> SamplingParams:
        return SamplingParams(
            temperature=self.temperature, top_k=self.top_k, top_p=self.top_p,
            seed=self.seed,
        )

    @property
    def n_branches(self) -> int:
        """Batch slots a request of this shape occupies while running."""
        return self.beam_width if self.beam_width else self.n

    @classmethod
    def from_legacy(cls, max_new_tokens: Optional[int] = None,
                    eos_id: Optional[int] = None,
                    sampling: Optional[SamplingParams] = None,
                    logprobs: Optional[int] = None) -> "GenerationParams":
        """Build from the pre-redesign kwarg surface (the Request shim)."""
        sp = sampling or SamplingParams()
        return cls(
            max_new_tokens=16 if max_new_tokens is None else max_new_tokens,
            eos_id=eos_id,
            temperature=sp.temperature, top_k=sp.top_k, top_p=sp.top_p,
            seed=sp.seed,
            logprobs=logprobs or 0,
        )


# finish_reason values (Sequence.finish_reason)
FINISH_EOS = "eos"
FINISH_LENGTH = "length"
FINISH_ERROR = "error"


@dataclasses.dataclass
class Sequence:
    """One generated branch: what a single RequestState used to be, made
    first-class so every request — n=1 included — returns a LIST of these
    instead of the results dict growing ad-hoc parallel fields."""

    tokens: List[int]
    # generated-token index -> [(token_id, logprob), ...] top-k entries
    logprobs: Dict[int, List[Tuple[int, float]]]
    # sum over generated tokens of log P(token | prefix) under the UNMASKED
    # model distribution (grammar masks constrain selection, not the score);
    # beam search ranks its hypotheses by exactly this value
    cumulative_logprob: float
    finish_reason: Optional[str]  # "eos" | "length" | "error" | None (running)


class RequestHandle:
    """What ``submit()`` returns: the request's identity plus accessors into
    the engine's results once ``run()`` completes. Deliberately thin — the
    engine stays a run-to-completion batch loop; the handle is the stable
    client-side name for one request's outcome."""

    def __init__(self, engine, rid: int):
        self._engine = engine
        self.rid = rid

    @property
    def done(self) -> bool:
        return self.rid in self._engine.results

    def result(self):
        """The finished request's state record (raises until run() finished
        it). ``.sequences`` on the result carries the per-branch outputs."""
        state = self._engine.results.get(self.rid)
        if state is None:
            raise RuntimeError(
                f"request {self.rid} has not finished (run the engine first)"
            )
        return state

    @property
    def sequences(self) -> List[Sequence]:
        return self.result().sequences

    def __repr__(self):
        return f"RequestHandle(rid={self.rid}, done={self.done})"
