"""SamplingParams: per-request token-selection policy, executed ON DEVICE.

The engine's decode hot path fuses token selection into the serve step
(serving/step.py): logits are produced, filtered and sampled without ever
leaving the device, and the only per-token D2H traffic is the (B,) chosen ids.
This module is the host-side half of that contract — the per-request policy
record plus the packing helpers that turn a batch slot's policies into the
(B,) device vectors ``ops.sample_tokens`` consumes.

Reproducibility contract (what the tests pin down):
  - greedy (temperature 0) equals host ``np.argmax`` over the same logits row,
    bit-for-bit — the on-device path is not allowed to drift from the oracle;
  - a sampled request is a pure function of (seed, rid, position): replaying
    the same trace through any engine — different batch composition, different
    chunking, preempted and recomputed — yields the same tokens, because the
    PRNG key folds the absolute position, never the step count or slot id;
  - multi-step fused decode (EngineConfig.multi_step) samples inside the
    on-device loop with the same fold, so K>1 is token-exact vs K=1.

Speculative decoding stream contract (serving/speculative.py,
ops.verify_draft_tokens): GREEDY requests are token-exact between the
speculative and non-speculative paths — argmax has no randomness, so
accepting argmax-agreeing draft prefixes reproduces the serial stream
bit-for-bit (CI pins this). SAMPLED requests stay a pure function of
(seed, rid, position) — the verify op derives per-position keys with the
same fold_in(PRNGKey(stream), position) base as sample_tokens, then fans
out into an acceptance-uniform and a resample-Gumbel stream via the
ops.SPEC_ACCEPT_FOLD / ops.SPEC_RESAMPLE_FOLD domain tags — but the
speculative sampled stream deliberately differs from the non-speculative
one: rejection sampling consumes different randomness than Gumbel-max, so
only reproducibility (same engine config -> same tokens, preemption-
recompute invariant), not cross-path equality, is promised above
temperature 0. Per-request opt-out: GenerationParams.speculative=False.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into a token (all selection on device).

    temperature 0 = greedy argmax (the default, and the exact-match oracle all
    engine-vs-engine tests rely on). temperature > 0 samples from the
    temperature-scaled distribution after the optional top_k (keep the k
    largest logits; 0 = off) and top_p (keep the smallest head of the
    distribution reaching mass top_p; 1.0 = off) filters. ``seed`` names the
    request's PRNG stream; the effective stream also folds the request id
    (``stream_seed``) so same-seed concurrent requests draw independently.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def stream_seed(seed: int, rid: int) -> int:
    """The per-request PRNG stream id: the user seed mixed with the request id
    (golden-ratio multiply, uint32 wraparound) so concurrent requests sharing a
    seed draw independent streams. A pure function of (seed, rid) — stable
    across runs, engines, batch slots, and preemption-recompute."""
    return (int(seed) ^ ((int(rid) * 0x9E3779B9) & 0xFFFFFFFF)) & 0xFFFFFFFF


def pack_slot_params(states_by_slot, max_batch: int):
    """Flatten the running slots' SamplingParams into the TWO packed host
    arrays the fused serve step consumes (a device_put costs ~1ms on this
    backend regardless of size, so the engine uploads two arrays per
    slot-composition change, never one per field):

      f32 (2, B): [temperature, top_p]
      i32 (2, B): [top_k, seed-bits] — the uint32 stream seed reinterpreted
      as int32 (two's complement; the step casts back, bit-identical)

    Inactive slots keep greedy defaults — they are masked out of the step
    anyway (the engine prepends its phase bitmap as the i32 pack's row 0)."""
    f32 = np.zeros((2, max_batch), np.float32)
    f32[1] = 1.0  # top_p off
    i32 = np.zeros((2, max_batch), np.int32)
    for slot, state in states_by_slot.items():
        # the state's EFFECTIVE policy — branch b of a parallel-generation
        # group folds its branch index into the seed (request.py), so packing
        # reads the state, never request.sampling directly
        sp = state.sampling
        f32[0, slot] = sp.temperature
        f32[1, slot] = sp.top_p
        i32[0, slot] = sp.top_k
        i32[1, slot] = np.uint32(
            stream_seed(sp.seed, state.request.rid)
        ).astype(np.int32)
    return f32, i32
