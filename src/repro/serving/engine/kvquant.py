"""PagedQuantSpec: QuantizedAccessor-style block scales composed with LayoutPaged.

The mdspan paper's pitch is that the layout and accessor customization points
are ORTHOGONAL: the same storage can change its index->offset map (layout) or
its element representation (accessor) without either knowing about the other.
The paged KV cache already exercises the layout axis (LayoutPaged's block-table
indirection); this module is the accessor axis on the very same pool — int8 or
int4 page bytes with one f32 scale per (physical page, kv head), decoded on
access, encoded on scatter.

Why (page, head) scales compose cleanly with LayoutPaged: the layout's offset is

    ((page * Hkv + head) * page_size + slot) * D + d

so one (page, head) pair covers a CONTIGUOUS ``page_size * D`` range of the flat
codomain. A scale per (page, head) is therefore exactly a QuantizedAccessor
block scale with ``block = page_size * D`` over the paged codomain — for int8
the pool's flat bytes + scales ARE valid ``QuantizedAccessor`` buffers
(``as_flat_accessor`` returns the accessor; tests assert access-equivalence).
Because scales are keyed by PHYSICAL page, every allocator-level law carries
over untouched: refcounts, prefix-index adoption, CoW, and
``LayoutPaged.is_unique()`` all reason about page ids, never bytes, so a shared
quantized page is copied (bytes AND scale) and privatized exactly like an f32
one.

int4 deviation: ``QuantizedAccessor`` packs ADJACENT value pairs per byte;
pages pack SPLIT-HALF along the feature dim (kernels/paged_attention.py:
pack_int4_splithalf) so in-kernel dequant is a lane concat and a token's
scatter stays nibble-local. The scale algebra is identical; only the nibble
order differs, and ``accessors.Int4SplitHalfAccessor`` (row = head_dim) is
the flat accessor that speaks it — ``as_flat_accessor`` returns it for int4,
so the instrumentation path (core/instrument.CountingAccessor) covers every
kv dtype.

Scale lifecycle (deterministic, so prefix sharing dedupes quantized pages):
  - prefill scatter: fresh scale per (page, head) from that page's own absmax
    (pad slack included — prompts are zero-padded deterministically, so a page
    is still a pure function of the tokens that hash to it);
  - decode append at slot 0: the page is brand new (decode just crossed a page
    boundary) — fresh scale from the token itself;
  - decode append at slot > 0: the page already carries prefill (or CoW-copied)
    content — re-quantize with the EXISTING scale, clipped, the same law as
    ``QuantizedAccessor.store``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.accessors import Int4SplitHalfAccessor, QuantizedAccessor
from repro.kernels.paged_attention import dequantize_pages, pack_int4_splithalf


@dataclasses.dataclass(frozen=True)
class PagedQuantSpec:
    """Element-representation policy for a paged KV pool (the accessor axis).

    A quantized pool leaf is the pytree {"q": intN bytes, "scale": f32} with
    q: (..., num_pages, Hkv, page_size, Dq) and scale: (..., num_pages, Hkv),
    Dq = D (int8) or D // 2 (int4). All methods are shape-polymorphic in the
    leading dims (the layer stack).
    """

    bits: int = 8
    element_type: Any = jnp.float32

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError("PagedQuantSpec supports bits in {4, 8}")

    @property
    def qmax(self) -> int:
        return 7 if self.bits == 4 else 127

    def packed_dim(self, head_dim: int) -> int:
        if self.bits == 8:
            return head_dim
        if head_dim % 2:
            raise ValueError(f"int4 KV pages need an even head_dim, got {head_dim}")
        return head_dim // 2

    # -- page encode/decode -------------------------------------------------------
    def encode_pages(self, x: jax.Array) -> Dict[str, jax.Array]:
        """x: f32 (..., page_size, D) -> {"q": (..., page_size, Dq), "scale": (...)}.

        One fresh scale per (page, head) slice (absmax / qmax; empty slices get
        scale 1.0, matching QuantizedAccessor.from_codomain so the int8 pool is
        bit-identical to the flat-accessor encoding)."""
        x = jnp.asarray(x, jnp.float32)
        absmax = jnp.max(jnp.abs(x), axis=(-2, -1))
        scale = jnp.where(absmax > 0, absmax / self.qmax, 1.0).astype(jnp.float32)
        q = jnp.clip(
            jnp.round(x / scale[..., None, None]), -self.qmax, self.qmax
        ).astype(jnp.int8)
        if self.bits == 4:
            q = pack_int4_splithalf(q)
        return {"q": q, "scale": scale}

    def decode_pages(self, q: jax.Array, scale: jax.Array) -> jax.Array:
        """Inverse of encode_pages (up to quantization error)."""
        return dequantize_pages(q, scale, bits=self.bits).astype(self.element_type)

    # -- token append (the decode scatter) ----------------------------------------
    def token_scale(self, tok: jax.Array) -> jax.Array:
        """Fresh scale for a page whose first content is this token.
        tok: (..., D) -> (...)."""
        absmax = jnp.max(jnp.abs(jnp.asarray(tok, jnp.float32)), axis=-1)
        return jnp.where(absmax > 0, absmax / self.qmax, 1.0).astype(jnp.float32)

    def quantize_tokens(self, tok: jax.Array, scale: jax.Array) -> jax.Array:
        """Quantize token vectors with a GIVEN (page, head) scale, clipped —
        QuantizedAccessor.store's law. tok: (..., D), scale: (...) ->
        packed (..., Dq) int8."""
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(
            jnp.round(jnp.asarray(tok, jnp.float32) / safe[..., None]),
            -self.qmax, self.qmax,
        ).astype(jnp.int8)
        if self.bits == 4:
            q = pack_int4_splithalf(q)
        return q

    # -- the composition law -------------------------------------------------------
    def as_flat_accessor(self, page_size: int, head_dim: int) -> QuantizedAccessor:
        """The equivalent flat accessor over the LayoutPaged codomain:
        (page, head) scales == block scales with block = page_size * head_dim.

        int8 returns a plain ``QuantizedAccessor`` (the pool's flat bytes ARE
        its buffers). int4 returns ``Int4SplitHalfAccessor`` with
        row = head_dim — the accessor that speaks the pages' split-half nibble
        order (pack_int4_splithalf packs per (slot, :) head vector, and the
        flat offset formula walks head vectors contiguously, so the packed
        pool reshaped to 1-D is byte-identical to that accessor's encoding).
        Both make the pool observable through core.instrument's
        CountingAccessor."""
        if self.bits == 8:
            return QuantizedAccessor(
                self.element_type, bits=8, block=page_size * head_dim
            )
        return Int4SplitHalfAccessor(
            self.element_type, bits=4, block=page_size * head_dim, row=head_dim
        )


# kv_dtype config values -> element-representation policy (None = dense f32/bf16
# pages, i.e. the BasicAccessor regime the engine shipped with)
KV_DTYPES: Dict[str, Optional[PagedQuantSpec]] = {
    "f32": None,
    "int8": PagedQuantSpec(bits=8),
    "int4": PagedQuantSpec(bits=4),
}


def kv_pool_bytes(pools) -> int:
    """Device bytes held by a (possibly quantized) list-of-pytrees page pool."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pools)))
