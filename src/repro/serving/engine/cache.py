"""PagedKVCache: device page pools + host page allocator, specified by LayoutPaged.

The device side is one page pool per layer stack, (L, num_pages, Hkv, ps, Dh) —
the LayoutPaged codomain (layout.pool_shape()) with a leading layer dim; every
layer shares the SAME block table, so one host-side allocation covers the whole
model. The host side is a free-list allocator over physical page ids plus the
block-table rows the Pallas kernel prefetches.

Page 0 is the reserved NULL page: inactive batch slots and unallocated table
entries point at it, so out-of-range DMA picks and masked scatter writes always
land somewhere harmless.

Prefix sharing: every physical page carries a refcount, and pages written by
prefill are registered in an index keyed by the page-granular hash chain of the
tokens they hold (request.page_hash_chain). ``allocate`` maps a new request's
leading chain entries onto existing live pages (incref, no free-list pop), so
the pool's capacity scales with UNIQUE tokens, not total tokens. Freeing
decrements refcounts; a page returns to the free list — and leaves the index —
only at refcount zero. A shared page is read-only: the first scatter into one
(the decode append) must copy-on-write first (``needs_cow``/``cow_page``), and
``layout_for`` reports the aliasing formally — LayoutPaged.is_unique() is False
exactly while the slot's table references a refcount>1 page.

``layout_for(slot)`` materializes the formal mdspan view of one sequence's cache
— the LayoutPaged instance whose offsets address the flat pool. ``dense_view``
gathers through exactly those offsets; tests use it to cross-check that the
engine's scatter writes and the layout's index->offset algebra agree.

``kv_dtype`` ("f32" | "int8" | "int4") selects the pool's element
representation (kvquant.PagedQuantSpec — the accessor axis composed with the
LayoutPaged one): quantized pools hold {q, scale} pytrees per k/v, prefill and
the decode append quantize at scatter time, and every allocator law above —
refcounts, prefix index, CoW — is representation-blind because it keys on page
ids and token hashes, never bytes. Pool bytes drop ~4x (int8) / ~8x (int4)
against f32 pages; ``stats()`` reports them.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Extents, LayoutPaged
from repro.models.attention import pack_kv_pages, pack_kv_pages_quant

from .kvquant import KV_DTYPES, kv_pool_bytes
from .request import page_hash_chain

_pack_kv_pages = jax.jit(pack_kv_pages, donate_argnums=(0,))


def _copy_page(pool, src, dst):
    """Duplicate one physical page across all layers (the CoW device op).

    ``pool`` is any pytree of page-major arrays (page ids on axis 1, after the
    layer dim) — the f32 {"k", "v"} pools and the quantized {"k"/"v": {"q",
    "scale"}} pools share this one code path, so CoW copies a quantized page's
    bytes AND its (page, head) scales in the same op."""
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)


_copy_page = jax.jit(_copy_page, donate_argnums=(0,))


def _patch_slot(tables, lens, patch):
    """Patch ONE slot of the device-resident table/len mirrors (the allocator
    event delta). ``patch`` is a single packed (2 + max_pages,) int32 vector
    [slot, len, row...] — one device_put per event (a put costs ~1ms on this
    backend regardless of size, so the delta travels as one array, not
    three). The slot index is traced, so every event shares one compile and
    the old buffers are donated in place. This is how allocation / CoW /
    preemption reach the device — a row-sized upload, never the whole table."""
    slot = patch[0]
    tables = jax.lax.dynamic_update_slice(tables, patch[None, 2:], (slot, 0))
    lens = jax.lax.dynamic_update_slice(lens, patch[1:2], (slot,))
    return tables, lens


_patch_slot = jax.jit(_patch_slot, donate_argnums=(0, 1))


def _gather_pages(pool, idx):
    """Stack the given physical pages out of a pool pytree, page-major — the
    demotion gather (ONE batched device op per pool per migration event; the
    same tree path as _copy_page, so quantized {q, scale} leaves ride along
    and a page's scales travel with its bytes)."""
    return jax.tree.map(lambda a: a[:, idx], pool)


_gather_pages = jax.jit(_gather_pages)


def _adopt_pages(pool, staged, idx):
    """Scatter ``staged`` (host-promoted) pages into the pool at page ids
    ``idx`` — the promotion scatter, donated in place. Callers pad ``idx`` to a
    power-of-two bucket with the reserved null page 0 (whose content is never
    read unmasked), so migrations of any size share O(log) compiles."""
    return jax.tree.map(lambda a, s: a.at[:, idx].set(s), pool, staged)


_adopt_pages = jax.jit(_adopt_pages, donate_argnums=(0,))


def _pad_bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class TierManager:
    """The host-RAM page tier behind the device pool (ROADMAP item 3): a
    second-level, CONTENT-KEYED prefix index whose pages live in host memory.

    The mdspan framing: HBM and host RAM are two memory spaces behind the
    accessor axis (core/accessors.py §"accessors as memory spaces"), and the
    block table is the indirection that makes migration invisible — a page's
    id, its chain key, and every offset that reaches it are space-blind, so
    moving its bytes is pure policy. This class IS that policy:

      - DEMOTION (preemption as swap): a preempted / finished-but-retained
        slot's complete pages are copied host-side under their page-hash chain
        keys BEFORE the device pages free. Write-back-free for clean pages: a
        key already host-resident skips the copy (the host bytes are still
        exact — pages are immutable once published; CoW replaces, never
        rewrites).
      - PROMOTION (resume as prefetch): ``PagedKVCache.allocate`` extends its
        device-index match with ``match_run`` over this index; hits are copied
        into freshly-popped device pages at admission, so a resumed session's
        first decode hits warm HBM pages instead of recomputing prefill.
      - EVICTION: expired retained pages first (``retain_finished_s``
        deadlines), then LRU by last-touch tick. Host pages carry no refcounts
        — they are cache entries, safe to drop at any time (the fallback is
        today's free-and-recompute path, token-exact by construction).
      - BUDGET: ``begin_step`` re-arms a per-step migration allowance
        (demote + promote pages both draw from it); overflow truncates the
        TAIL of a run, and a shorter warm prefix is still a valid prefix by
        the chain-key semantics.

    Transfers move whole page-major pytrees (``_gather_pages`` /
    ``_adopt_pages`` + one ``jax.device_get`` / ``jnp.asarray`` upload per
    event), so int8/int4 pages round-trip bit-identically, scales included.
    Host pools are lazily allocated numpy mirrors of the device pools — a
    tier that never demotes costs no host RAM and no device work at all.
    """

    def __init__(self, cache: "PagedKVCache", host_pages: int,
                 budget_pages_per_step: int = 0):
        if host_pages <= 0:
            raise ValueError("TierManager needs host_pages >= 1")
        self.cache = cache
        self.host_pages = host_pages
        self.budget_pages = int(budget_pages_per_step)
        self._pools = None  # lazy numpy mirrors of cache.pools, page axis 1
        self._free: deque = deque(range(host_pages))
        self._index: Dict[tuple, int] = {}  # chain key -> host page
        self._key_of: Dict[int, tuple] = {}  # host page -> chain key
        self._tick = 0
        self._touch: Dict[int, int] = {}  # host page -> last-use tick (LRU)
        self._expiry: Dict[int, float] = {}  # host page -> retention deadline
        self._budget_left = self.budget_pages or (1 << 30)
        # counters (PagedKVCache.stats merges these into every snapshot)
        self.swap_out_pages = 0
        self.swap_out_elided = 0  # demotions satisfied by existing residency
        self.swap_in_pages = 0
        self.prefetch_hits = 0
        self.evictions = 0

    @property
    def resident(self) -> int:
        return len(self._index)

    @property
    def budget_left(self) -> int:
        return self._budget_left

    def begin_step(self) -> None:
        """Re-arm the per-step migration budget (engine calls this once per
        step; with budget 0 the allowance is effectively unlimited)."""
        self._budget_left = self.budget_pages or (1 << 30)

    def _ensure_pools(self) -> None:
        if self._pools is None:
            self._pools = [
                jax.tree.map(
                    lambda a: np.zeros(
                        (a.shape[0], self.host_pages) + a.shape[2:], a.dtype
                    ),
                    pool,
                )
                for pool in self.cache.pools
            ]

    def match_run(self, chain, start: int) -> int:
        """Length of the host-resident run extending ``chain[start:]`` — the
        second-level prefix match allocate consults after the device index."""
        n = 0
        for key in chain[start:]:
            if key not in self._index:
                break
            n += 1
        return n

    def _drop(self, hp: int) -> None:
        key = self._key_of.pop(hp, None)
        if key is not None:
            self._index.pop(key, None)
        self._expiry.pop(hp, None)
        self._touch.pop(hp, None)
        self._free.append(hp)

    def _evict_one(self) -> bool:
        """Free one host page: expired retained pages first, then global LRU."""
        if not self._key_of:
            return False
        now = time.monotonic()
        expired = [
            p for p in self._key_of
            if self._expiry.get(p, float("inf")) <= now
        ]
        pool = expired or list(self._key_of)
        victim = min(pool, key=lambda p: self._touch.get(p, 0))
        self._drop(victim)
        self.evictions += 1
        tr = self.cache.trace
        if tr is not None:
            tr.instant("tier_evict", -1, expired=bool(expired),
                       resident=len(self._index))
        return True

    def release(self, chain) -> int:
        """Drop residency for a context's keys (request-failure paths): a
        request that can never resume must not orphan host pages until LRU
        pressure happens to find them."""
        n = 0
        for key in chain:
            hp = self._index.get(key)
            if hp is not None:
                self._drop(hp)
                n += 1
        return n

    def demote(self, keys, dev_pages, retain_s: float = 0.0) -> int:
        """Copy device pages host-side under their chain keys (swap-out).
        Skips already-resident keys (write-back-free), truncates to the
        per-step budget, and evicts to make room; returns pages copied. Must
        run while the device pages still hold their content (i.e. BEFORE the
        slot frees them)."""
        todo = [
            (k, p) for k, p in zip(keys, dev_pages) if k not in self._index
        ]
        self.swap_out_elided += len(keys) - len(todo)
        if len(todo) > self._budget_left:
            todo = todo[: self._budget_left]
        while todo and len(self._free) < len(todo):
            if not self._evict_one():
                todo = todo[: len(self._free)]
        if not todo:
            return 0
        self._ensure_pools()
        hps = [self._free.popleft() for _ in todo]
        self._tick += 1
        for (key, _), hp in zip(todo, hps):
            self._index[key] = hp
            self._key_of[hp] = key
            self._touch[hp] = self._tick
            if retain_s > 0:
                self._expiry[hp] = time.monotonic() + retain_s
        n = len(todo)
        pad = _pad_bucket(n)
        dps = np.zeros((pad,), np.int32)  # pad gathers read the null page
        dps[:n] = [p for _, p in todo]
        idx_h = np.asarray(hps)
        for host, pool in zip(self._pools, self.cache.pools):
            staged = jax.device_get(_gather_pages(pool, jnp.asarray(dps)))
            for h_leaf, s_leaf in zip(
                jax.tree.leaves(host), jax.tree.leaves(staged)
            ):
                h_leaf[:, idx_h] = s_leaf[:, :n]
        self._budget_left -= n
        self.swap_out_pages += n
        return n

    def promote(self, keys, dst_pages) -> int:
        """Copy host-resident pages into freshly-popped device pages (swap-in;
        the prefetch-on-admission path). The host copies STAY resident — pages
        are immutable once published, so a later demotion of the same content
        is write-back-free. Caller owns ``dst_pages`` and caps by
        ``budget_left``."""
        n = len(keys)
        if n == 0:
            return 0
        hps = [self._index[k] for k in keys]
        self._tick += 1
        for hp in hps:
            self._touch[hp] = self._tick
        pad = _pad_bucket(n)
        dst = np.zeros((pad,), np.int32)  # pad scatters hit the null page
        dst[:n] = dst_pages
        idx_h = np.zeros((pad,), np.int64)
        idx_h[:n] = hps
        new_pools = []
        for host, pool in zip(self._pools, self.cache.pools):
            staged = jax.tree.map(lambda h: jnp.asarray(h[:, idx_h]), host)
            new_pools.append(_adopt_pages(pool, staged, jnp.asarray(dst)))
        self.cache.pools = new_pools
        self._budget_left -= n
        self.swap_in_pages += n
        self.prefetch_hits += n
        return n

    def reset_counters(self) -> None:
        """Zero the migration counters WITHOUT flushing residency — bench
        rehearsals reset metrics but a warm tier must stay warm."""
        self.swap_out_pages = 0
        self.swap_out_elided = 0
        self.swap_in_pages = 0
        self.prefetch_hits = 0
        self.evictions = 0


class PagedKVCache:
    def __init__(self, model, *, num_pages: int, page_size: int, max_batch: int,
                 max_pages_per_seq: int, prefix_sharing: bool = True,
                 kv_dtype: str = "f32", host_pool_pages: int = 0,
                 swap_budget_pages_per_step: int = 0):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the reserved null page)")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype {kv_dtype!r} not in {sorted(KV_DTYPES)}"
            )
        self.cfg = model.cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq
        self.prefix_sharing = prefix_sharing
        self.kv_dtype = kv_dtype
        self.kv_spec = KV_DTYPES[kv_dtype]
        if self.kv_spec is None:
            self.pools = model.init_paged_cache(num_pages, page_size)
            self._pack = _pack_kv_pages
        else:
            self.pools = model.init_paged_cache(num_pages, page_size, kv_spec=self.kv_spec)
            self._pack = jax.jit(
                functools.partial(pack_kv_pages_quant, spec=self.kv_spec),
                donate_argnums=(0,),
            )
        self._free: deque = deque(range(1, num_pages))
        # block-table rows + live lengths, indexed by batch slot (null-page filled)
        self.tables = np.zeros((max_batch, max_pages_per_seq), np.int32)
        self.lens = np.zeros((max_batch,), np.int32)
        # device-resident mirrors of tables/lens — the persistent LayoutPaged
        # index->offset state, living beside the pool it indexes. Allocator
        # events (allocate/append/CoW/free/set_len) mark their slot dirty;
        # device_state() patches exactly those rows (dynamic_update_slice
        # deltas) before the next step instead of re-uploading whole arrays.
        # Routine decode appends never touch this path: the fused serve step
        # advances the device lens itself and adopt_lens_device() takes over
        # its (donated) output.
        self._tables_dev = jnp.asarray(self.tables)
        self._lens_dev = jnp.asarray(self.lens)
        self._dirty_slots: set = set()
        # warm the event-patch compile now (a no-op patch of slot 0) so the
        # first allocator event inside a measured run never pays it
        self._tables_dev, self._lens_dev = _patch_slot(
            self._tables_dev, self._lens_dev,
            jnp.asarray(np.zeros(2 + max_pages_per_seq, np.int32)),
        )
        self.pages_of: Dict[int, List[int]] = {}
        # per-page refcounts (ref[0] stays 0: the null page is never allocated)
        self.ref = np.zeros((num_pages,), np.int32)
        # prefix index: hash-chain key -> physical page holding that content,
        # plus the reverse map so a dying page evicts its own entry
        self._index: Dict[tuple, int] = {}
        self._key_of: Dict[int, tuple] = {}
        # pages of a just-allocated slot already holding its prefix (skip their
        # prefill scatter); consumed by write_prefill
        self._shared_upto: Dict[int, int] = {}
        # chunked prefill: chain entries whose pages are allocated but whose
        # content is still materializing — registered into the index
        # incrementally by publish_prefix() as chunks land (a chunk-by-chunk
        # filler must never let another request adopt a half-written page, but
        # every page BEHIND the chunk cursor is final and adoptable)
        self._deferred: Dict[int, List[tuple]] = {}
        self._published: Dict[int, int] = {}  # deferred keys already registered
        # same-step twin adoption (per-page written frontier): chain key ->
        # (donor slot, page index) for every deferred-but-unpublished key, so a
        # co-admitted twin can adopt a donor's pages BEFORE they are written
        # and skip the duplicate prefill compute. The adopter is gated out of
        # chunk dispatch until the donor's frontier covers its adopted pages
        # (frontier_ready); if the donor dies first the adopter lands in
        # _broken and the engine preempts it back to the queue.
        self._inflight: Dict[tuple, Tuple[int, int]] = {}
        self._frontier_deps: Dict[int, Tuple[int, int]] = {}  # adopter -> (donor, pages needed)
        self._broken: set = set()
        # host page tier (ROADMAP item 3): preemption as swap, resume as
        # prefetch. None when host_pool_pages == 0 — every tier touchpoint
        # below is `is None`-guarded, the PR 6 zero-overhead discipline.
        self.tier = (
            TierManager(self, host_pool_pages, swap_budget_pages_per_step)
            if host_pool_pages > 0 else None
        )
        # stats (benchmarks read these through ServeEngine.metrics)
        self.pages_shared_total = 0
        self.cow_copies = 0
        self.peak_pages_in_use = 0
        self.branch_forks = 0    # fork_slot calls (parallel-generation groups)
        self.beam_reorders = 0   # reorder_rows calls that changed any row
        # lifecycle trace (serving/telemetry.EngineTrace), attached by the
        # engine when EngineConfig.trace is set. Allocator events — allocate,
        # append_page, CoW, free_slot — are exactly the device-delta emission
        # points (_patch_slot), so tracing them costs one guarded host append
        # per EVENT, never per token, and nothing at all when None.
        self.trace = None

    # -- allocator ---------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def capacity_tokens(self, slot: int) -> int:
        """Tokens of owned page capacity beyond the slot's current length —
        how far decode (or a speculative window) can append before the next
        page-boundary event. The quantity event_free_horizon proves windows
        against and reserve_decode_tokens raises up front."""
        return len(self.pages_of[slot]) * self.page_size - int(self.lens[slot])

    def _take_free(self) -> int:
        p = self._free.popleft()
        self.ref[p] = 1
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return p

    def _chain(self, tokens) -> List[tuple]:
        """Prefix keys for a context — empty when sharing is off (the index is
        never read then, so no admission should pay the hashing either)."""
        if not self.prefix_sharing or tokens is None:
            return []
        return page_hash_chain(tokens, self.page_size)

    def _match_prefix(self, chain) -> List[int]:
        """Leading run of live pages already holding this context's pages.
        Chained keys make a hit at entry i imply the full token prefix through
        page i matches, so the run can be adopted wholesale."""
        matched = []
        for key in chain:
            page = self._index.get(key)
            if page is None:
                break
            matched.append(page)
        return matched

    def new_pages_needed(self, tokens, chain=None) -> int:
        """Free-list pages a request with this context must pop to run one more
        token — its admission cost. Shared-prefix pages are free. ``chain``
        (RequestState.hash_chain) skips re-hashing the context."""
        if chain is None or not self.prefix_sharing:
            chain = self._chain(tokens)
        return self.pages_for(len(tokens) + 1) - len(self._match_prefix(chain))

    def allocate(self, slot: int, n_pages: int, tokens=None, chain=None,
                 publish: bool = True) -> List[int]:
        """Bind ``n_pages`` logical pages to ``slot``: the leading run found in
        the prefix index is adopted by reference (incref), the rest pops from the
        free list. Fresh pages that prefill will fill are registered under the
        context's chain keys so later arrivals can share them in turn —
        immediately when ``publish`` (the monolithic engine fills them in the
        same step), or deferred to ``publish_prefix`` when the filler is
        chunk-by-chunk and the content only exists once the last chunk lands."""
        if n_pages > self.max_pages_per_seq:
            raise RuntimeError(
                f"sequence needs {n_pages} pages > max_pages_per_seq {self.max_pages_per_seq}"
            )
        if chain is None or not self.prefix_sharing:
            chain = self._chain(tokens)
        shared = self._match_prefix(chain)[:n_pages]
        base = len(shared)
        # second-level match: extend the device-index run with host-resident
        # pages (prefetch-on-admission). Promoted pages pop from the free list
        # like fresh ones — `fits` counts HBM only — but arrive pre-written.
        promote_keys: List[tuple] = []
        if self.tier is not None and base < n_pages:
            run = self.tier.match_run(chain, base)
            k = min(run, n_pages - base, self.tier.budget_left)
            promote_keys = list(chain[base : base + k])
        # same-step twin adoption: extend the warm run further with a donor's
        # in-flight (allocated, not yet published) pages — incref, no pop.
        # Only a single donor, only a contiguous run at matching page indices,
        # and only for deferred (chunked) allocations that can be gated.
        pos = base + len(promote_keys)
        donor: Optional[int] = None
        twin_pages: List[int] = []
        if not publish and self.prefix_sharing:
            while pos + len(twin_pages) < min(len(chain), n_pages):
                ent = self._inflight.get(chain[pos + len(twin_pages)])
                if ent is None:
                    break
                d_slot, d_idx = ent
                if (d_idx != pos + len(twin_pages)
                        or (donor is not None and d_slot != donor)
                        or d_slot == slot):
                    break
                donor = d_slot
                twin_pages.append(self.pages_of[d_slot][d_idx])
        n_new = n_pages - base - len(twin_pages)
        if n_new > len(self._free):
            raise RuntimeError(
                f"pool exhausted: want {n_new} new pages "
                f"({n_pages} total, {base} shared), free {len(self._free)}"
            )
        for p in shared:
            self.ref[p] += 1
        for p in twin_pages:
            self.ref[p] += 1
        self.pages_shared_total += len(shared) + len(twin_pages)
        fresh = [self._take_free() for _ in range(n_new)]
        k = len(promote_keys)
        pages = shared + fresh[:k] + twin_pages + fresh[k:]
        if promote_keys:
            self.tier.promote(promote_keys, fresh[:k])
            # promoted content is FINAL — register even for deferred (chunked)
            # allocations so later arrivals share it immediately
            self._register(promote_keys, pages, base)
            if self.trace is not None:
                self.trace.instant("prefetch", slot, pages=k)
        adopted = base + k + len(twin_pages)
        if twin_pages:
            self._frontier_deps[slot] = (donor, pos + len(twin_pages))
            if self.trace is not None:
                self.trace.instant(
                    "twin_adopt", slot, donor=donor, pages=len(twin_pages),
                )
        # register the fresh content-bearing pages (chain covers exactly the
        # pages prefill fills; the +1 decode-headroom tail has no content yet)
        fresh_keys = list(chain[adopted : min(len(chain), n_pages)])
        if publish:
            self._register(fresh_keys, pages, adopted)
        else:
            self._deferred[slot] = fresh_keys
            for j, key in enumerate(fresh_keys):
                self._inflight.setdefault(key, (slot, adopted + j))
        self.pages_of[slot] = pages
        self._shared_upto[slot] = adopted
        self.tables[slot, :] = 0
        self.tables[slot, : len(pages)] = pages
        self._dirty_slots.add(slot)
        if self.trace is not None:
            self.trace.instant(
                "alloc", slot, pages=n_pages, shared=adopted,
                free=len(self._free),
            )
        return pages

    def _register(self, keys: List[tuple], pages: List[int], start: int) -> None:
        for i, key in enumerate(keys, start=start):
            if key not in self._index:
                self._index[key] = pages[i]
                self._key_of[pages[i]] = key

    def publish_prefix(self, slot: int, written_pages: Optional[int] = None) -> None:
        """Register a chunk-prefilled slot's fresh pages in the prefix index as
        their content becomes final: entries for pages with index <
        ``written_pages`` (None = all — the prefill completed, including the
        partial last page whose pad tail the final chunk computed). Called
        after each chunk's scatter, so a mid-prefill donor is adoptable up to
        its written frontier and adopters NEVER see a half-written page. No-op
        for monolithic allocations (already published at allocate) and after
        preemption (free_slot discards the deferral)."""
        keys = self._deferred.get(slot)
        if not keys:
            return
        start = self._shared_upto.get(slot, 0)
        done = self._published.get(slot, 0)
        end = (
            len(keys) if written_pages is None
            else max(0, min(written_pages - start, len(keys)))
        )
        if end > done:
            self._register(keys[done:end], self.pages_of[slot], start + done)
            # published keys are ordinary index entries now — twins arriving
            # later adopt via _match_prefix, not the in-flight map
            for key in keys[done:end]:
                ent = self._inflight.get(key)
                if ent is not None and ent[0] == slot:
                    self._inflight.pop(key)
        if end >= len(keys):
            self._deferred.pop(slot, None)
            self._published.pop(slot, None)
        elif end > done:
            self._published[slot] = end
        # release twin adopters whose adopted run the frontier now covers
        final = start + end
        for adopter, (d_slot, need) in list(self._frontier_deps.items()):
            if d_slot == slot and need <= final:
                self._frontier_deps.pop(adopter)

    def adopted_pages(self, slot: int) -> int:
        """Pages of this slot adopted from the prefix index at allocation (the
        leading run whose KV is already resident) — the shared-prefix
        compute-skip extent, and the write-protected prefix of chunk scatters.
        Unlike write_prefill's consumption of the same bookkeeping, reading
        this does not clear it."""
        return self._shared_upto.get(slot, 0)

    def write_table_row(self, slot: int) -> np.ndarray:
        """The slot's block-table row with every non-writable entry nulled to
        page 0: adopted shared-prefix pages (other holders read them — the
        chunk-scatter CoW obligation is discharged by never aiming at them)
        and unallocated tail entries. The READ view stays ``tables[slot]``."""
        row = self.tables[slot].copy()
        row[: self.adopted_pages(slot)] = 0
        return row

    def append_page(self, slot: int) -> bool:
        """Grow a running sequence by one page; False when the pool is exhausted
        (caller preempts a victim and retries)."""
        pages = self.pages_of[slot]
        if len(pages) >= self.max_pages_per_seq:
            raise RuntimeError(f"slot {slot} hit max_pages_per_seq {self.max_pages_per_seq}")
        if not self._free:
            return False
        p = self._take_free()
        pages.append(p)
        self.tables[slot, len(pages) - 1] = p
        self._dirty_slots.add(slot)
        if self.trace is not None:
            self.trace.instant("append_page", slot, page=p, free=len(self._free))
        return True

    def _release_page(self, p: int) -> None:
        self.ref[p] -= 1
        assert self.ref[p] >= 0, f"page {p} refcount went negative"
        if self.ref[p] == 0:
            key = self._key_of.pop(p, None)
            if key is not None:
                self._index.pop(key, None)
            self._free.append(p)

    def free_slot(self, slot: int) -> None:
        """Release the slot's pages (idempotent). Shared pages survive with the
        other holders; only refcount-zero pages rejoin the free list. A
        mid-prefill release also discards the deferred index entries — the
        half-written pages were never adoptable and never become so."""
        released = self.pages_of.pop(slot, [])
        if released and self.trace is not None:
            self.trace.instant("free_slot", slot, pages=len(released))
        for p in released:
            self._release_page(p)
        self._drop_inflight(slot)
        self._shared_upto.pop(slot, None)
        self._deferred.pop(slot, None)
        self._published.pop(slot, None)
        self.tables[slot, :] = 0
        self.lens[slot] = 0
        self._dirty_slots.add(slot)

    def _drop_inflight(self, slot: int) -> None:
        """Unwind the twin bookkeeping for a dying slot: its own unpublished
        in-flight entries leave the map, and any adopter still waiting on it
        as a donor is marked broken (its adopted pages hold garbage — the
        engine preempts it back to the queue for a clean re-admit)."""
        for key in self._deferred.get(slot, []):
            ent = self._inflight.get(key)
            if ent is not None and ent[0] == slot:
                self._inflight.pop(key)
        for adopter, (d_slot, _) in list(self._frontier_deps.items()):
            if d_slot == slot:
                self._frontier_deps.pop(adopter)
                self._broken.add(adopter)
        self._frontier_deps.pop(slot, None)
        self._broken.discard(slot)

    def frontier_ready(self, slot: int) -> bool:
        """False while the slot waits on a twin donor's written frontier —
        chunk dispatch must skip it (its adopted pages are not yet real)."""
        return slot not in self._frontier_deps

    def take_broken(self) -> List[int]:
        """Slots whose twin donor died before covering their adopted run;
        cleared on read. The engine preempts these back to the queue."""
        out = sorted(self._broken)
        self._broken.clear()
        return out

    # -- host tier ---------------------------------------------------------------
    def demote_slot(self, slot: int, chain, retain_s: float = 0.0) -> int:
        """Swap a slot's COMPLETE pages out to the host tier before freeing
        them (preemption as swap / finished-session retention). Only full
        pages demote — a partial page holds fewer tokens than its chain key
        claims — and a twin adopter with an unsatisfied frontier holds garbage
        pages, so it never demotes. Must run BEFORE free_slot (the device
        pages must still hold their content; device_get syncs the stream)."""
        if self.tier is None or not chain or slot in self._frontier_deps:
            return 0
        pages = self.pages_of.get(slot)
        if not pages:
            return 0
        n = min(int(self.lens[slot]) // self.page_size, len(pages), len(chain))
        if n <= 0:
            return 0
        moved = self.tier.demote(chain[:n], pages[:n], retain_s=retain_s)
        if moved and self.trace is not None:
            self.trace.instant(
                "swap_out", slot, pages=moved,
                host_resident=self.tier.resident,
            )
        return moved

    def release_host(self, chain) -> int:
        """Drop host-tier residency for a context that can never resume
        (request-failure paths — no orphaned host pages)."""
        if self.tier is None or not chain:
            return 0
        return self.tier.release(chain)

    def check_conservation(self) -> None:
        """Allocator conservation invariants, checked on every stats() pull:
        refcount mass equals slot ownership, live + free covers the pool, and
        the host tier's free list + index partition its pages exactly."""
        owned = sum(len(v) for v in self.pages_of.values())
        total_ref = int(self.ref.sum())
        assert total_ref == owned, (
            f"refcount mass {total_ref} != owned pages {owned}"
        )
        live = sum(1 for p in range(1, self.num_pages) if self.ref[p] > 0)
        assert live + len(self._free) == self.num_pages - 1, (
            f"live {live} + free {len(self._free)} != pool {self.num_pages - 1}"
        )
        if self.tier is not None:
            t = self.tier
            assert len(t._free) + len(t._index) == t.host_pages, (
                f"host free {len(t._free)} + resident {len(t._index)} "
                f"!= host pool {t.host_pages}"
            )
            for key, hp in t._index.items():
                assert t._key_of.get(hp) == key, (
                    f"host page {hp} index/reverse-map mismatch"
                )

    # -- parallel generation: layout forks ---------------------------------------
    def fork_slot(self, src: int, dst: int, n_tokens: int) -> List[int]:
        """Bind ``dst`` as a FORK of ``src`` at context length ``n_tokens``: the
        pages covering those tokens are adopted by reference (incref — this is
        LayoutPaged.fork_group made physical: N branches of one prompt cost ~1x
        its KV pages), padded with fresh pages to the usual +1-token decode
        headroom. The first divergent write into a shared page goes through the
        ordinary CoW path (needs_cow/cow_page) — fork itself copies nothing.
        Raises when the headroom pages don't exist (caller checks ``fits``)."""
        src_pages = self.pages_of[src]
        n_alias = min(self.pages_for(n_tokens), len(src_pages))
        n_total = max(self.pages_for(n_tokens + 1), n_alias)
        if n_total > self.max_pages_per_seq:
            raise RuntimeError(
                f"fork needs {n_total} pages > max_pages_per_seq {self.max_pages_per_seq}"
            )
        if n_total - n_alias > len(self._free):
            raise RuntimeError(
                f"pool exhausted: fork wants {n_total - n_alias} fresh pages, "
                f"free {len(self._free)}"
            )
        shared = src_pages[:n_alias]
        for p in shared:
            self.ref[p] += 1
        self.pages_shared_total += len(shared)
        pages = list(shared) + [self._take_free() for _ in range(n_total - n_alias)]
        self.pages_of[dst] = pages
        self._shared_upto[dst] = n_alias
        self.tables[dst, :] = 0
        self.tables[dst, : len(pages)] = pages
        self.lens[dst] = n_tokens
        self._dirty_slots.add(dst)
        self.branch_forks += 1
        if self.trace is not None:
            self.trace.instant(
                "fork", dst, src=src, shared=n_alias, free=len(self._free)
            )
        return pages

    def reorder_rows(self, assignment: Dict[int, int]) -> None:
        """Rebind each child slot's row to a SNAPSHOT of its parent slot's
        pages/len — the beam-search step's hypothesis permutation, executed as
        pure block-table surgery (LayoutPaged.permute_rows over the live
        mapping): every new reference increfs BEFORE any old page is released,
        so a page held on both sides never transits refcount zero, and no page
        is ever copied here — divergence is the NEXT decode write's CoW
        problem, not the reorder's. Identity entries are skipped; a fully
        identity assignment is free (no dirty slots, no counter)."""
        live = {c: p for c, p in assignment.items() if c != p}
        if not live:
            return
        snap = {
            p: (list(self.pages_of[p]), int(self.lens[p]))
            for p in set(live.values())
        }
        for c, p in live.items():
            for page in snap[p][0]:
                self.ref[page] += 1
        self.pages_shared_total += sum(len(snap[p][0]) for p in live.values())
        for c in live:
            for page in self.pages_of.get(c, []):
                self._release_page(page)
        for c, p in live.items():
            pages, length = snap[p]
            self.pages_of[c] = list(pages)
            self._drop_inflight(c)
            self._shared_upto.pop(c, None)
            self._deferred.pop(c, None)
            self._published.pop(c, None)
            self.tables[c, :] = 0
            self.tables[c, : len(pages)] = pages
            self.lens[c] = length
            self._dirty_slots.add(c)
        self.beam_reorders += 1
        if self.trace is not None:
            self.trace.instant(
                "beam_reorder", min(live), moves=len(live), free=len(self._free)
            )

    # -- device-resident layout state ---------------------------------------------
    def set_len(self, slot: int, n: int) -> None:
        """Host-side length assignment (admission, chunk landings, prefill
        completion) — an allocator EVENT, so the slot is marked for a device
        patch. Routine decode appends go through bump_len instead."""
        self.lens[slot] = n
        self._dirty_slots.add(slot)

    def bump_len(self, slot: int, n: int = 1) -> None:
        """Advance the host lens mirror after a decode step appended ``n``
        tokens. NO dirty mark: the fused serve step already advanced the
        device-resident lens itself (adopt_lens_device took its output), so
        patching here would be a redundant upload."""
        self.lens[slot] += n

    def device_state(self) -> Tuple[jax.Array, jax.Array]:
        """The device-resident (tables, lens) mirrors, with pending allocator
        events applied as per-slot dynamic_update_slice patches (one compile,
        row-sized uploads). When an event storm touched most of the batch —
        bursts of admissions, cascading preemptions — one whole-array upload
        is cheaper than row-by-row patching and resets the delta stream."""
        if self._dirty_slots:
            if len(self._dirty_slots) > max(1, self.max_batch // 2):
                self._tables_dev = jnp.asarray(self.tables)
                self._lens_dev = jnp.asarray(self.lens)
            else:
                for s in sorted(self._dirty_slots):
                    patch = np.empty(2 + self.max_pages_per_seq, np.int32)
                    patch[0], patch[1] = s, self.lens[s]
                    patch[2:] = self.tables[s]
                    self._tables_dev, self._lens_dev = _patch_slot(
                        self._tables_dev, self._lens_dev, jnp.asarray(patch)
                    )
            self._dirty_slots.clear()
        return self._tables_dev, self._lens_dev

    def adopt_lens_device(self, lens_dev: jax.Array) -> None:
        """Take over the serve step's device-side lens output (the donated
        successor of the array device_state handed out) — decode appends
        advance the mapping state entirely on device."""
        self._lens_dev = lens_dev

    # -- copy-on-write -----------------------------------------------------------
    def needs_cow(self, slot: int) -> bool:
        """True when the page the next decode token scatters into is shared —
        writing it in place would corrupt every other holder's sequence."""
        pos = int(self.lens[slot])
        pages = self.pages_of[slot]
        pi = pos // self.page_size
        return pi < len(pages) and self.ref[pages[pi]] > 1

    def cow_page(self, slot: int) -> bool:
        """Privatize the page covering position lens[slot]: copy it to a fresh
        page, swap the block-table entry, drop the donor's refcount. False when
        no free page exists (caller preempts a victim and retries)."""
        if not self._free:
            return False
        pos = int(self.lens[slot])
        pi = pos // self.page_size
        pages = self.pages_of[slot]
        old = pages[pi]
        new = self._take_free()
        self.pools = [_copy_page(pool, old, new) for pool in self.pools]
        pages[pi] = new
        self.tables[slot, pi] = new
        self.ref[old] -= 1
        self.cow_copies += 1
        self._dirty_slots.add(slot)
        if self.trace is not None:
            self.trace.instant("cow", slot, src=old, dst=new)
        return True

    # -- device writes -----------------------------------------------------------
    def write_prefill(self, slot: int, caches) -> None:
        """Scatter a single-sequence prefill's packed KV (list of per-entry
        {"k": (L, 1, Hkv, S, Dh), ...}, S == n_pages * ps) into this slot's pages.
        Pages adopted from the prefix index already hold exactly these values
        (KV is a pure per-token function of token id and absolute position), so
        only the fresh tail is written."""
        ps = self.page_size
        n = caches[0]["k"].shape[3] // ps
        start = min(self._shared_upto.pop(slot, 0), n)
        if start >= n:
            return
        pages = jnp.asarray(self.pages_of[slot][start:n], jnp.int32)
        self.pools = [
            self._pack(
                pool, c["k"][:, :, :, start * ps :], c["v"][:, :, :, start * ps :], pages
            )
            for pool, c in zip(self.pools, caches)
        ]

    # -- mdspan view -------------------------------------------------------------
    def shared_pages_of(self, slot: int) -> Tuple[int, ...]:
        """The slot's pages other holders also reference (refcount > 1)."""
        return tuple(p for p in self.pages_of[slot] if self.ref[p] > 1)

    def layout_for(self, slot: int) -> LayoutPaged:
        """The LayoutPaged mapping of one sequence's cache over the flat pool.
        Pages co-owned with other sequences surface as ``shared_pages``, so
        ``is_unique()`` is False exactly while the table references a
        refcount>1 page — the formal statement of the CoW obligation."""
        pages = self.pages_of[slot]
        hkv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
        return LayoutPaged(
            Extents.fully_dynamic(1, hkv, len(pages) * self.page_size, dh),
            (tuple(pages),),
            self.page_size,
            self.num_pages,
            self.shared_pages_of(slot),
        )

    def _flat_codomain(self, leaf, layer: int):
        """One layer's pool as the layout's flat codomain, decoded through the
        accessor when the pool is quantized — the layout algebra never sees
        the representation."""
        if self.kv_spec is None:
            return leaf[layer].reshape(-1)
        return self.kv_spec.decode_pages(
            leaf["q"][layer], leaf["scale"][layer]
        ).reshape(-1)

    def dense_view(self, slot: int, entry: int = 0, layer: int = 0):
        """(k, v) of shape (Hkv, len, Dh) gathered through layout_for(slot)'s
        offsets — the generic-fallback read path of the paged layout. Quantized
        pools are decoded first (the accessor's access() over the whole
        codomain), then gathered through the SAME offsets."""
        layout = self.layout_for(slot)
        offs = layout.offsets_dense()[0]  # (Hkv, n_pages*ps, Dh)
        length = int(self.lens[slot])
        k = jnp.take(self._flat_codomain(self.pools[entry]["k"], layer), offs)[:, :length, :]
        v = jnp.take(self._flat_codomain(self.pools[entry]["v"], layer), offs)[:, :length, :]
        return k, v

    def chunk_view(self, slot: int, start: int, stop: int, entry: int = 0,
                   layer: int = 0):
        """The formal mdspan of one prefill chunk: LITERALLY
        ``submdspan(seq_view, all_, all_, (start, stop), all_)`` over the flat
        pool (core/submdspan.py §chunk views are submdspans). Returns the K
        span; its layout is again a LayoutPaged whose rows are trimmed to the
        chunk's pages, whose ``pos_offset`` carries partial-page starts, and
        whose ``is_unique()`` is True exactly when the chunk lies past every
        shared page — the view the engine's chunk scatter/attend implements."""
        from repro.core.mdspan import MdSpan
        from repro.core.submdspan import all_, submdspan

        span = MdSpan.over(
            self._flat_codomain(self.pools[entry]["k"], layer),
            self.layout_for(slot),
        )
        return submdspan(span, all_, all_, (start, stop), all_)

    # -- stats -------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        self.check_conservation()
        out = {
            "peak_pages_in_use": self.peak_pages_in_use,
            "pages_shared": self.pages_shared_total,
            "cow_copies": self.cow_copies,
            "branch_forks": self.branch_forks,
            "beam_reorders": self.beam_reorders,
            "kv_pool_bytes": kv_pool_bytes(self.pools),
        }
        if self.tier is not None:
            out.update(
                swap_out_pages=self.tier.swap_out_pages,
                swap_out_elided=self.tier.swap_out_elided,
                swap_in_pages=self.tier.swap_in_pages,
                prefetch_hits=self.tier.prefetch_hits,
                evictions=self.tier.evictions,
                host_pages_resident=self.tier.resident,
                host_pool_pages=self.tier.host_pages,
            )
        return out

    def reset_stats(self) -> None:
        self.pages_shared_total = 0
        self.cow_copies = 0
        self.branch_forks = 0
        self.beam_reorders = 0
        self.peak_pages_in_use = self.pages_in_use
        if self.tier is not None:
            self.tier.reset_counters()
