"""PagedKVCache: device page pools + host page allocator, specified by LayoutPaged.

The device side is one page pool per layer stack, (L, num_pages, Hkv, ps, Dh) —
the LayoutPaged codomain (layout.pool_shape()) with a leading layer dim; every
layer shares the SAME block table, so one host-side allocation covers the whole
model. The host side is a free-list allocator over physical page ids plus the
block-table rows the Pallas kernel prefetches.

Page 0 is the reserved NULL page: inactive batch slots and unallocated table
entries point at it, so out-of-range DMA picks and masked scatter writes always
land somewhere harmless.

``layout_for(slot)`` materializes the formal mdspan view of one sequence's cache
— the LayoutPaged instance whose offsets address the flat pool. ``dense_view``
gathers through exactly those offsets; tests use it to cross-check that the
engine's scatter writes and the layout's index->offset algebra agree.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Extents, LayoutPaged
from repro.models.attention import pack_kv_pages

_pack_kv_pages = jax.jit(pack_kv_pages, donate_argnums=(0,))


class PagedKVCache:
    def __init__(self, model, *, num_pages: int, page_size: int, max_batch: int,
                 max_pages_per_seq: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the reserved null page)")
        self.cfg = model.cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq
        self.pools = model.init_paged_cache(num_pages, page_size)
        self._free: deque = deque(range(1, num_pages))
        # block-table rows + live lengths, indexed by batch slot (null-page filled)
        self.tables = np.zeros((max_batch, max_pages_per_seq), np.int32)
        self.lens = np.zeros((max_batch,), np.int32)
        self.pages_of: Dict[int, List[int]] = {}

    # -- allocator ---------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def allocate(self, slot: int, n_pages: int) -> List[int]:
        if n_pages > len(self._free):
            raise RuntimeError(f"pool exhausted: want {n_pages}, free {len(self._free)}")
        if n_pages > self.max_pages_per_seq:
            raise RuntimeError(
                f"sequence needs {n_pages} pages > max_pages_per_seq {self.max_pages_per_seq}"
            )
        pages = [self._free.popleft() for _ in range(n_pages)]
        self.pages_of[slot] = pages
        self.tables[slot, :] = 0
        self.tables[slot, : len(pages)] = pages
        return pages

    def append_page(self, slot: int) -> bool:
        """Grow a running sequence by one page; False when the pool is exhausted
        (caller preempts a victim and retries)."""
        pages = self.pages_of[slot]
        if len(pages) >= self.max_pages_per_seq:
            raise RuntimeError(f"slot {slot} hit max_pages_per_seq {self.max_pages_per_seq}")
        if not self._free:
            return False
        p = self._free.popleft()
        pages.append(p)
        self.tables[slot, len(pages) - 1] = p
        return True

    def free_slot(self, slot: int) -> None:
        for p in self.pages_of.pop(slot, []):
            self._free.append(p)
        self.tables[slot, :] = 0
        self.lens[slot] = 0

    # -- device writes -----------------------------------------------------------
    def write_prefill(self, slot: int, caches) -> None:
        """Scatter a single-sequence prefill's packed KV (list of per-entry
        {"k": (L, 1, Hkv, S, Dh), ...}, S == n_pages * ps) into this slot's pages."""
        n = caches[0]["k"].shape[3] // self.page_size
        pages = jnp.asarray(self.pages_of[slot][:n], jnp.int32)
        self.pools = [
            _pack_kv_pages(pool, c["k"], c["v"], pages)
            for pool, c in zip(self.pools, caches)
        ]

    # -- mdspan view -------------------------------------------------------------
    def layout_for(self, slot: int) -> LayoutPaged:
        """The LayoutPaged mapping of one sequence's cache over the flat pool."""
        pages = self.pages_of[slot]
        hkv, dh = self.cfg.n_kv_heads, self.cfg.head_dim
        return LayoutPaged(
            Extents.fully_dynamic(1, hkv, len(pages) * self.page_size, dh),
            (tuple(pages),),
            self.page_size,
            self.num_pages,
        )

    def dense_view(self, slot: int, entry: int = 0, layer: int = 0):
        """(k, v) of shape (Hkv, len, Dh) gathered through layout_for(slot)'s
        offsets — the generic-fallback read path of the paged layout."""
        layout = self.layout_for(slot)
        offs = layout.offsets_dense()[0]  # (Hkv, n_pages*ps, Dh)
        length = int(self.lens[slot])
        k = jnp.take(self.pools[entry]["k"][layer].reshape(-1), offs)[:, :length, :]
        v = jnp.take(self.pools[entry]["v"][layer].reshape(-1), offs)[:, :length, :]
        return k, v
