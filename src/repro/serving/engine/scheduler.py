"""Step-level admission/eviction policy for the continuous-batching engine.

Each engine step the scheduler:
  1. admits queued requests FIFO while a batch slot is free AND the pool can
     hold the whole context plus a one-page decode headroom (watermark) — never
     admitting a request it would immediately have to preempt. Admission cost
     counts only the NEW pages the request must pop from the free list: pages
     its prompt prefix can adopt from the cache's prefix index are free, so
     bursts of shared-prefix requests admit far deeper batches than the pool's
     raw size suggests;
  2. guarantees every running sequence a page it may WRITE for its next token:
     appending a page when the sequence crosses a page boundary, and
     copy-on-write-privatizing the target page when prefix sharing left it
     refcount>1 — in both cases preempting the MOST RECENTLY admitted other
     sequence when the pool runs dry (LIFO victim choice keeps the oldest
     requests making progress, so total recompute work is bounded); preempted
     sequences release all pages (shared ones survive with their co-owners) and
     requeue at the FRONT with their generated tokens kept — on re-admission
     the full context is re-prefilled and may re-share any of its prefix pages
     that stayed alive. With a host tier configured
     (EngineConfig.host_pool_pages) preemption becomes SWAP instead: complete
     pages demote to host RAM before freeing, and re-admission promotes them
     back (prefetch) so only the tail is recomputed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .cache import PagedKVCache
from .request import DECODING, BranchGroup, RequestQueue, RequestState


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int
    watermark_pages: int = 1  # free pages kept back at admission for decode growth


class Scheduler:
    def __init__(self, cache: PagedKVCache, config: SchedulerConfig):
        self.cache = cache
        self.config = config
        # slot -> state, in admission order (dict preserves insertion order)
        self.running: Dict[int, RequestState] = {}
        # lifecycle trace (serving/telemetry.EngineTrace), attached by the
        # engine; preemption and rejection decisions are emitted here, at the
        # point the policy makes them
        self.trace = None

    # -- admission -----------------------------------------------------------------
    def _chain_of(self, state: RequestState):
        """The state's memoized prefix keys — None when sharing is off, so the
        non-sharing configuration pays no hashing at all."""
        if not self.cache.prefix_sharing:
            return None
        return state.hash_chain(self.cache.page_size)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.config.max_batch) if s not in self.running]

    def fits(self, state: RequestState) -> bool:
        # ServeEngine.submit() already rejected any request whose EVENTUAL
        # footprint (pages_for(prompt + max_new_tokens), invariant under
        # preemption/requeue) exceeds max_pages_per_seq, so only page
        # availability is decided here. Only pages the request cannot adopt
        # from the prefix index count against the free list (the state memoizes
        # its hash chain, so a queued request re-checked every step hashes once).
        need = self.cache.new_pages_needed(state.context, chain=self._chain_of(state))
        # no watermark when the batch is empty: an unadmittable head request with
        # nothing running would deadlock, and with no co-tenants there is nothing
        # for decode growth to collide with
        watermark = self.config.watermark_pages if self.running else 0
        return need + watermark <= self.cache.num_free

    def _group_need(self, group: BranchGroup) -> int:
        """Free-list pages a whole branch group needs at admission. Fresh
        siblings fork the primary's pages, so each costs at most ONE fresh page
        (the +1-token decode headroom when the prompt fills its last page, or
        the eventual CoW privatization of a shared partial page — never both at
        once); re-admitted siblings re-prefill their own diverged contexts and
        are costed like any request (their chains re-adopt whatever prefix
        pages survived, including each other's)."""
        need = 0
        for st in group.branches:
            if st.done:
                continue
            if st.await_fork:
                need += 1
            else:
                need += self.cache.new_pages_needed(
                    st.context, chain=self._chain_of(st)
                )
        return need

    def impossible(self, state: RequestState) -> bool:
        """True when this request can NEVER admit: its context needs more pages
        than the whole pool holds even with every page free and no co-tenant.
        Prefix sharing cannot rescue it — adopted pages still occupy the pool,
        and the one-page decode headroom must come from somewhere. Without this
        check such a request sits at the queue head forever, wedging everything
        behind it (fits() keeps returning False each step, the engine keeps
        spinning). The engine fails it with a clear error instead."""
        return (
            self.cache.pages_for(len(state.context) + 1) > self.cache.num_pages - 1
        )

    def reject_impossible(self, queue: RequestQueue) -> List[RequestState]:
        """Pop every queue-head request that impossible() condemns (arrival
        order scans until the first servable head), stamping .error. Covers
        both fresh submissions that slipped past submit()'s static check (a
        preempted request's context GROWS by its generated tokens, so a
        request servable at submit time can outgrow the pool) and keeps FIFO
        semantics for everything behind the failed head."""
        failed = []
        while queue:
            state = queue.peek()
            if not self.impossible(state):
                break
            queue.pop()
            state.error = (
                f"request {state.request.rid} needs "
                f"{self.cache.pages_for(len(state.context) + 1)} pages for its "
                f"{len(state.context)}-token context but the pool only has "
                f"{self.cache.num_pages - 1} — raise num_pages or shorten the request"
            )
            if self.trace is not None:
                self.trace.instant(
                    "reject", rid=state.request.rid,
                    context=len(state.context),
                )
            failed.append(state)
        return failed

    def admit(self, queue: RequestQueue, now: float,
              publish: bool = True) -> List[Tuple[int, RequestState]]:
        """Pop admissible requests, allocate their prompt pages (+1 headroom page
        so the first decode token always has a slot), bind batch slots.
        ``publish=False`` defers prefix-index registration to
        cache.publish_prefix (chunked prefill: pages fill over many steps)."""
        admitted = []
        slots = self.free_slots()
        while queue and slots:
            state = queue.peek()
            if state.request.arrival_time > now:
                break
            group = state.group
            if group is not None:
                # a branch group admits AS A UNIT: one slot per live branch,
                # pages for every re-prefilling member plus fork headroom for
                # the fresh ones — or not at all (partial groups would let a
                # sibling's admission preempt its own primary)
                live = [st for st in group.branches if not st.done]
                watermark = self.config.watermark_pages if self.running else 0
                if (len(slots) < len(live)
                        or self._group_need(group) + watermark > self.cache.num_free):
                    break
                queue.pop()
                group.pending_rows.clear()
                for st in live:
                    slot = slots.pop(0)
                    if not st.await_fork:
                        ctx = st.context
                        self.cache.allocate(
                            slot, self.cache.pages_for(len(ctx) + 1), tokens=ctx,
                            chain=self._chain_of(st), publish=publish,
                        )
                    st.slot = slot
                    st.admit_time = now
                    self.running[slot] = st
                    admitted.append((slot, st))
                continue
            if not self.fits(state):
                break
            queue.pop()
            slot = slots.pop(0)
            ctx = state.context
            self.cache.allocate(
                slot, self.cache.pages_for(len(ctx) + 1), tokens=ctx,
                chain=self._chain_of(state), publish=publish,
            )
            state.slot = slot
            state.admit_time = now
            self.running[slot] = state
            admitted.append((slot, state))
        return admitted

    # -- decode-page guarantee -------------------------------------------------------
    def _preempt_one(self, queue: RequestQueue, keep_slot: int) -> Optional[RequestState]:
        keep_group = (
            self.running[keep_slot].group if keep_slot in self.running else None
        )
        victims = [
            s for s, st in self.running.items()
            if s != keep_slot
            and (keep_group is None or st.group is not keep_group)
        ]
        if not victims:
            return None
        slot = victims[-1]  # most recently admitted
        state = self.running.pop(slot)
        group = state.group
        # a group member's eviction evicts the WHOLE group: its siblings alias
        # its pages (sample) or advance in lockstep with it (beam), so leaving
        # them running would either pin the pages eviction was meant to free or
        # stall the joint step. The group requeues as its primary — re-admission
        # re-prefills every diverged branch and re-forks the fresh ones.
        members = [state]
        if group is not None:
            for s in [s for s, st in list(self.running.items()) if st.group is group]:
                members.append(self.running.pop(s))
            group.pending_rows.clear()
        if self.trace is not None:
            self.trace.instant(
                "preempt", slot, rid=state.request.rid,
                n_preemptions=state.n_preemptions + 1, keep_slot=keep_slot,
                group_size=len(members),
            )
        for st in members:
            if st.slot is not None:
                # preemption as swap: demote the victim's complete pages to
                # the host tier (no-op without one) BEFORE freeing, so
                # re-admission prefetches instead of recomputing prefill
                self.cache.demote_slot(st.slot, self._chain_of(st))
                self.cache.free_slot(st.slot)
            st.release()  # drops the slot AND any mid-prefill chunk cursor
        head = state if group is None else group.primary
        head.n_preemptions += 1
        queue.requeue_front(head)
        return head

    def preempt_slot(self, slot: int, queue: RequestQueue) -> Optional[RequestState]:
        """Targeted eviction of ONE specific slot (the broken-twin recovery
        path: its donor died before covering its adopted pages, so those
        pages hold garbage). Same whole-group semantics as _preempt_one but
        NEVER demotes — garbage pages must not enter the host tier."""
        if slot not in self.running:
            return None
        state = self.running.pop(slot)
        group = state.group
        members = [state]
        if group is not None:
            for s in [s for s, st in list(self.running.items()) if st.group is group]:
                members.append(self.running.pop(s))
            group.pending_rows.clear()
        if self.trace is not None:
            self.trace.instant(
                "preempt", slot, rid=state.request.rid,
                n_preemptions=state.n_preemptions + 1, keep_slot=-1,
                group_size=len(members),
            )
        for st in members:
            if st.slot is not None:
                self.cache.free_slot(st.slot)
            st.release()
        head = state if group is None else group.primary
        head.n_preemptions += 1
        queue.requeue_front(head)
        return head

    def ensure_decode_page(self, slot: int, queue: RequestQueue) -> None:
        """Make sure ``slot`` owns a WRITABLE page covering position lens[slot]
        (where the next token's KV lands): append a page at page boundaries, and
        copy-on-write the target page if prefix sharing left it refcount>1 —
        preempting later arrivals if either needs a page the pool cannot give."""
        pos = int(self.cache.lens[slot])
        while pos >= len(self.cache.pages_of[slot]) * self.cache.page_size:
            if self.cache.append_page(slot):
                continue
            if self._preempt_one(queue, keep_slot=slot) is None:
                raise RuntimeError(
                    "KV pool exhausted with a single running sequence — "
                    "num_pages is too small for this request"
                )
        while self.cache.needs_cow(slot):
            if self.cache.cow_page(slot):
                continue
            # a shared page always has >= 2 holders, so a victim must exist
            if self._preempt_one(queue, keep_slot=slot) is None:
                raise RuntimeError(
                    "KV pool exhausted while copy-on-write needed a page — "
                    "num_pages is too small for this request"
                )

    # -- fused-decode horizon --------------------------------------------------------
    def reserve_decode_tokens(self, slot: int, n_tokens: int) -> bool:
        """Best-effort page pre-append: grow ``slot``'s owned pages until it
        can take ``n_tokens`` MORE tokens beyond lens[slot] with no further
        host intervention — the horizon-aware pre-append that lets a fused (or
        speculative) window prove its whole page budget UP FRONT instead of
        shrinking to whatever the current page has left. Never preempts: a dry
        pool or the per-seq page cap returns False and the caller degrades
        (smaller window / non-speculative path). Appended pages are ordinary
        owned pages — freed with the slot, filled by later decode either way,
        so a failed window wastes nothing."""
        cache = self.cache
        while cache.capacity_tokens(slot) < n_tokens:
            if len(cache.pages_of[slot]) >= cache.max_pages_per_seq:
                return False
            if not cache.append_page(slot):
                return False
        return True

    def event_free_horizon(self, queue: RequestQueue,
                           tokens_per_step: int = 1) -> int:
        """Largest K such that the next K decode steps provably need NO
        scheduler intervention — the precondition for running them as one
        on-device fused loop (make_paged_serve_multistep). A pure function of
        host-mirrored state: no admission (queue must be empty — free pages
        only shrink during decode, so nothing unadmittable becomes admittable
        mid-horizon), every slot DECODING, no CoW pending, and per slot at
        least K steps' worth of both owned page capacity (no page-boundary
        append; reserve_decode_tokens can raise capacity first) and
        max_new_tokens budget (no max-token finish). EOS finishes are NOT
        predictable; a fused window may overrun an EOS by up to K-1 tokens —
        the driver discards them, and the overrun writes stay inside the
        slot's owned pages because K never exceeds its remaining capacity.

        ``tokens_per_step`` is the per-step token footprint: 1 for plain
        decode, K_draft+1 for a speculative window (every window may append
        up to the full present, and the max-new budget must cover a fully
        accepted window — the speculative driver commits at most
        ``remaining`` tokens by the same overrun-discard rule)."""
        if queue or not self.running:
            return 0
        k = 1 << 30
        for slot, state in self.running.items():
            if state.phase != DECODING or self.cache.needs_cow(slot):
                return 0
            if state.group is not None and state.group.mode == "beam":
                # beam steps interleave host-side candidate selection and
                # block-table reorders between decodes — never fusable
                return 0
            capacity = self.cache.capacity_tokens(slot)
            remaining = state.request.max_new_tokens - len(state.generated)
            k = min(k, capacity // tokens_per_step,
                    max(remaining, 0) // tokens_per_step)
        return max(k, 0)

    def finish(self, slot: int) -> RequestState:
        state = self.running.pop(slot)
        self.cache.free_slot(slot)
        state.release()
        return state
