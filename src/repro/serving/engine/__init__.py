"""Continuous-batching serving engine over a paged (LayoutPaged) KV cache.

    engine = ServeEngine(model, params, EngineConfig(num_pages=64, page_size=16))
    h = engine.submit(Request(rid=0, prompt=[...],
                              params=GenerationParams(max_new_tokens=32)))
    results = engine.run()          # rid -> RequestState
    seqs = h.sequences              # per-branch Sequence list (n=1: one entry)
    print(engine.metrics())         # tokens/sec, p50/p99 latency, preemptions
"""
from repro.serving.params import (
    FINISH_EOS,
    FINISH_ERROR,
    FINISH_LENGTH,
    GenerationParams,
    RequestHandle,
    Sequence,
)
from repro.serving.sampling import GREEDY, SamplingParams

from .cache import PagedKVCache
from .engine import EngineConfig, ServeEngine, aligned_max_logit_err
from .kvquant import KV_DTYPES, PagedQuantSpec
from .request import (
    DECODING,
    PREFILLING,
    QUEUED,
    BranchGroup,
    Request,
    RequestQueue,
    RequestState,
)
from .scheduler import Scheduler, SchedulerConfig

from repro.serving.telemetry import (  # noqa: E402  (re-export)
    EngineTrace,
    MetricsRegistry,
    validate_chrome_trace,
)

__all__ = [
    "DECODING",
    "EngineConfig",
    "EngineTrace",
    "GREEDY",
    "MetricsRegistry",
    "SamplingParams",
    "aligned_max_logit_err",
    "BranchGroup",
    "FINISH_EOS",
    "FINISH_ERROR",
    "FINISH_LENGTH",
    "GenerationParams",
    "RequestHandle",
    "Sequence",
    "validate_chrome_trace",
    "KV_DTYPES",
    "PagedQuantSpec",
    "PagedKVCache",
    "PREFILLING",
    "QUEUED",
    "Request",
    "RequestQueue",
    "RequestState",
    "Scheduler",
    "SchedulerConfig",
    "ServeEngine",
]
