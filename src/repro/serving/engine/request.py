"""Requests and the FIFO admission queue for the continuous-batching engine."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serving.sampling import GREEDY, SamplingParams


def page_hash_chain(tokens: Sequence[int], page_size: int) -> List[Tuple]:
    """Chain hashes of page-granular token chunks — the prefix-sharing keys.

    Entry ``i`` identifies the CONTENT of logical page ``i`` given everything
    before it: chaining makes equal keys imply equal full token prefixes, so two
    requests whose chains agree on a leading run can alias those physical pages.
    Full pages hash their page_size chunk; a trailing partial chunk (if any)
    gets a final entry keyed by its exact tokens — two identical prompts share
    even their last, partially filled page (copy-on-write resolves the first
    divergent append). Keys are tuples (not raw ints) so accidental collision
    with user data is impossible; the index lives in-process only.
    """
    chain: List[Tuple] = []
    h: Tuple = ("kv-prefix", page_size)
    n_full = len(tokens) // page_size
    for i in range(n_full):
        h = (hash(h), tuple(int(t) for t in tokens[i * page_size : (i + 1) * page_size]))
        chain.append(h)
    rem = tokens[n_full * page_size :]
    if rem:
        chain.append((hash(h), tuple(int(t) for t in rem), "partial"))
    return chain


@dataclasses.dataclass
class Request:
    """One generation request as submitted by a client."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None
    # token-selection policy, executed on device inside the fused serve step
    # (serving/sampling.py). Default: greedy argmax — the exact-match oracle.
    sampling: SamplingParams = GREEDY
    # top-k logprobs to return per generated token (0 = none). The engine
    # computes them on device and they ride the existing per-token ids fetch;
    # must not exceed EngineConfig.logprobs_k, the compiled width.
    logprobs: int = 0

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.logprobs < 0:
            raise ValueError(f"logprobs must be >= 0, got {self.logprobs}")
        if self.sampling is None:
            self.sampling = GREEDY


# RequestState.phase values — the mixed-step lifecycle. QUEUED -> PREFILLING
# (admitted, context KV materializing chunk by chunk) -> DECODING (context
# resident, one token per step). The monolithic engine never observes
# PREFILLING: it admits and fully prefills in the same step.
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class RequestState:
    """Engine-side lifecycle of a request (survives preemption)."""

    request: Request
    generated: List[int] = dataclasses.field(default_factory=list)
    # generated-token index -> [(token_id, logprob), ...] of the top
    # request.logprobs candidates at that position (empty unless requested).
    # Keyed like logits_of — by token index, not step — so preemption-recompute
    # overwrites deterministically.
    logprobs: Dict[int, List[Tuple[int, float]]] = dataclasses.field(
        default_factory=dict
    )
    slot: Optional[int] = None  # batch slot while running, None while queued
    # chunked prefill: tokens of context whose KV is computed AND resident for
    # the current residency (page-aligned except at completion); None once the
    # prefill completes (or always, in the monolithic engine). Reset by
    # release(): preemption is recompute-style, the cursor does not survive.
    chunk_cursor: Optional[int] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_preemptions: int = 0
    error: Optional[str] = None  # set when the engine fails the request
    # memoized prefix-sharing keys: (page_size, len(context)) -> chain. The
    # context is append-only per request, so its length identifies its content
    # and a queued request re-checked every engine step hashes only once.
    _chain_key: Optional[Tuple[int, int]] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _chain: List[Tuple] = dataclasses.field(
        default_factory=list, repr=False, compare=False
    )

    def hash_chain(self, page_size: int) -> List[Tuple]:
        """Prefix-sharing keys for the context as it would be (re-)prefilled
        now; recomputed only when the context has grown (admission retries while
        queued are O(1))."""
        key = (page_size, len(self.context))
        if self._chain_key != key:
            self._chain_key = key
            self._chain = page_hash_chain(self.context, page_size)
        return self._chain

    @property
    def context(self) -> List[int]:
        """Tokens that must be in the KV cache: prompt + everything generated.
        After preemption this whole sequence is re-prefilled (recompute policy)."""
        return self.request.prompt + self.generated

    @property
    def phase(self) -> str:
        """QUEUED / PREFILLING / DECODING — where the mixed step routes this
        request: a PREFILLING slot receives prefill chunks and is masked out of
        the batched decode; a DECODING slot appends one token per step."""
        if self.slot is None:
            return QUEUED
        return PREFILLING if self.chunk_cursor is not None else DECODING

    def release(self) -> None:
        """Drop residency state on preemption: the slot binding and the chunk
        cursor (recompute policy — a re-admitted request restarts its prefill,
        re-adopting whatever prefix pages survived)."""
        self.slot = None
        self.chunk_cursor = None

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and bool(self.generated) and self.generated[-1] == eos


class RequestQueue:
    """FIFO with front-requeue for preempted requests."""

    def __init__(self):
        self._q: Deque[RequestState] = deque()

    def push(self, state: RequestState) -> None:
        self._q.append(state)

    def requeue_front(self, state: RequestState) -> None:
        self._q.appendleft(state)

    def peek(self) -> Optional[RequestState]:
        return self._q[0] if self._q else None

    def pop(self) -> RequestState:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
