"""Requests, branch groups, and the FIFO admission queue for the engine.

A Request names WHAT to generate from (rid + prompt + arrival time); its
GenerationParams (serving/params.py) names HOW. Requests whose params ask for
parallel generation (n > 1 or beam_width > 0) expand into a BranchGroup of
RequestStates — one per branch — that the scheduler admits and preempts as a
UNIT and whose block-table rows fork one prompt's pages (cache.fork_slot).

Back-compat: the pre-redesign kwargs (``max_new_tokens=``, ``eos_id=``,
``sampling=``, ``logprobs=``) still construct a Request through a shim that
builds the equivalent GenerationParams and emits a DeprecationWarning; the
read-side properties (``request.max_new_tokens`` etc.) remain as plain
delegations and are not deprecated.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence as Seq, Tuple

from repro.serving.params import (
    FINISH_EOS,
    FINISH_LENGTH,
    GenerationParams,
    Sequence,
)
from repro.serving.sampling import SamplingParams


def page_hash_chain(tokens: Seq[int], page_size: int) -> List[Tuple]:
    """Chain hashes of page-granular token chunks — the prefix-sharing keys.

    Entry ``i`` identifies the CONTENT of logical page ``i`` given everything
    before it: chaining makes equal keys imply equal full token prefixes, so two
    requests whose chains agree on a leading run can alias those physical pages.
    Full pages hash their page_size chunk; a trailing partial chunk (if any)
    gets a final entry keyed by its exact tokens — two identical prompts share
    even their last, partially filled page (copy-on-write resolves the first
    divergent append). Keys are tuples (not raw ints) so accidental collision
    with user data is impossible; the index lives in-process only.
    """
    chain: List[Tuple] = []
    h: Tuple = ("kv-prefix", page_size)
    n_full = len(tokens) // page_size
    for i in range(n_full):
        h = (hash(h), tuple(int(t) for t in tokens[i * page_size : (i + 1) * page_size]))
        chain.append(h)
    rem = tokens[n_full * page_size :]
    if rem:
        chain.append((hash(h), tuple(int(t) for t in rem), "partial"))
    return chain


_LEGACY_SENTINEL = object()


class Request:
    """One generation request as submitted by a client: identity (rid), prompt,
    arrival time, and a GenerationParams policy record."""

    def __init__(self, rid: int, prompt: Seq[int],
                 params: Optional[GenerationParams] = None, *,
                 arrival_time: float = 0.0,
                 max_new_tokens=_LEGACY_SENTINEL, eos_id=_LEGACY_SENTINEL,
                 sampling=_LEGACY_SENTINEL, logprobs=_LEGACY_SENTINEL):
        legacy = {
            k: v for k, v in (
                ("max_new_tokens", max_new_tokens), ("eos_id", eos_id),
                ("sampling", sampling), ("logprobs", logprobs),
            ) if v is not _LEGACY_SENTINEL
        }
        if isinstance(params, int):
            # pre-redesign positional call: Request(rid, prompt, max_new_tokens)
            legacy.setdefault("max_new_tokens", params)
            params = None
        if legacy:
            if params is not None:
                raise ValueError(
                    "pass either params=GenerationParams(...) or the legacy "
                    f"kwargs {sorted(legacy)}, not both"
                )
            warnings.warn(
                f"Request kwargs {sorted(legacy)} are deprecated — pass "
                "params=GenerationParams(...) instead",
                DeprecationWarning, stacklevel=2,
            )
            params = GenerationParams.from_legacy(**legacy)
        self.rid = int(rid)
        self.prompt = [int(t) for t in prompt]
        self.params = params if params is not None else GenerationParams()
        self.arrival_time = float(arrival_time)
        if not self.prompt:
            raise ValueError("empty prompt")

    # plain delegations — the read surface the engine/scheduler/tests use
    @property
    def max_new_tokens(self) -> int:
        return self.params.max_new_tokens

    @property
    def eos_id(self) -> Optional[int]:
        return self.params.eos_id

    @property
    def sampling(self) -> SamplingParams:
        return self.params.sampling

    @property
    def logprobs(self) -> int:
        return self.params.logprobs

    def __repr__(self):
        return (
            f"Request(rid={self.rid}, prompt=<{len(self.prompt)} tokens>, "
            f"params={self.params})"
        )


# RequestState.phase values — the mixed-step lifecycle. QUEUED -> PREFILLING
# (admitted, context KV materializing chunk by chunk — or, for a branch-group
# sibling, awaiting the fork of its primary's pages) -> DECODING (context
# resident, one token per step). The monolithic engine only observes
# PREFILLING on awaiting siblings: it admits and fully prefills in one step.
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class RequestState:
    """Engine-side lifecycle of one BRANCH of a request (survives preemption).
    A plain n=1 request is a single branch with no group."""

    request: Request
    generated: List[int] = dataclasses.field(default_factory=list)
    # generated-token index -> [(token_id, logprob), ...] of the top
    # request.logprobs candidates at that position (empty unless requested).
    # Keyed like logits_of — by token index, not step — so preemption-recompute
    # overwrites deterministically.
    logprobs: Dict[int, List[Tuple[int, float]]] = dataclasses.field(
        default_factory=dict
    )
    slot: Optional[int] = None  # batch slot while running, None while queued
    # chunked prefill: tokens of context whose KV is computed AND resident for
    # the current residency (page-aligned except at completion); None once the
    # prefill completes (or always, in the monolithic engine). Reset by
    # release(): preemption is recompute-style, the cursor does not survive.
    chunk_cursor: Optional[int] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_preemptions: int = 0
    error: Optional[str] = None  # set when the engine fails the request
    # parallel generation: which branch of which group this state is (branch 0
    # of a group is the PRIMARY — it prefills the prompt; siblings fork its
    # pages). None/0 for ordinary single-branch requests.
    group: Optional["BranchGroup"] = None
    branch: int = 0
    # True while a fresh sibling waits (slot bound, no pages) for its primary's
    # prefill to complete so it can fork the prompt pages — masked out of both
    # the chunk scheduler and the batched decode meanwhile
    await_fork: bool = False
    # beam search: True while this branch's top candidates sit in the group's
    # pending_rows awaiting the JOINT selection (re-admitted branches finish
    # their recompute prefills on different steps under chunked prefill) —
    # masked out of decode like await_fork, but with pages resident
    hold: bool = False
    # why generation stopped: "eos" | "length" | "error" (params.FINISH_*);
    # None while running. Replaces the old implicit hit-max-tokens inference.
    finish_reason: Optional[str] = None
    # sum of log P(token | prefix) over generated tokens (the per-branch score
    # best-of-n ranks by; beam search maintains it through its own candidates)
    cum_logprob: float = 0.0
    # constrained decoding: the branch's GLOBAL grammar-state id inside the
    # engine's stacked mask/transition tables (None = unconstrained). The host
    # mirror of the device-resident per-slot state vector.
    grammar_state: Optional[int] = None
    # memoized prefix-sharing keys: (page_size, len(context)) -> chain. The
    # context is append-only per request, so its length identifies its content
    # and a queued request re-checked every engine step hashes only once.
    _chain_key: Optional[Tuple[int, int]] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _chain: List[Tuple] = dataclasses.field(
        default_factory=list, repr=False, compare=False
    )

    def hash_chain(self, page_size: int) -> List[Tuple]:
        """Prefix-sharing keys for the context as it would be (re-)prefilled
        now; recomputed only when the context has grown (admission retries while
        queued are O(1))."""
        key = (page_size, len(self.context))
        if self._chain_key != key:
            self._chain_key = key
            self._chain = page_hash_chain(self.context, page_size)
        return self._chain

    @property
    def context(self) -> List[int]:
        """Tokens that must be in the KV cache: prompt + everything generated.
        After preemption this whole sequence is re-prefilled (recompute policy)."""
        return self.request.prompt + self.generated

    @property
    def sampling(self) -> SamplingParams:
        """The branch's EFFECTIVE sampling policy: branch b draws from the
        stream of seed + b, so a branch is token-exact with a serial n=1
        request submitted with that seed (and the same rid)."""
        sp = self.request.sampling
        if self.branch:
            sp = dataclasses.replace(sp, seed=sp.seed + self.branch)
        return sp

    @property
    def phase(self) -> str:
        """QUEUED / PREFILLING / DECODING — where the mixed step routes this
        request: a PREFILLING slot receives prefill chunks (or, awaiting a
        group fork, nothing) and is masked out of the batched decode; a
        DECODING slot appends one token per step."""
        if self.slot is None:
            return QUEUED
        return (
            PREFILLING
            if (self.chunk_cursor is not None or self.await_fork or self.hold)
            else DECODING
        )

    def release(self) -> None:
        """Drop residency state on preemption: the slot binding and the chunk
        cursor (recompute policy — a re-admitted request restarts its prefill,
        re-adopting whatever prefix pages survived). A fresh sibling goes back
        to awaiting its fork; a started one re-prefills its own context."""
        self.slot = None
        self.chunk_cursor = None
        self.hold = False
        self.await_fork = self.group is not None and self.branch > 0 and not self.generated

    @property
    def done(self) -> bool:
        if self.finish_reason is not None:
            return True
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and bool(self.generated) and self.generated[-1] == eos

    def finished_reason(self) -> str:
        """The reason ``done`` holds (records it if not yet stamped)."""
        if self.finish_reason is None:
            eos = self.request.eos_id
            self.finish_reason = (
                FINISH_EOS if eos is not None and self.generated
                and self.generated[-1] == eos else FINISH_LENGTH
            )
        return self.finish_reason

    def own_sequence(self) -> Sequence:
        return Sequence(
            tokens=list(self.generated),
            logprobs=dict(self.logprobs),
            cumulative_logprob=self.cum_logprob,
            finish_reason=self.finish_reason,
        )

    @property
    def sequences(self) -> List[Sequence]:
        """The request's per-branch results — a one-element list for plain
        n=1 requests, the group's branches (or surviving beam hypotheses)
        otherwise. This is the ONE results surface; the engine's results dict
        maps rid -> the primary state, and everything per-branch lives here."""
        if self.group is not None:
            return self.group.sequences()
        return [self.own_sequence()]


class BranchGroup:
    """N branches of one request, admitted/preempted as a unit and aliasing one
    prompt's pages. mode "sample" (best-of-n: branches decode independently on
    forked streams) or "beam" (joint per-step candidate selection + block-table
    row reorder)."""

    def __init__(self, request: Request):
        self.request = request
        self.mode = "beam" if request.params.beam_width else "sample"
        n = request.params.n_branches
        self.branches: List[RequestState] = [
            RequestState(request, group=self, branch=b, await_fork=b > 0)
            for b in range(n)
        ]
        # beam search: hypotheses that reached eos (moved out of the live
        # branches), as finished Sequence records ranked by cumulative_logprob
        self.finished: List[Sequence] = []
        # beam re-admission: per-branch top-k candidate rows collected while
        # the group's branches finish their recompute prefills; the beam step
        # resumes once every live branch has reported
        self.pending_rows: Dict[int, Tuple] = {}

    @property
    def primary(self) -> RequestState:
        return self.branches[0]

    @property
    def n_branches(self) -> int:
        return len(self.branches)

    @property
    def all_done(self) -> bool:
        return all(st.done for st in self.branches)

    def sequences(self) -> List[Sequence]:
        if self.mode == "beam":
            ranked = sorted(
                self.finished, key=lambda s: -s.cumulative_logprob
            )
            return ranked[: self.request.params.n]
        return [st.own_sequence() for st in self.branches]


class RequestQueue:
    """FIFO with front-requeue for preempted requests."""

    def __init__(self):
        self._q: Deque[RequestState] = deque()

    def push(self, state: RequestState) -> None:
        self._q.append(state)

    def requeue_front(self, state: RequestState) -> None:
        self._q.appendleft(state)

    def peek(self) -> Optional[RequestState]:
        return self._q[0] if self._q else None

    def pop(self) -> RequestState:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
