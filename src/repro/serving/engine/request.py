"""Requests and the FIFO admission queue for the continuous-batching engine."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence


@dataclasses.dataclass
class Request:
    """One generation request as submitted by a client."""

    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class RequestState:
    """Engine-side lifecycle of a request (survives preemption)."""

    request: Request
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None  # batch slot while running, None while queued
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_preemptions: int = 0

    @property
    def context(self) -> List[int]:
        """Tokens that must be in the KV cache: prompt + everything generated.
        After preemption this whole sequence is re-prefilled (recompute policy)."""
        return self.request.prompt + self.generated

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and bool(self.generated) and self.generated[-1] == eos


class RequestQueue:
    """FIFO with front-requeue for preempted requests."""

    def __init__(self):
        self._q: Deque[RequestState] = deque()

    def push(self, state: RequestState) -> None:
        self._q.append(state)

    def requeue_front(self, state: RequestState) -> None:
        self._q.appendleft(state)

    def peek(self) -> Optional[RequestState]:
        return self._q[0] if self._q else None

    def pop(self) -> RequestState:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
