"""ServeEngine: continuous-batching generation over a paged KV cache.

One engine step is a MIXED step: (admit newcomers) then (one prefill chunk for
each PREFILLING sequence, token-budgeted) then (one batched decode step for
every DECODING sequence). Sequences enter and leave the batch at arbitrary
steps (continuous batching): a fixed-size slot vector keeps the decode
computation at one compiled shape, and per-slot positions (context_lens) +
block-table rows carry each sequence's own state into decode_step_paged — the
LayoutPaged path.

Invariants the step loop maintains per running slot:
  - DECODING: cache.lens[slot] == len(state.context) - 1 — every context token
    EXCEPT the newest generated one has its KV in the pool; the decode input is
    state.generated[-1]; its KV is written at position lens[slot] during the
    step (LayoutPaged: page table[lens//ps], slot lens%ps); and the slot owns a
    WRITABLE page covering position lens[slot]: the scheduler appends a page at
    page boundaries and copy-on-write-privatizes it when prefix sharing left it
    refcount>1 (preempting later arrivals when the pool runs dry), so the
    decode scatter never lands in a page another sequence still reads.
  - PREFILLING (chunked mode): cache.lens[slot] == state.chunk_cursor — the
    page-aligned count of context tokens whose KV is computed and resident.
    Each mixed step advances the cursor by one chunk (formally: the engine
    executes the submdspan [cursor, cursor + chunk) of the sequence's paged
    view — cache.chunk_view); the slot is masked out of the batched decode
    (null table row, length 0, so its lockstep "write" lands in the null page).

Prefill comes in two regimes:
  - monolithic (chunked_prefill=False, the pre-mixed-step behavior): a newly
    admitted request prefills at batch 1 on its full padded length, one compile
    per page bucket, stalling the step for the whole prompt;
  - chunked (chunked_prefill=True): the prompt advances chunk_tokens per step
    through ONE compiled chunk step (cursor traced — every chunk position and
    every prompt length share the compile), interleaved with decode so
    long prompts stop freezing the batch. A per-step token quota splits the
    step between decode appends and chunks; chunk boundaries are page-aligned
    so a chunk-written page is bit-compatible with a monolithic one (the last
    chunk computes the same zero-pad tail a monolithic prefill would).
    When prefix sharing finds the prompt's leading pages resident, the first
    chunk starts at the last whole page boundary before the first non-shared
    token: the shared pages' COMPUTE is skipped, not just their storage
    (metrics: prefill_tokens_skipped). KV is a pure per-token function of
    token ids and absolute position, so the adopted pages already hold
    exactly what this prompt's prefill would write.

The decode hot path is DEVICE-RESIDENT: block tables and lengths live in
persistent device mirrors beside the page pools (PagedKVCache.device_state —
allocator events patch single rows, routine appends advance lengths on device),
token selection (serving/sampling.py: greedy/temperature/top-k/top-p) is fused
into the serve step so logits never cross to the host, and the host loop splits
into an event-driven scheduler tick (admission, page appends, CoW, sweeping)
and a device-loop driver (_decode_once) whose only per-token D2H traffic is the
(B,) sampled ids. Over a scheduler-proven event-free horizon the driver runs
``multi_step`` iterations in ONE on-device lax.scan (append -> attend ->
sample -> feed back), amortizing dispatch over K tokens — token-exact vs K=1
because sampling folds absolute positions, never steps or slots.

Quantization (``kv_dtype`` int8/int4, kvquant.PagedQuantSpec) composes with
both regimes: prefill chunks quantize at scatter time page-by-page with the
same whole-page scale law as monolithic prefill. Preemption is recompute-style
in both regimes: pages are dropped (mid-prefill chunks included), and the full
context (prompt + generated so far) is re-prefilled on re-admission, which
under greedy decoding reproduces the identical continuation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.runtime.health import StragglerPolicy
from repro.serving.params import (
    FINISH_EOS,
    FINISH_ERROR,
    FINISH_LENGTH,
    GenerationParams,
    RequestHandle,
    Sequence as SequenceResult,
)
from repro.serving.sampling import pack_slot_params, stream_seed
from repro.serving.speculative import (
    NGramProposer,
    make_paged_serve_spec_multistep,
)
from repro.serving.step import (
    make_chunked_prefill_step,
    make_paged_serve_multistep,
    make_paged_serve_step,
    make_prefill,
    top_logprobs,
)
from repro.serving.telemetry import EngineTrace, MetricsRegistry

from .cache import PagedKVCache
from .request import (
    DECODING,
    PREFILLING,
    BranchGroup,
    Request,
    RequestQueue,
    RequestState,
)
from .scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_pages: int = 64
    page_size: int = 16
    max_batch: int = 8
    max_pages_per_seq: int = 16
    watermark_pages: int = 1
    attn_impl: str = "auto"  # "pallas" | "jnp" | "auto" — ops.paged_decode_attention
    prefix_sharing: bool = True  # dedupe common prompt prefixes onto shared pages
    kv_dtype: str = "f32"  # "f32" | "int8" | "int4" — KV page representation
    # (kvquant.PagedQuantSpec): same pages/tables/admission, ~4x/~8x fewer bytes
    record_logits: bool = False  # keep per-step logits rows (ServeEngine.logits_of)
    # for cross-engine accuracy audits (e.g. int8 vs f32 max-logit-error).
    # OPT-IN SLOW PATH: the fused step normally samples on device and logits
    # never cross to the host; recording fetches the full (B, vocab) rows each
    # step and disables the multi-step fused loop
    multi_step: int = 1  # fused decode horizon K: when the scheduler proves the
    # next K steps event-free (no admission/page-append/CoW/max-token finish —
    # Scheduler.event_free_horizon), run them as ONE on-device lax.scan loop:
    # append -> attend -> sample -> feed back, amortizing a dispatch and a
    # (K, B) ids fetch over K tokens. 1 = off; token-exact for any K
    spec_tokens: int = 0  # speculative decoding draft length K (0 = off):
    # each decode step becomes a WINDOW — an n-gram table over the request's
    # own context proposes K tokens, ONE chunk-style verify pass scores all of
    # them against the paged cache, and the longest agreeing prefix (+1
    # correction/bonus token) commits. Rejection is pure lens arithmetic —
    # no page frees, no host work (serving/speculative.py). GREEDY requests
    # are token-exact vs spec_tokens=0 (CI pins it); per-request opt-out via
    # GenerationParams.speculative=False. Windows fuse multi_step-at-a-time
    # under the same event-free-horizon contract as plain fused decode,
    # with tokens_per_step = K+1
    spec_ngram: int = 2  # n-gram order of the draft lookup key
    spec_table_size: int = 512  # n-gram hash buckets per slot (power of two)
    spec_accept_floor: float = 2.0  # adaptive backoff: a verify window costs
    # ~2x a plain decode step (C=K+1 positions through the chunk kernel plus
    # window host accounting), so speculation only pays while the mean
    # accepted-tokens-per-window clears this floor. The engine keeps an EMA of
    # per-dispatch acceptance; when it dips under the floor the planner runs
    # plain decode for spec_backoff dispatches, then re-probes — repetitive
    # streams keep full-window speed, incompressible streams pay only the
    # occasional probe instead of a per-step verify tax. Consecutive
    # under-floor probes DOUBLE the wait (capped at 32x spec_backoff; an
    # above-floor probe resets it), so a stream that stays incompressible
    # converges to ~zero verify overhead. 0 disables backoff
    spec_backoff: int = 32  # base plain-dispatch count between re-probes
    chunked_prefill: bool = False  # mixed steps: page-sized prefill chunks
    # interleaved with decode instead of monolithic batch-1 prefills
    chunk_tokens: int = 0  # max tokens per prefill chunk (page multiple; 0 =
    # auto: 2 pages). Chunks dispatch at the smallest power-of-two-of-page-size
    # bucket >= their real length, so a short prompt never pays a full-width
    # chunk step — one compile per bucket, O(log(chunk_tokens/page_size)) total
    step_token_quota: int = 0  # per-step token budget split across decode
    # appends + prefill chunks (0 = auto: max_batch + chunk_tokens)
    prefill_compute_skip: bool = True  # start a shared-prefix request's first
    # chunk past the adopted pages (skip their COMPUTE, not just their storage);
    # effective only with chunked_prefill + prefix_sharing
    trace: bool = False  # record lifecycle events (serving/telemetry.EngineTrace):
    # enqueue/admit/chunk/CoW/preempt/fused-window/finish, exportable as Chrome
    # trace JSON (ServeEngine.trace.export -> Perfetto). Off: every emission
    # site is one `is None` check. On: host appends at engine EVENTS only —
    # the per-token D2H budget of the fused step is untouched
    trace_capacity: int = 65536  # trace ring-buffer events before wrap
    logprobs_k: int = 0  # compile-time top-k logprob width of the fused step.
    # 0 compiles the identical step as before the feature; > 0 lets requests
    # opt in (Request.logprobs <= this) to per-token top-k logprobs that ride
    # the existing ids fetch
    max_beam_width: int = 0  # widest beam_width a request may ask for. Beam
    # candidates come from the fused step's top-k logprob pair, so this widens
    # the compile-time logprob width to max_beam_width + 1 (the +1 guarantees
    # enough non-eos continuations even when every branch's top candidate is
    # eos — eos is ONE token id, so at most one of any row's top entries is it)
    grammar_states: int = 0  # grammar-table rows reserved for constrained
    # decoding (sum of TokenDFA.n_states over every grammar registered with
    # this engine). The mask/transition tables compile at the FIXED shape
    # (1 + grammar_states, vocab) — row 0 is the reserved unconstrained state —
    # so registering a grammar never recompiles the fused step; 0 compiles the
    # identical step as before the feature
    slow_step_threshold: float = 2.0  # decode steps slower than this multiple
    # of the per-token EMA (runtime/health.StragglerPolicy) count as slow:
    # trace event + `slow_steps` counter
    autotune: bool = False  # consult kernels/autotune.py at engine init: fill
    # any block-shape field left at its auto sentinel (page_size=0 via
    # sized_for, decode_block_pages=0, chunk_tokens=0) from the disk-cached
    # tuning table for (model, kv_dtype, batch bucket), sweeping once on a
    # cache miss. Explicitly-set fields are never overridden; the decision is
    # surfaced in metrics() and as a `tuning_selected` trace instant
    decode_block_pages: int = 0  # pages per decode-kernel compute block
    # (paged_attention block_pages). 0 = auto: tuned when autotune is on,
    # unblocked (the pre-knob schedule) otherwise; > 0 pins the value
    sized_max_len: int = 0  # the max_len sized_for() was called with (0 when
    # the pool was sized by hand); lets autotune re-derive the pool extents
    # when page_size itself is deferred to the tuner
    host_pool_pages: int = 0  # host-RAM page tier capacity (README
    # "Hierarchical KV"). 0 = no tier (identical engine to before the
    # feature); > 0 turns preemption into swap-out and re-admission into
    # prefetch: demoted pages live host-side under their prefix-chain keys,
    # so resumable-session capacity scales with host RAM, not HBM. Requires
    # prefix_sharing (the tier is a content-keyed index)
    swap_budget_pages_per_step: int = 0  # per-step HBM<->host migration
    # allowance, shared by demotions and promotions (0 = unlimited). Keeps
    # swap traffic from starving a step; overflow truncates a run's TAIL, and
    # a shorter warm prefix is still a valid prefix
    retain_finished_s: float = 0.0  # on finish, demote a request's pages to
    # the host tier and retain them for this many seconds (session resume: a
    # follow-up sharing the context prefetches instead of re-prefilling).
    # Retained pages are evicted deadline-first, then LRU; 0 = don't retain

    @classmethod
    def sized_for(cls, max_len: int, *, page_size: int, max_batch: int,
                  **kw) -> "EngineConfig":
        """Pool sized so max_batch sequences of ``max_len`` tokens (prompt + new)
        can run with no contention: per-seq pages cover max_len plus the one-page
        decode headroom, and the pool adds the reserved null page 0.

        ``page_size=0`` defers the page size to the autotuner (requires
        autotune=True): pool sizing then happens at engine init, after the
        tuning table has been consulted, from the stored ``sized_max_len``."""
        if page_size == 0:
            if not kw.get("autotune"):
                raise ValueError("page_size=0 requires autotune=True")
            return cls(
                num_pages=0, page_size=0, max_batch=max_batch,
                max_pages_per_seq=0, sized_max_len=max_len, **kw,
            )
        pages_per_seq = -(-max_len // page_size) + 1
        return cls(
            num_pages=max_batch * pages_per_seq + 1,
            page_size=page_size,
            max_batch=max_batch,
            max_pages_per_seq=pages_per_seq,
            sized_max_len=max_len,
            **kw,
        )


def aligned_max_logit_err(eng_ref, eng, results_ref, results) -> float:
    """Max |logit difference| between two record_logits engines over steps
    where both saw the SAME context: per request, every step up to and
    including the first divergent generated token (those logits were computed
    on identical prefixes, so the comparison stays meaningful after greedy
    trajectories split). The accuracy metric the quantized-KV CI gate bounds."""
    errs = [0.0]
    for rid, s_ref in results_ref.items():
        a, b = s_ref.generated, results[rid].generated
        n_cmp = min(len(a), len(b))
        div = next((i for i in range(n_cmp) if a[i] != b[i]), n_cmp - 1)
        for n in range(div + 1):
            errs.append(float(np.max(np.abs(
                eng_ref.logits_of[rid][n] - eng.logits_of[rid][n]
            ))))
    return max(errs)


def _apply_tuning(config: EngineConfig, tuned) -> EngineConfig:
    """Fill every auto-sentinel block-shape field of ``config`` from a
    TunedPoint; explicitly-set fields win. page_size=0 (sized_for deferral)
    re-derives the pool extents from sized_max_len at the tuned page size."""
    kw = {}
    if config.page_size == 0:
        if not config.sized_max_len:
            raise ValueError(
                "page_size=0 needs EngineConfig.sized_for (sized_max_len unset)"
            )
        ps = tuned.page_size
        pps = -(-config.sized_max_len // ps) + 1
        kw.update(
            page_size=ps,
            max_pages_per_seq=pps,
            num_pages=config.max_batch * pps + 1,
        )
    if config.decode_block_pages == 0:
        kw["decode_block_pages"] = tuned.block_pages
    if config.chunked_prefill and config.chunk_tokens == 0:
        kw["chunk_tokens"] = tuned.chunk_tokens
    return dataclasses.replace(config, **kw) if kw else config


class ServeEngine:
    def __init__(self, model, params, config: EngineConfig = EngineConfig(),
                 mesh=None, rules=None):
        self.model = model
        self.params = params
        # autotune: resolve block shapes BEFORE the pool is sized — a deferred
        # page_size (sized_for(..., page_size=0)) materializes here. Warm path
        # (tuning table hit) is a pure file read; the sweep runs once per
        # (model, kv_dtype, batch bucket) per cache file.
        self.tuned = None
        if config.autotune:
            from repro.kernels import autotune as _autotune

            self.tuned = _autotune.resolve(
                model.cfg, kv_dtype=config.kv_dtype, batch=config.max_batch,
                seq_len=config.sized_max_len,
                page_size=config.page_size or None,
            )
            config = _apply_tuning(config, self.tuned)
        self.config = config
        if config.host_pool_pages and not config.prefix_sharing:
            raise ValueError(
                "host_pool_pages requires prefix_sharing: the host tier is a "
                "content-keyed index over the same page-hash chains"
            )
        self.cache = PagedKVCache(
            model,
            num_pages=config.num_pages,
            page_size=config.page_size,
            max_batch=config.max_batch,
            max_pages_per_seq=config.max_pages_per_seq,
            prefix_sharing=config.prefix_sharing,
            kv_dtype=config.kv_dtype,
            host_pool_pages=config.host_pool_pages,
            swap_budget_pages_per_step=config.swap_budget_pages_per_step,
        )
        self.scheduler = Scheduler(
            self.cache, SchedulerConfig(config.max_batch, config.watermark_pages)
        )
        self.queue = RequestQueue()
        self._pending: List[RequestState] = []  # submitted, not yet arrived
        self._mesh, self._rules = mesh, rules
        # telemetry: one trace shared by engine/scheduler/allocator (None =
        # off, every emission site a single check), one metrics registry
        # backing metrics() with O(1)-memory sketches
        self.trace = EngineTrace(config.trace_capacity) if config.trace else None
        self.cache.trace = self.trace
        self.scheduler.trace = self.trace
        if self.trace is not None and self.tuned is not None:
            # the tuning decision is an engine event like any other: observable
            # in the exported trace, not a silent constant baked into the jit
            self.trace.instant(
                "tuning_selected",
                page_size=config.page_size,
                block_pages=config.decode_block_pages,
                chunk_tokens=config.chunk_tokens,
                source=self.tuned.source,
            )
        self.registry = MetricsRegistry()
        self._h_step = self.registry.histogram("step_time_s")
        self._h_host = self.registry.histogram("host_overhead_s")
        self._h_chunk = self.registry.histogram("chunk_time_s")
        self._c_decode = self.registry.counter("decode_steps")
        self._c_fused = self.registry.counter("fused_steps")
        self._c_pf_computed = self.registry.counter("prefill_tokens_computed")
        self._c_pf_skipped = self.registry.counter("prefill_tokens_skipped")
        self._c_slow = self.registry.counter("slow_steps")
        self._last_step_time: Optional[float] = None  # fused-horizon estimate
        self._straggler = StragglerPolicy(threshold=config.slow_step_threshold)
        # beam search selects from the fused step's top-k logprob pair, so the
        # compiled width covers max_beam_width + 1 (+1: eos is one token id, so
        # at most one top entry per row is eos and W non-eos continuations
        # always exist)
        self._lp_k = max(
            0, int(config.logprobs_k),
            (config.max_beam_width + 1) if config.max_beam_width else 0,
        )
        vocab = model.cfg.vocab
        # constrained decoding: one stacked mask row + transition row per
        # GLOBAL grammar state, row 0 the reserved unconstrained state (zero
        # mask, self-loop). FIXED shape (1 + grammar_states, vocab): grammar
        # registration rewrites table CONTENT (one upload), never the compiled
        # step. Per-slot states live in a device vector the fused step advances
        # itself (donated, like the lens mirror); the host replays the same
        # transitions on its own copy of the tables.
        self._grammar_on = config.grammar_states > 0
        if self._grammar_on:
            n_rows = 1 + config.grammar_states
            self._gmask_host = np.zeros((n_rows, vocab), np.float32)
            self._gtrans_host = np.zeros((n_rows, vocab), np.int32)
            self._gmask_dev = jnp.asarray(self._gmask_host)
            self._gtrans_dev = jnp.asarray(self._gtrans_host)
            self._gstate_dev = jnp.zeros((config.max_batch,), jnp.int32)
            self._grammars: Dict[int, int] = {}  # id(dfa) -> global row offset
            self._grammar_refs: List[object] = []  # keep registrants alive
            self._grammar_used = 0
        # fused step: sample on device, advance lens on device; donate the page
        # pools, the fed-back token vector, the lens mirror — and the grammar
        # state vector when constrained decoding is compiled in — so the step
        # mutates them in place. Tables are NOT donated — the device mirror is
        # persistent and only patched by allocator events (cache.device_state).
        step_donate = (1, 2, 4) + ((7,) if self._grammar_on else ())
        self._block_pages = config.decode_block_pages or None
        self._step = jax.jit(
            make_paged_serve_step(
                model, mesh, rules, attn_impl=config.attn_impl,
                kv_spec=self.cache.kv_spec, vocab=vocab,
                logprobs_k=self._lp_k, grammar=self._grammar_on,
                block_pages=self._block_pages,
            ),
            donate_argnums=step_donate,
        )
        # multi-step fused loop (one compile: only exactly-K windows fuse).
        # record_logits needs per-step rows on the host, so it forces K = 1.
        self._k = 1 if config.record_logits else max(1, int(config.multi_step))
        if self._k > 1:
            self._multistep = jax.jit(
                make_paged_serve_multistep(
                    model, self._k, mesh, rules, attn_impl=config.attn_impl,
                    kv_spec=self.cache.kv_spec, vocab=vocab,
                    logprobs_k=self._lp_k, grammar=self._grammar_on,
                    block_pages=self._block_pages,
                ),
                donate_argnums=step_donate,
            )
        # speculative decoding (serving/speculative.py): the window step is a
        # SIBLING of the fused multistep — same donation discipline (pools,
        # fed-back tokens, lens mirror; tables NOT donated), plus the
        # proposer's two persistent per-slot device arrays (hist, table)
        # donated and flowed back exactly like the lens mirror. Host rebuilds
        # of individual rows happen only on slot-composition events
        # (_spec_stale), mirroring _sync_slot_state.
        self._spec_k = int(config.spec_tokens)
        if self._spec_k:
            if config.record_logits:
                raise ValueError(
                    "spec_tokens does not compose with record_logits: "
                    "recording needs per-step host logits rows, but the "
                    "speculative window never materializes them off device"
                )
            self._spec_windows = max(1, int(config.multi_step))
            # hist must cover every legal position plus one full window past
            # it, so the in-scan history write never clamps for active rows
            hist_len = (
                config.max_pages_per_seq * config.page_size
                + self._spec_k + 2
            )
            self._proposer = NGramProposer(
                spec_tokens=self._spec_k, ngram=config.spec_ngram,
                table_size=config.spec_table_size, vocab=vocab,
                hist_len=hist_len,
            )
            self._spec_step = jax.jit(
                make_paged_serve_spec_multistep(
                    model, self._spec_windows, self._proposer, mesh, rules,
                    attn_impl=config.attn_impl, kv_spec=self.cache.kv_spec,
                    vocab=vocab, logprobs_k=self._lp_k,
                ),
                donate_argnums=(1, 2, 4, 7, 8),
            )
            self._hist_dev = jnp.zeros((config.max_batch, hist_len), jnp.int32)
            self._table_dev = jnp.zeros(
                (config.max_batch, config.spec_table_size + 1), jnp.int32
            )
            self._spec_stale: set = set()
            # adaptive backoff state (spec_accept_floor / spec_backoff):
            # EMA of per-dispatch mean accepted-tokens-per-window, the plain
            # dispatches left before the next speculative re-probe, and the
            # current (exponentially grown) backoff length
            self._spec_accept_ema: float = None
            self._spec_backoff_left = 0
            self._spec_backoff_len = int(config.spec_backoff)
            self._c_spec_windows = self.registry.counter("spec_windows")
            self._c_spec_backoffs = self.registry.counter("spec_backoffs")
            self._c_spec_accepted = self.registry.counter(
                "spec_accepted_tokens"
            )
            self._c_spec_hits = self.registry.counter("spec_draft_hits")
            self._c_spec_rollback = self.registry.counter(
                "spec_rollback_tokens"
            )
        if self._lp_k:
            # prefill first tokens sample from a single (Vp,) logits row; the
            # same row yields its top-k logprobs on device, fetched with the
            # chosen id (no extra sync — the id fetch already blocks)
            self._row_logprobs = jax.jit(
                lambda row: top_logprobs(row[None], vocab, self._lp_k)
            )

        # single-row sampler for prefill first tokens: the (vocab,) logits row
        # stays on device; only the chosen id (+ its unmasked logprob, the
        # cumulative-score increment) crosses to the host. Policy rides in two
        # packed vectors (f32 [temp, top_p], i32 [top_k, seed-bits, pos]) —
        # two device_puts per prefill token, not five scalar ones. The masked
        # variant adds the slot's grammar mask row (constrained first tokens).
        def _row_sample(row, f, i, mask=None):
            tok = ops.sample_tokens(
                row[None], f[0:1], i[0:1], f[1:2],
                i[1:2].astype(jnp.uint32), i[2:3], vocab=vocab, mask=mask,
            )[0]
            lp = jax.nn.log_softmax(row[:vocab].astype(jnp.float32))
            return tok, lp[tok]

        self._sample_row = jax.jit(_row_sample)
        self._sample_row_masked = jax.jit(
            lambda row, f, i, m: _row_sample(row, f, i, m[None])
        )
        # per-slot device vectors for the fused step: fed-back tokens + the
        # packed policy/phase arrays (slot_f32 (2, B): temperature, top_p;
        # slot_i32 (3, B): active bitmap, top_k, seed-bits). Rebuilt — three
        # small uploads — only when slot composition changes; in steady state
        # the previous step's device outputs flow straight back in.
        self._tokens_dev = jnp.zeros((config.max_batch,), jnp.int32)
        f32p, i32p = pack_slot_params({}, config.max_batch)
        self._slot_f32 = jnp.asarray(f32p)
        self._slot_i32 = jnp.asarray(
            np.vstack([np.zeros((1, config.max_batch), np.int32), i32p])
        )
        self._slots_stale = True
        self._slot_sig: object = None
        self._prefill_fns: Dict[int, object] = {}  # padded_len -> jitted prefill
        self._chunk_tokens = 0
        if config.chunked_prefill:
            self._chunk_tokens = config.chunk_tokens or 2 * config.page_size
            if self._chunk_tokens % config.page_size:
                raise ValueError(
                    f"chunk_tokens {self._chunk_tokens} must be a multiple of "
                    f"page_size {config.page_size} (chunk boundaries are "
                    f"page-aligned so chunk-written pages match monolithic ones)"
                )
            # ONE compile serves every chunk of every prompt: cursor, valid
            # length and logits index are all traced
            self._chunk_step = jax.jit(
                make_chunked_prefill_step(
                    model, mesh, rules, attn_impl=config.attn_impl,
                    kv_spec=self.cache.kv_spec,
                ),
                donate_argnums=(1,),
            )
        self.results: Dict[int, RequestState] = {}
        self._next_rid = 0  # auto-assigned rids for prompt-form submit()
        # rid -> {n: logits row that produced generated[n]} (config.record_logits).
        # Keyed by generated-token index, not step, so preemption/recompute
        # overwrites deterministically and traces align across engines.
        self.logits_of: Dict[int, Dict[int, np.ndarray]] = {}
        # per-token timing lives in the registry histograms (step_time_s:
        # device dispatch + execute + ids D2H, fused windows contributing
        # time / K per token; host_overhead_s: the wall the host loop adds
        # around it; chunk_time_s: one entry per prefill chunk) — O(1) memory
        # however long the run, metrics() snapshots their sketches

    # -- submission -------------------------------------------------------------
    def _register_grammar(self, dfa) -> int:
        """Install a TokenDFA's mask/transition rows into the engine's stacked
        grammar tables; returns the grammar's GLOBAL row offset (its state 0).
        Idempotent per automaton instance. The tables keep their compiled shape
        — registration is one content upload, never a recompile."""
        off = self._grammars.get(id(dfa))
        if off is not None:
            return off
        if dfa.vocab != self.model.cfg.vocab:
            raise ValueError(
                f"grammar compiled for vocab {dfa.vocab} but the model's is "
                f"{self.model.cfg.vocab}"
            )
        if self._grammar_used + dfa.n_states > self.config.grammar_states:
            raise ValueError(
                f"grammar needs {dfa.n_states} states but only "
                f"{self.config.grammar_states - self._grammar_used} of "
                f"EngineConfig.grammar_states={self.config.grammar_states} "
                f"remain — raise grammar_states"
            )
        off = 1 + self._grammar_used
        self._grammar_used += dfa.n_states
        self._grammars[id(dfa)] = off
        self._grammar_refs.append(dfa)  # id() stays unique while referenced
        self._gmask_host[off : off + dfa.n_states] = dfa.mask
        self._gtrans_host[off : off + dfa.n_states] = dfa.next_state + off
        self._gmask_dev = jnp.asarray(self._gmask_host)
        self._gtrans_dev = jnp.asarray(self._gtrans_host)
        return off

    def submit(self, request=None, params: Optional[GenerationParams] = None, *,
               rid: Optional[int] = None, arrival_time: float = 0.0,
               **legacy) -> RequestHandle:
        """Enqueue one request; returns its RequestHandle. Two call forms:

          submit(Request(rid, prompt, params))          # explicit identity
          submit(prompt_tokens, GenerationParams(...))  # rid auto-assigned

        (plus the deprecated legacy kwargs, which Request shims onto
        GenerationParams). EVERY impossible-combination check lives here or in
        GenerationParams.__post_init__ — at enqueue — so the mid-step
        scheduler never meets a request it cannot serve."""
        if not isinstance(request, Request):
            if request is None:
                raise ValueError("submit() needs a Request or a prompt")
            if rid is None:
                rid = self._next_rid
            request = Request(
                rid, request, params, arrival_time=arrival_time, **legacy
            )
        elif params is not None or rid is not None or legacy:
            raise ValueError(
                "submit(Request(...)) takes no extra params/rid/legacy kwargs "
                "— they belong on the Request"
            )
        self._next_rid = max(self._next_rid, request.rid + 1)
        p = request.params
        if p.logprobs > self._lp_k:
            raise ValueError(
                f"request {request.rid} asks for {p.logprobs} logprobs "
                f"but the engine compiled logprobs_k={self._lp_k} — raise "
                f"EngineConfig.logprobs_k"
            )
        if p.beam_width > self.config.max_beam_width:
            raise ValueError(
                f"request {request.rid} asks for beam_width={p.beam_width} but "
                f"the engine compiled max_beam_width="
                f"{self.config.max_beam_width} — raise "
                f"EngineConfig.max_beam_width"
            )
        if p.n_branches > self.config.max_batch:
            raise ValueError(
                f"request {request.rid} needs {p.n_branches} batch slots "
                f"(admitted as a unit) > max_batch {self.config.max_batch}"
            )
        if p.record_logits and not self.config.record_logits:
            raise ValueError(
                f"request {request.rid} asks for record_logits but the engine "
                f"was built with record_logits=False"
            )
        if p.speculative and not self.config.spec_tokens:
            raise ValueError(
                f"request {request.rid} asks for speculative decoding but the "
                f"engine was built with spec_tokens=0 — set "
                f"EngineConfig.spec_tokens"
            )
        if p.n_branches > 1 and self.config.record_logits:
            raise ValueError(
                "record_logits keys rows by rid — unsupported for parallel "
                "generation (n > 1 / beam_width > 0)"
            )
        grammar_off = None
        if p.grammar is not None:
            if not self._grammar_on:
                raise ValueError(
                    f"request {request.rid} carries a grammar but the engine "
                    f"was built with grammar_states=0 — set "
                    f"EngineConfig.grammar_states"
                )
            grammar_off = self._register_grammar(p.grammar)
        need = self.cache.pages_for(len(request.prompt) + p.max_new_tokens)
        if need > self.config.max_pages_per_seq:
            raise ValueError(
                f"request {request.rid} will need {need} pages "
                f"(prompt {len(request.prompt)} + up to {p.max_new_tokens} new) "
                f"> max_pages_per_seq {self.config.max_pages_per_seq}"
            )
        # a prompt whose admission floor exceeds the whole pool can never run,
        # even against an empty cache — fail loudly at enqueue instead of
        # letting it wedge the queue head forever (Scheduler.impossible covers
        # the runtime variant: a preempted request whose context GREW past the
        # pool). A branch group's floor adds one fork-headroom page per sibling.
        floor = self.cache.pages_for(len(request.prompt) + 1) + (p.n_branches - 1)
        if floor > self.config.num_pages - 1:
            raise ValueError(
                f"request {request.rid} needs {floor} pages just to admit its "
                f"{len(request.prompt)}-token prompt"
                + (f" across {p.n_branches} branches" if p.n_branches > 1 else "")
                + f", but the pool only has {self.config.num_pages - 1} usable "
                f"pages — raise num_pages"
            )
        if p.n_branches > 1:
            group = BranchGroup(request)
            for st in group.branches:
                st.grammar_state = grammar_off
            self._pending.append(group.primary)  # siblings ride the primary
        else:
            state = RequestState(request)
            state.grammar_state = grammar_off
            self._pending.append(state)
        return RequestHandle(self, request.rid)

    def submit_all(self, requests: Sequence[Request]) -> List[RequestHandle]:
        return [self.submit(r) for r in requests]

    # -- prefill path -----------------------------------------------------------
    def _prefill_fn(self, padded_len: int):
        fn = self._prefill_fns.get(padded_len)
        if fn is None:
            fn = jax.jit(
                make_prefill(self.model, self._mesh, self._rules, max_len=padded_len)
            )
            self._prefill_fns[padded_len] = fn
        return fn

    def _admit_and_prefill(self, now: float) -> None:
        tr = self.trace
        # fresh branch-group siblings FORK the primary's pages once ITS
        # prefill completes (_first_token), which also CLEARS their
        # await_fork flag — snapshot the flag at admission so a sibling
        # admitted alongside its primary isn't prefilled a second time in
        # this same pass (that ghost prefill writes no KV — every page is
        # shared — but would sample a duplicate first token)
        to_prefill = [
            (slot, state)
            for slot, state in self.scheduler.admit(self.queue, now)
            if not state.await_fork
        ]
        for slot, state in to_prefill:
            ctx = state.context
            padded = self.cache.pages_for(len(ctx)) * self.cache.page_size
            if tr is not None:
                tr.instant("admit", slot, rid=state.request.rid, context=len(ctx))
                tr.begin("prefill", slot, rid=state.request.rid, tokens=padded)
            # right-pad to the page bucket so ONE compile serves every context
            # length that rounds to it (preempted re-admissions arrive with
            # arbitrary lengths); logits read at the true last position, the
            # pad tail's KV lands in page slack that is masked or overwritten
            tokens = jnp.asarray([list(ctx) + [0] * (padded - len(ctx))], jnp.int32)
            logits, caches = self._prefill_fn(padded)(
                self.params, tokens, last_index=jnp.int32(len(ctx) - 1)
            )
            self.cache.write_prefill(slot, caches)
            self.cache.set_len(slot, len(ctx))
            self._c_pf_computed.inc(padded)
            if tr is not None:
                tr.end("prefill", slot)
            self._first_token(state, logits[0, 0])

    def _first_token(self, state: RequestState, logits_row) -> None:
        """Sample the token a completed prefill produced (either regime), ON
        DEVICE: ``logits_row`` is the (Vp,) device array; only the chosen id
        (and its logprob — the cumulative-score increment) crosses to the host
        (the full row only under record_logits). The PRNG fold position is
        len(context) — the length of the context the token extends — identical
        to what the decode path would fold for the same token, so
        preemption-recompute re-samples it bit-for-bit.

        This is also the parallel-generation FORK HOOK, shared by both prefill
        regimes: when a sample-mode group's primary takes its first token, each
        awaiting sibling's block-table row forks the primary's pages
        (cache.fork_slot) and samples its own first token from the SAME logits
        row under its branch seed; a beam-mode branch instead stashes its row's
        top candidates and the joint selection runs once every live branch has
        reported (_beam_advance)."""
        grp = state.group
        if grp is not None and grp.mode == "beam":
            vals, ids = self._row_logprobs(logits_row)
            grp.pending_rows[state.branch] = (
                np.asarray(vals[0]), np.asarray(ids[0])
            )
            state.hold = True  # masked from decode until the joint selection
            if state.first_token_time is None:
                state.first_token_time = time.perf_counter() - self._t0
            started = [
                st for st in grp.branches if not st.await_fork and not st.done
            ]
            if all(st.branch in grp.pending_rows for st in started):
                self._beam_advance(grp)
            return
        sp = state.sampling  # branch-aware: branch b draws from seed + b
        seed_bits = np.uint32(
            stream_seed(sp.seed, state.request.rid)
        ).astype(np.int32)
        f = jnp.asarray(np.array([sp.temperature, sp.top_p], np.float32))
        i = jnp.asarray(np.array(
            [sp.top_k, seed_bits, len(state.context)], np.int32
        ))
        if state.grammar_state is not None:
            tok_dev, lp_dev = self._sample_row_masked(
                logits_row, f, i,
                jnp.asarray(self._gmask_host[state.grammar_state]),
            )
        else:
            tok_dev, lp_dev = self._sample_row(logits_row, f, i)
        tok = int(tok_dev)
        state.generated.append(tok)
        state.cum_logprob += float(lp_dev)
        if state.grammar_state is not None:
            state.grammar_state = int(self._gtrans_host[state.grammar_state, tok])
        self._slots_stale = True  # the slot's next decode input is host-known
        if self._spec_k:
            # the proposer's hist/table rows for this slot must be rebuilt
            # from the (new) context before the next speculative window
            self._spec_stale.add(state.slot)
        if state.request.logprobs:
            vals, ids = self._row_logprobs(logits_row)
            vals, ids = np.asarray(vals[0]), np.asarray(ids[0])
            state.logprobs[len(state.generated) - 1] = [
                (int(i_), float(v))
                for i_, v in zip(ids[: state.request.logprobs],
                                 vals[: state.request.logprobs])
            ]
        if self._records(state):
            self.logits_of.setdefault(state.request.rid, {})[
                len(state.generated) - 1
            ] = np.asarray(logits_row[: self.model.cfg.vocab], np.float32)
        if state.first_token_time is None:
            state.first_token_time = time.perf_counter() - self._t0
        if grp is not None and state.branch == 0:
            # fork the awaiting siblings onto the primary's prompt pages: each
            # aliases the resident KV (incref, zero copies — CoW privatizes on
            # first divergent write) and samples its own first token from the
            # same row under its branch seed
            n_resident = int(self.cache.lens[state.slot])
            for sib in grp.branches[1:]:
                if sib.await_fork and not sib.done:
                    self.cache.fork_slot(state.slot, sib.slot, n_resident)
                    sib.await_fork = False
                    self._first_token(sib, logits_row)

    def _records(self, state: RequestState) -> bool:
        rl = state.request.params.record_logits
        return self.config.record_logits and rl is not False

    # -- beam search (host-side selection, device-layout reorder) -----------------
    def _beam_advance(self, group: BranchGroup) -> None:
        """One joint beam step over a group's stashed candidate rows.

        Pure HOST-side selection — the candidates already rode the step's
        existing top-k logprob fetch — followed by block-table surgery only:
        every surviving hypothesis is (parent branch, token); a branch that
        keeps continuing itself keeps its slot untouched (the common,
        non-diverging case — NO allocator event at all), a hypothesis hopping
        parents rebinds its slot's row to a snapshot of the parent's
        (cache.reorder_rows: incref'd aliasing, zero page copies — the next
        divergent write CoWs), and a first-step sibling forks the primary
        (cache.fork_slot). Candidates ending in eos move to the finished pool;
        the group completes at >= beam_width finished hypotheses or the length
        cap, returning the best n by cumulative logprob."""
        params = group.request.params
        w = params.beam_width
        eos = group.request.eos_id
        live = [st for st in group.branches if not st.done]
        started = [st for st in live if not st.await_fork]
        by_branch = {st.branch: st for st in started}
        cands = []
        for st in started:
            vals, ids = group.pending_rows[st.branch]
            for v, t in zip(vals[: w + 1], ids[: w + 1]):
                cands.append((st.cum_logprob + float(v), st.branch, int(t)))
        group.pending_rows.clear()
        # deterministic total order: score desc, then branch, then token —
        # replays identically across engines/preemptions
        cands.sort(key=lambda c: (-c[0], c[1], c[2]))
        cont = []
        for score, b, t in cands:
            if eos is not None and t == eos:
                group.finished.append(SequenceResult(
                    tokens=list(by_branch[b].generated) + [t], logprobs={},
                    cumulative_logprob=score, finish_reason=FINISH_EOS,
                ))
                continue
            if len(cont) < w:
                cont.append((score, b, t))
        if len(group.finished) >= w or not cont:
            self._finish_beam(group, live, survivors=False)
            return
        # slot assignment, identity-greedy: each parent's best continuation
        # keeps the parent's own slot, so a step where every branch follows
        # itself is a pure host append — no reorder, no allocator event
        base = {st.branch: list(st.generated) for st in started}
        carriers = list(live)
        assign, spill = [], []
        for score, b, t in cont:
            st = by_branch[b]
            if st in carriers:
                carriers.remove(st)
                assign.append((st, st, t, score))
            else:
                spill.append((score, b, t))
        for (score, b, t), carrier in zip(spill, carriers):
            assign.append((carrier, by_branch[b], t, score))
        now = time.perf_counter() - self._t0
        forks = [
            (c, p) for c, p, _, _ in assign if c is not p and c.await_fork
        ]
        reorder = {
            c.slot: p.slot for c, p, _, _ in assign
            if c is not p and not c.await_fork
        }
        for carrier, parent in forks:
            self.cache.fork_slot(
                parent.slot, carrier.slot, int(self.cache.lens[parent.slot])
            )
            carrier.await_fork = False
        self.cache.reorder_rows(reorder)
        for carrier, parent, t, score in assign:
            carrier.generated = base[parent.branch] + [t]
            carrier.cum_logprob = score
            carrier.hold = False
            if carrier.first_token_time is None:
                carrier.first_token_time = now
        self._slots_stale = True
        if self.trace is not None:
            self.trace.instant(
                "beam_step", group.primary.slot, rid=group.request.rid,
                moves=len(reorder), forks=len(forks),
                finished=len(group.finished),
            )
        if len(assign[0][0].generated) >= params.max_new_tokens:
            self._finish_beam(group, live, survivors=True)

    def _finish_beam(self, group: BranchGroup, live, *, survivors: bool) -> None:
        """Retire a beam group: at the length cap the live hypotheses join the
        finished pool as FINISH_LENGTH survivors; every live branch gets its
        finish_reason stamped so the group sweeps out as a unit (the branch
        states' own reasons never surface — group.sequences() ranks the
        finished pool)."""
        if survivors:
            for st in live:
                if not st.await_fork and not st.hold:
                    group.finished.append(SequenceResult(
                        tokens=list(st.generated), logprobs={},
                        cumulative_logprob=st.cum_logprob,
                        finish_reason=FINISH_LENGTH,
                    ))
        for st in live:
            if st.finish_reason is None:
                st.finish_reason = FINISH_LENGTH
            st.hold = False

    # -- chunked prefill path ----------------------------------------------------
    def _admit_chunked(self, now: float) -> None:
        """Admit without computing anything: pages bind now (index registration
        deferred to publish_prefix, which releases them chunk by chunk as their
        content lands), and the chunk cursor starts at the shared-prefix
        compute skip — the last whole-page boundary at or before the first
        token the adopted pages don't already cover (always leaving >= 1 token
        to compute: the prompt's last position must produce logits)."""
        ps = self.cache.page_size
        for slot, state in self.scheduler.admit(self.queue, now, publish=False):
            if state.await_fork:
                continue  # fresh sibling: forks at the primary's first token
            n_ctx = len(state.context)
            skip = 0
            if self.config.prefill_compute_skip and self.cache.prefix_sharing:
                adopted = self.cache.adopted_pages(slot)
                skip = min(adopted * ps, ((n_ctx - 1) // ps) * ps)
            state.chunk_cursor = skip
            self.cache.set_len(slot, skip)
            self._c_pf_skipped.inc(skip)
            if self.trace is not None:
                self.trace.instant(
                    "admit", slot, rid=state.request.rid, context=n_ctx,
                    skip=skip,
                )

    def _prefill_chunks(self, now: float) -> None:
        """Advance PREFILLING slots by at most one chunk each, within the
        step's token quota (decode appends are charged first — decode latency
        is what chunking protects). Chunks run shortest-remaining-first,
        stable on admission order: an interactive prompt's whole prefill costs
        less than one long chunk, so it never queues behind one — this is the
        TTFT bound chunking exists for. The budget's leftover flows to the
        longest prompts in admission order (the same serialization a
        monolithic engine imposes, at chunk granularity instead of
        whole-prompt granularity)."""
        running = self.scheduler.running
        # chunk-cursor holders only: await_fork and beam-hold slots are
        # PREFILLING (masked from decode) but have no chunk to advance
        prefilling = [
            s for s in sorted(running)
            if running[s].chunk_cursor is not None
            and self.cache.frontier_ready(s)  # twin adopters wait on the
            # donor's written frontier — their adopted pages are not real yet
        ]
        if not prefilling:
            return
        ps = self.cache.page_size
        n_decoding = sum(1 for st in running.values() if st.phase == DECODING)
        quota = self.config.step_token_quota or (
            self.config.max_batch + self._chunk_tokens
        )
        budget = max(0, quota - n_decoding)
        if n_decoding == 0:
            # liveness: with nothing decoding, the step makes progress only
            # through chunks — a too-small quota must not stall the engine
            budget = max(budget, ps)
        prefilling.sort(
            key=lambda s: self.cache.pages_for(len(running[s].context)) * ps
            - running[s].chunk_cursor
        )
        for slot in prefilling:
            if budget < ps:
                break
            state = running[slot]
            ctx = state.context
            n_ctx = len(ctx)
            padded = self.cache.pages_for(n_ctx) * ps
            cursor = state.chunk_cursor
            c_real = min(self._chunk_tokens, padded - cursor, (budget // ps) * ps)
            budget -= c_real
            # dispatch at the smallest bucket that holds the chunk: the jit
            # cache traces one compile per bucket width, so an 8-token short
            # prompt costs an 8-wide step, not a chunk_tokens-wide one
            bucket = ps
            while bucket < c_real:
                bucket *= 2
            bucket = min(bucket, self._chunk_tokens)
            # the chunk's tokens, zero-padded through the page bucket exactly as
            # a monolithic prefill pads — the last chunk COMPUTES the pad tail's
            # KV so its final page is bit-compatible with the monolithic page
            # (and with the prefix index's purity law)
            padded_ctx = list(ctx) + [0] * (padded - n_ctx)
            toks = padded_ctx[cursor : cursor + c_real]
            toks += [0] * (bucket - c_real)
            read_row = self.cache.tables[slot : slot + 1]
            write_row = self.cache.write_table_row(slot)[None, :]
            tr = self.trace
            if tr is not None:
                tr.begin(
                    "chunk", slot, rid=state.request.rid, cursor=cursor,
                    tokens=c_real,
                )
            t0 = time.perf_counter()
            logits, pools = self._chunk_step(
                self.params,
                self.cache.pools,
                jnp.asarray([toks], jnp.int32),
                jnp.asarray(read_row),
                jnp.asarray(write_row),
                jnp.asarray([cursor], jnp.int32),
                jnp.asarray([c_real], jnp.int32),
                jnp.asarray([min(n_ctx - 1 - cursor, c_real - 1)], jnp.int32),
            )
            self.cache.pools = pools
            self._h_chunk.observe(time.perf_counter() - t0)
            if tr is not None:
                tr.end("chunk", slot)
            self._c_pf_computed.inc(c_real)
            if cursor + c_real >= n_ctx:  # this chunk covered the last position
                state.chunk_cursor = None
                self.cache.set_len(slot, n_ctx)
                self.cache.publish_prefix(slot)
                self._first_token(state, logits[0])
            else:
                state.chunk_cursor = cursor + c_real
                self.cache.set_len(slot, cursor + c_real)
                # pages behind the new cursor are final: publish them so a
                # same-prefix arrival can adopt (and compute-skip) mid-prefill
                self.cache.publish_prefix(slot, (cursor + c_real) // ps)

    # -- decode path (the device-loop driver) -------------------------------------
    def _sync_slot_state(self) -> None:
        """Re-upload the per-slot device vectors — fed-back tokens + the two
        packed policy/phase arrays — ONLY when slot composition changed
        (admission, finish, preemption, a prefill completing). In steady-state
        decode the previous step's sampled tokens ARE the next inputs and flow
        back as device arrays: the step's only recurring H2D traffic is zero
        and its only D2H traffic is the (B,) sampled ids."""
        running = self.scheduler.running
        sig = tuple(
            (slot, st.request.rid, st.phase) for slot, st in sorted(running.items())
        )
        if not self._slots_stale and sig == self._slot_sig:
            return
        b = self.config.max_batch
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((1, b), np.int32)
        decoding = {}
        for slot, state in running.items():
            if state.phase == DECODING:
                tokens[slot] = state.generated[-1]
                active[0, slot] = 1
                decoding[slot] = state
        f32p, i32p = pack_slot_params(decoding, b)
        self._tokens_dev = jnp.asarray(tokens)
        self._slot_f32 = jnp.asarray(f32p)
        self._slot_i32 = jnp.asarray(np.vstack([active, i32p]))
        if self._grammar_on:
            # per-slot grammar states re-seed from the host mirror on the same
            # trigger as the other vectors; in steady state the step's own
            # (donated) output flows back and the host just replays transitions
            gstate = np.zeros((b,), np.int32)
            for slot, state in decoding.items():
                if state.grammar_state is not None:
                    gstate[slot] = state.grammar_state
            self._gstate_dev = jnp.asarray(gstate)
        self._slots_stale = False
        self._slot_sig = sig

    def _fused_k(self, now: float) -> int:
        """How many decode steps to run in one device dispatch: K when the
        scheduler proves the horizon event-free AND no pending arrival lands
        inside it (estimated from the last measured step), else 1. Page
        capacity is the one horizon limit the host can raise for free, so a
        short horizon first pre-appends decode pages up to the window
        (Scheduler.reserve_decode_tokens) and re-proves."""
        if self._k <= 1:
            return 1
        if self.scheduler.event_free_horizon(self.queue) < self._k:
            if self.queue:
                return 1
            for slot, st in self.scheduler.running.items():
                if st.phase == DECODING:
                    self.scheduler.reserve_decode_tokens(slot, self._k)
            if self.scheduler.event_free_horizon(self.queue) < self._k:
                return 1
        if self._pending:
            est = self._last_step_time if self._last_step_time else 2e-3
            if self._pending[0].request.arrival_time <= now + self._k * est:
                return 1
        return self._k

    # -- speculative path (serving/speculative.py) --------------------------------
    def _spec_plan(self, now: float, decoding) -> int:
        """Windows to run speculatively in THIS dispatch (0 = plain decode).
        Speculation is a batch-wide window: every decoding slot must be
        eligible (no per-request opt-out, no grammar, no branch group), the
        whole window's page budget must pre-reserve
        (Scheduler.reserve_decode_tokens — at most S*(K+1) tokens per slot),
        the horizon must prove S windows event-free at tokens_per_step = K+1,
        and no pending arrival may land inside the window. Any failure
        degrades to the plain path for this dispatch — never an error.

        Adaptive backoff: while the acceptance EMA sits under
        spec_accept_floor (speculation not paying for its ~2x-a-step verify
        cost on this stream), the planner answers 0 for spec_backoff
        dispatches before probing another window — an incompressible stream
        pays an occasional probe, not a per-step verify tax."""
        if not decoding or self.queue:
            return 0
        if self._spec_backoff_left:
            self._spec_backoff_left -= 1
            return 0
        for state in decoding.values():
            p = state.request.params
            if (p.speculative is False or p.grammar is not None
                    or state.group is not None):
                return 0
        c = self._spec_k + 1
        s = self._spec_windows
        for slot in decoding:
            if not self.scheduler.reserve_decode_tokens(slot, s * c):
                return 0
        if self.scheduler.event_free_horizon(
                self.queue, tokens_per_step=c) < s:
            return 0
        if self._pending:
            est = self._last_step_time if self._last_step_time else 2e-3
            if self._pending[0].request.arrival_time <= now + s * est:
                return 0
        return s

    def _sync_spec_state(self, decoding) -> None:
        """Rebuild the proposer's hist/table rows for slots whose context
        changed outside a speculative window (admission, plain-decode steps,
        preemption-recompute) — the spec twin of _sync_slot_state. Rebuilt
        rows are bit-identical to what in-window device updates would have
        produced (NGramProposer's shifted-insertion law; tests pin it), so
        mixing plain and speculative dispatches never drifts the table."""
        stale = sorted(s for s in self._spec_stale if s in decoding)
        if stale:
            hists, tables = [], []
            for slot in stale:
                h, t = self._proposer.rebuild_row(decoding[slot].context)
                hists.append(h)
                tables.append(t)
            idx = jnp.asarray(stale, jnp.int32)
            self._hist_dev = self._hist_dev.at[idx].set(
                jnp.asarray(np.stack(hists))
            )
            self._table_dev = self._table_dev.at[idx].set(
                jnp.asarray(np.stack(tables))
            )
        self._spec_stale.difference_update(stale)

    def _decode_spec_once(self, now: float, decoding, s: int) -> None:
        """One speculative dispatch: S windows of propose -> verify -> accept
        inside one on-device lax.scan. Each window commits 1..K+1 tokens per
        slot; the rejected suffix is never covered by the advanced lens
        (rollback = layout arithmetic — its KV bytes sit in pre-reserved
        owned pages and later appends overwrite them). The only bulk D2H is
        the (S, B, K+1) ids + committed-counts fetch."""
        wall0 = time.perf_counter()
        self._sync_slot_state()
        self._sync_spec_state(decoding)
        tables, lens = self.cache.device_state()
        kd = self._spec_k
        c = kd + 1
        tr = self.trace
        if tr is not None:
            tr.begin("spec_window", -1, windows=s, k=kd, batch=len(decoding))
        want_lp = self._lp_k and any(
            st.request.logprobs for st in decoding.values()
        )
        t0 = time.perf_counter()
        out = self._spec_step(
            self.params, self.cache.pools, self._tokens_dev, tables, lens,
            self._slot_f32, self._slot_i32, self._hist_dev, self._table_dev,
        )
        toks, committed, last, new_lens, pools, lps = out[:6]
        ids = np.asarray(toks)  # (S, B, C)
        acc = np.asarray(committed)  # (S, B) tokens committed per window
        lp_arr = np.asarray(lps)  # (S, B, C)
        lp_vals = lp_ids = None
        if want_lp:
            lp_vals = np.asarray(out[8][0])  # (S, B, C, k)
            lp_ids = np.asarray(out[8][1])
        t_dev = time.perf_counter() - t0
        self.cache.pools = pools
        self.cache.adopt_lens_device(new_lens)
        self._tokens_dev = last
        self._hist_dev, self._table_dev = out[6], out[7]
        per_win = t_dev / s  # one window = one model dispatch, like one step
        for _ in range(s):
            self._h_step.observe(per_win)
        self._last_step_time = per_win
        self._c_decode.inc(s)
        self._c_fused.inc(s)
        verdict = self._straggler.observe(per_win)
        if verdict != "ok":
            self._c_slow.inc()
            if tr is not None:
                tr.instant(
                    "slow_step", -1, verdict=verdict,
                    step_ms=per_win * 1e3,
                    ema_ms=(self._straggler.ema or 0.0) * 1e3,
                )
        win_acc = 0
        win_n = 0
        for i in range(s):
            for slot, state in decoding.items():
                if state.done:
                    continue  # finished mid-window: overrun windows discarded
                a = int(acc[i, slot])
                take = 0
                for j in range(a):
                    tok = int(ids[i, slot, j])
                    state.generated.append(tok)
                    state.cum_logprob += float(lp_arr[i, slot, j])
                    take += 1
                    n_lp = state.request.logprobs
                    if n_lp and lp_vals is not None:
                        state.logprobs[len(state.generated) - 1] = [
                            (int(t), float(v))
                            for t, v in zip(lp_ids[i, slot, j, :n_lp],
                                            lp_vals[i, slot, j, :n_lp])
                        ]
                    if state.done:
                        break  # EOS inside the window truncates the commit
                # host mirror follows the HONEST count; an EOS-truncated slot
                # (take < a) is done and sweeps out — free_slot dirty-marks
                # its row, repairing the device lens the window over-advanced
                self.cache.bump_len(slot, take)
                win_n += 1
                win_acc += take
                self._c_spec_windows.inc()
                self._c_spec_accepted.inc(take)
                # draft hits: committed tokens that CAME from the draft (the
                # last committed token is the target's correction/bonus)
                self._c_spec_hits.inc(min(take, max(a - 1, 0)))
                self._c_spec_rollback.inc(c - a)
        mean = (win_acc / win_n) if win_n else 0.0
        ema = self._spec_accept_ema
        self._spec_accept_ema = mean if ema is None else 0.6 * ema + 0.4 * mean
        if self.config.spec_backoff:
            if self._spec_accept_ema < self.config.spec_accept_floor:
                self._spec_backoff_left = self._spec_backoff_len
                self._spec_backoff_len = min(
                    self._spec_backoff_len * 2, 32 * self.config.spec_backoff
                )
                self._c_spec_backoffs.inc()
                if tr is not None:
                    tr.instant(
                        "spec_backoff", -1, ema=self._spec_accept_ema,
                        floor=self.config.spec_accept_floor,
                        dispatches=self._spec_backoff_left,
                    )
            else:
                # the stream pays again: next backoff starts from the base
                self._spec_backoff_len = int(self.config.spec_backoff)
        if tr is not None:
            tr.instant(
                "spec_accept", -1, windows=win_n, accepted=win_acc,
                mean=mean,
            )
            tr.end("spec_window", -1)
        wall = time.perf_counter() - wall0
        self._h_host.observe((wall - t_dev) / s)

    def _decode_once(self, now: float) -> None:
        """One device dispatch of the decode hot path: a single fused step, or
        a K-step on-device loop over an event-free horizon. PREFILLING slots
        (mixed steps only) are masked ON DEVICE via the phase bitmap — table
        row and length null-routed inside the step — so the host never copies
        or re-uploads tables to mask them; the compiled shape never changes.
        Tokens are sampled on device; the only per-token D2H traffic is the
        sampled ids ((B,) per step, (K, B) per fused window)."""
        running = self.scheduler.running
        decoding = {s: st for s, st in running.items() if st.phase == DECODING}
        if self._spec_k:
            n_win = self._spec_plan(now, decoding)
            if n_win:
                self._decode_spec_once(now, decoding, n_win)
                return
            # plain decode generates tokens the proposer's device arrays
            # never saw — every decoding row is stale for the next window
            self._spec_stale.update(decoding)
        wall0 = time.perf_counter()
        k = self._fused_k(now)
        self._sync_slot_state()
        tables, lens = self.cache.device_state()
        record = self.config.record_logits
        tr = self.trace
        if tr is not None:
            tr.begin("fused_window" if k > 1 else "decode", -1, k=k,
                     batch=len(decoding))
        # requests riding the per-token fetch for logprobs (opt-in per request;
        # with nobody opted in the (B, k) pair is computed but never fetched) —
        # beam groups always ride it: the top-k pair IS their candidate set
        want_lp = self._lp_k and any(
            st.request.logprobs
            or (st.group is not None and st.group.mode == "beam")
            for st in decoding.values()
        )
        lp_vals = lp_ids = None
        g_args = (
            (self._gstate_dev, self._gmask_dev, self._gtrans_dev)
            if self._grammar_on else ()
        )
        lp_i = 6 if self._grammar_on else 5  # top-k pair's output index
        t0 = time.perf_counter()
        if k > 1:
            out = self._multistep(
                self.params, self.cache.pools, self._tokens_dev, tables, lens,
                self._slot_f32, self._slot_i32, *g_args,
            )
            toks, last, new_lens, pools = out[:4]
            ids = np.asarray(toks)  # (K, B) — the fused window's only D2H
            lps = np.asarray(out[4])  # (K, B) chosen logprobs, same round
            if want_lp:
                lp_vals = np.asarray(out[lp_i][0])  # (K, B, k)
                lp_ids = np.asarray(out[lp_i][1])
            logits_rows = None
            self._c_fused.inc(k)
        else:
            out = self._step(
                self.params, self.cache.pools, self._tokens_dev, tables, lens,
                self._slot_f32, self._slot_i32, *g_args,
            )
            last, logits, new_lens, pools = out[:4]
            ids = np.asarray(last)[None]  # (1, B)
            lps = np.asarray(out[4])[None]  # (1, B)
            if want_lp:
                lp_vals = np.asarray(out[lp_i][0])[None]  # (1, B, k)
                lp_ids = np.asarray(out[lp_i][1])[None]
            logits_rows = (
                np.asarray(logits[:, : self.model.cfg.vocab], np.float32)
                if record else None
            )
        if self._grammar_on:
            self._gstate_dev = out[5]  # donated input's successor
        t_dev = time.perf_counter() - t0
        self.cache.pools = pools
        self.cache.adopt_lens_device(new_lens)
        self._tokens_dev = last
        per_tok = t_dev / k
        for _ in range(k):
            self._h_step.observe(per_tok)
        self._last_step_time = per_tok
        self._c_decode.inc(k)
        verdict = self._straggler.observe(per_tok)
        if verdict != "ok":
            self._c_slow.inc()
            if tr is not None:
                tr.instant(
                    "slow_step", -1, verdict=verdict,
                    step_ms=per_tok * 1e3,
                    ema_ms=(self._straggler.ema or 0.0) * 1e3,
                )
        beam_groups = []
        for i in range(k):
            for slot, state in decoding.items():
                if state.done:
                    continue  # finished mid-window (EOS): overrun ids discarded
                grp = state.group
                if grp is not None and grp.mode == "beam":
                    # the KV write happened (bump the mirror), but the DEVICE
                    # sample is not the branch's next token — the top-k pair
                    # is this branch's candidate row, selection is joint
                    self.cache.bump_len(slot)
                    grp.pending_rows[state.branch] = (
                        lp_vals[i, slot], lp_ids[i, slot]
                    )
                    if grp not in beam_groups:
                        beam_groups.append(grp)
                    continue
                tok = int(ids[i, slot])
                state.generated.append(tok)
                state.cum_logprob += float(lps[i, slot])
                if state.grammar_state is not None:
                    state.grammar_state = int(
                        self._gtrans_host[state.grammar_state, tok]
                    )
                self.cache.bump_len(slot)
                n_lp = state.request.logprobs
                if n_lp and lp_vals is not None:
                    state.logprobs[len(state.generated) - 1] = [
                        (int(t), float(v))
                        for t, v in zip(lp_ids[i, slot, :n_lp],
                                        lp_vals[i, slot, :n_lp])
                    ]
                if logits_rows is not None and self._records(state):
                    self.logits_of.setdefault(state.request.rid, {})[
                        len(state.generated) - 1
                    ] = logits_rows[slot].copy()
        for grp in beam_groups:
            started = [
                st for st in grp.branches if not st.await_fork and not st.done
            ]
            if all(st.branch in grp.pending_rows for st in started):
                self._beam_advance(grp)
        if tr is not None:
            tr.end("fused_window" if k > 1 else "decode", -1)
        wall = time.perf_counter() - wall0
        self._h_host.observe((wall - t_dev) / k)

    def _sweep_finished(self) -> None:
        for slot in list(self.scheduler.running):
            state = self.scheduler.running[slot]
            if state.done:
                state.finish_time = time.perf_counter() - self._t0
                reason = state.finished_reason()
                if self.trace is not None:
                    self.trace.instant(
                        "finish", slot, rid=state.request.rid, reason=reason,
                        generated=len(state.generated), branch=state.branch,
                    )
                # session retention: demote a cleanly-finished request's pages
                # to the host tier with an eviction deadline, so a follow-up
                # sharing this context prefetches instead of re-prefilling
                if (self.cache.tier is not None
                        and self.config.retain_finished_s > 0
                        and state.error is None):
                    self.cache.demote_slot(
                        slot, state.hash_chain(self.cache.page_size),
                        retain_s=self.config.retain_finished_s,
                    )
                # freeing this branch's pages decrefs — never frees — the
                # pages its still-running siblings alias (cache.free_slot),
                # so one branch's EOS neither stalls nor corrupts the rest
                self.scheduler.finish(slot)
                grp = state.group
                if grp is None:
                    self.results[state.request.rid] = state
                elif grp.all_done and state.request.rid not in self.results:
                    # the group completes as a UNIT: results carry the primary,
                    # whose .sequences ranks/collects every branch
                    grp.primary.finish_time = state.finish_time
                    self.results[state.request.rid] = grp.primary

    # -- main loop ----------------------------------------------------------------
    def run(self, requests: Optional[Sequence[Request]] = None) -> Dict[int, RequestState]:
        """Serve until every submitted request completes; returns rid -> state.
        A request the pool can never hold (Scheduler.impossible) is FAILED —
        returned with .error set and empty .generated — instead of wedging the
        queue; everything behind it keeps serving."""
        if requests is not None:
            self.submit_all(requests)
        self._pending.sort(key=lambda s: s.request.arrival_time)
        chunked = self.config.chunked_prefill
        self._t0 = time.perf_counter()
        while self._pending or self.queue or self.scheduler.running:
            now = time.perf_counter() - self._t0
            if self.cache.tier is not None:
                self.cache.tier.begin_step()
            # broken twins: a slot whose twin donor died before covering its
            # adopted pages holds garbage — preempt it back to the queue for a
            # clean re-admit (its pages never demote; they were never written)
            for slot in self.cache.take_broken():
                if slot in self.scheduler.running:
                    self.scheduler.preempt_slot(slot, self.queue)
            while self._pending and self._pending[0].request.arrival_time <= now:
                state = self._pending.pop(0)
                if self.trace is not None:
                    self.trace.instant("enqueue", rid=state.request.rid)
                self.queue.push(state)
            for state in self.scheduler.reject_impossible(self.queue):
                state.finish_time = time.perf_counter() - self._t0
                # a rejected request can never resume: drop any host-tier
                # residency its context holds (no orphaned host pages)
                if self.cache.tier is not None:
                    self.cache.release_host(
                        state.hash_chain(self.cache.page_size)
                    )
                if state.group is not None:
                    for st in state.group.branches:
                        if st.finish_reason is None:  # keep earlier finishes
                            st.error = state.error
                            st.finish_reason = FINISH_ERROR
                else:
                    state.finish_reason = FINISH_ERROR
                self.results[state.request.rid] = state
            if chunked:
                self._admit_chunked(now)
                self._prefill_chunks(now)
            else:
                self._admit_and_prefill(now)
            self._sweep_finished()  # a request can complete at prefill time
            running = self.scheduler.running
            if any(st.phase == DECODING for st in running.values()):
                for slot in sorted(running):
                    if slot in running and running[slot].phase == DECODING:
                        self.scheduler.ensure_decode_page(slot, self.queue)
                self._decode_once(now)
                self._sweep_finished()
            elif running:
                pass  # only PREFILLING slots: next mixed step continues chunking
            elif self._pending and not self.queue:
                time.sleep(
                    min(max(self._pending[0].request.arrival_time - now, 0.0), 0.01)
                )
            elif self.queue:
                # nothing running, nothing arriving, head request not admitted:
                # the whole (free) pool cannot hold its unshared pages — this
                # can never resolve (with nothing running, no donor pages will
                # ever join the prefix index). reject_impossible already failed
                # requests too big for the pool, so this is the safety net for
                # allocator states it cannot see.
                head = self.queue.peek()
                raise RuntimeError(
                    f"request {head.request.rid} needs "
                    f"{self.cache.new_pages_needed(head.context)} new pages but only "
                    f"{self.cache.num_free} exist — raise num_pages"
                )
        return self.results

    def reset_metrics(self) -> None:
        """Drop finished-request records and timing state (benchmarks rehearse a
        warmup trace on the same engine so jit caches stay hot, then reset):
        zero every registry instrument, clear the trace ring, restart the
        straggler EMA, and reset allocator stats."""
        self.results = {}
        self.logits_of = {}
        self.registry.reset()
        if self.trace is not None:
            self.trace.clear()
        self._last_step_time = None
        self._straggler = StragglerPolicy(
            threshold=self.config.slow_step_threshold
        )
        self.cache.reset_stats()

    # -- metrics ------------------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        """Flat snapshot over the registry + per-request records + allocator
        stats — same keys the bench suite always consumed, now backed by
        O(1)-memory sketches (histogram percentiles are within one log-bucket
        of exact, ~7.5% relative)."""
        # the autotuner's decision rides every snapshot (empty ones included)
        # so "what config is this engine actually running" is always one
        # metrics() call away; absent entirely when autotune is off, keeping
        # the no-autotune snapshot shape byte-identical to before the feature
        tuning: Dict[str, float] = {}
        if self.tuned is not None:
            tuning = {
                "tuned_page_size": self.config.page_size,
                "tuned_block_pages": self.config.decode_block_pages,
                "tuned_chunk_tokens": self.config.chunk_tokens,
                "tuned_source": self.tuned.source,
            }
        failed = [s for s in self.results.values() if s.error is not None]
        states = [s for s in self.results.values() if s.error is None]
        if not states:
            out = {"failed": len(failed)} if failed else {}
            out.update(tuning)
            return out
        wall = max(s.finish_time for s in states)
        # throughput over the SPAN the engine was actually serving: replayed
        # traces with offset arrivals used to divide by max(finish) alone,
        # under-reporting whenever the first arrival wasn't at epoch 0
        span = wall - min(s.request.arrival_time for s in states)
        e2e = np.array([s.finish_time - s.request.arrival_time for s in states])
        ttft = np.array(
            [s.first_token_time - s.request.arrival_time for s in states]
        )
        # decode work done: a branch group's primary stands for the whole
        # group in results, so count every branch's tokens, not just its own
        n_tok = sum(
            sum(len(b.generated) for b in s.group.branches)
            if s.group is not None else len(s.generated)
            for s in states
        )
        # speculative acceptance telemetry (absent when spec_tokens=0, so the
        # non-speculative snapshot keeps its exact pre-feature shape):
        # accepted_tokens_per_step is the headline — mean tokens committed per
        # slot-window (>= 1 by construction: the correction token always
        # commits); draft_hit_rate is the fraction of PROPOSED draft tokens
        # that committed; spec_rollback_tokens counts positions whose KV was
        # written then abandoned to the lens rollback
        spec: Dict[str, float] = {}
        if self._spec_k:
            w = self._c_spec_windows.value
            spec = {
                "spec_windows": w,
                "spec_accepted_tokens": self._c_spec_accepted.value,
                "accepted_tokens_per_step": (
                    self._c_spec_accepted.value / w if w else 0.0
                ),
                "draft_hit_rate": (
                    self._c_spec_hits.value / (w * self._spec_k) if w else 0.0
                ),
                "spec_rollback_tokens": self._c_spec_rollback.value,
                "spec_backoffs": self._c_spec_backoffs.value,
            }
        return {
            "requests": len(states),
            "failed": len(failed),
            "generated_tokens": n_tok,
            "wall_s": float(wall),
            "tokens_per_s": float(n_tok / span) if span > 0 else float("inf"),
            "decode_steps": self._c_decode.value,
            "fused_steps": self._c_fused.value,
            # device-path tail + the host-vs-device breakdown: step_ms_* times
            # dispatch + device execute + the (B,)/(K, B) ids fetch per token;
            # host_overhead_ms_p50 is the wall-clock the host loop adds around
            # it (slot sync, scheduler bookkeeping) — what the device-resident
            # refactor squeezed out, and what the bench's breakdown proves
            "step_ms_p50": self._h_step.percentile(50) * 1e3,
            "step_ms_p95": self._h_step.percentile(95) * 1e3,
            # summed device step time (dispatch + execute + ids fetch) over
            # every decode step/window: generated-minus-first tokens divided
            # by this is DECODE throughput, the hot-path quantity the
            # speculative bench gates on without prefill/scheduler noise
            "decode_ms_total": self._h_step.total * 1e3,
            "host_overhead_ms_p50": self._h_host.percentile(50) * 1e3,
            "chunk_ms_p50": self._h_chunk.percentile(50) * 1e3,
            "latency_s_p50": float(np.percentile(e2e, 50)),
            "latency_s_p99": float(np.percentile(e2e, 99)),
            "ttft_s_p50": float(np.percentile(ttft, 50)),
            "ttft_s_p95": float(np.percentile(ttft, 95)),
            "ttft_s_p99": float(np.percentile(ttft, 99)),
            "preemptions": sum(s.n_preemptions for s in states),
            "slow_steps": self._c_slow.value,
            "prefill_tokens_computed": self._c_pf_computed.value,
            "prefill_tokens_skipped": self._c_pf_skipped.value,
            **spec,
            **self.cache.stats(),
            **tuning,
        }
