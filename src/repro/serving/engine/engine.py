"""ServeEngine: continuous-batching generation over a paged KV cache.

One engine step = (admit + prefill newcomers) then (one batched decode step for
every running sequence). Sequences enter and leave the batch at arbitrary steps
(continuous batching): a fixed-size slot vector keeps the decode computation at
one compiled shape, and per-slot positions (context_lens) + block-table rows
carry each sequence's own state into decode_step_paged — the LayoutPaged path.

Invariants the step loop maintains per running slot:
  - cache.lens[slot] == len(state.context) - 1: every context token EXCEPT the
    newest generated one has its KV in the pool;
  - the decode input is state.generated[-1]; its KV is written at position
    lens[slot] during the step (LayoutPaged: page table[lens//ps], slot lens%ps);
  - the slot owns a WRITABLE page covering position lens[slot]: the scheduler
    appends a page at page boundaries and copy-on-write-privatizes it when
    prefix sharing left it refcount>1 (preempting later arrivals when the pool
    runs dry), so the decode scatter never lands in a page another sequence
    still reads.

Prefill of a newly admitted request runs at batch 1 on the sequence's true
length (the KV pool is padded to whole pages, the logits are read at the true
last position), then the packed KV pages are scattered into the pool —
quantized at scatter time when ``kv_dtype`` selects int8/int4 pages
(kvquant.PagedQuantSpec): the allocator, scheduler and admission logic are
identical in that regime, only the pool's bytes shrink.
Preemption is recompute-style: pages are dropped and the full context
(prompt + generated so far) is re-prefilled on re-admission, which under greedy
decoding reproduces the identical continuation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.step import make_paged_serve_step, make_prefill

from .cache import PagedKVCache
from .request import Request, RequestQueue, RequestState
from .scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_pages: int = 64
    page_size: int = 16
    max_batch: int = 8
    max_pages_per_seq: int = 16
    watermark_pages: int = 1
    attn_impl: str = "auto"  # "pallas" | "jnp" | "auto" — ops.paged_decode_attention
    prefix_sharing: bool = True  # dedupe common prompt prefixes onto shared pages
    kv_dtype: str = "f32"  # "f32" | "int8" | "int4" — KV page representation
    # (kvquant.PagedQuantSpec): same pages/tables/admission, ~4x/~8x fewer bytes
    record_logits: bool = False  # keep per-step logits rows (ServeEngine.logits_of)
    # for cross-engine accuracy audits (e.g. int8 vs f32 max-logit-error)

    @classmethod
    def sized_for(cls, max_len: int, *, page_size: int, max_batch: int,
                  **kw) -> "EngineConfig":
        """Pool sized so max_batch sequences of ``max_len`` tokens (prompt + new)
        can run with no contention: per-seq pages cover max_len plus the one-page
        decode headroom, and the pool adds the reserved null page 0."""
        pages_per_seq = -(-max_len // page_size) + 1
        return cls(
            num_pages=max_batch * pages_per_seq + 1,
            page_size=page_size,
            max_batch=max_batch,
            max_pages_per_seq=pages_per_seq,
            **kw,
        )


def aligned_max_logit_err(eng_ref, eng, results_ref, results) -> float:
    """Max |logit difference| between two record_logits engines over steps
    where both saw the SAME context: per request, every step up to and
    including the first divergent generated token (those logits were computed
    on identical prefixes, so the comparison stays meaningful after greedy
    trajectories split). The accuracy metric the quantized-KV CI gate bounds."""
    errs = [0.0]
    for rid, s_ref in results_ref.items():
        a, b = s_ref.generated, results[rid].generated
        n_cmp = min(len(a), len(b))
        div = next((i for i in range(n_cmp) if a[i] != b[i]), n_cmp - 1)
        for n in range(div + 1):
            errs.append(float(np.max(np.abs(
                eng_ref.logits_of[rid][n] - eng.logits_of[rid][n]
            ))))
    return max(errs)


class ServeEngine:
    def __init__(self, model, params, config: EngineConfig = EngineConfig(),
                 mesh=None, rules=None):
        self.model = model
        self.params = params
        self.config = config
        self.cache = PagedKVCache(
            model,
            num_pages=config.num_pages,
            page_size=config.page_size,
            max_batch=config.max_batch,
            max_pages_per_seq=config.max_pages_per_seq,
            prefix_sharing=config.prefix_sharing,
            kv_dtype=config.kv_dtype,
        )
        self.scheduler = Scheduler(
            self.cache, SchedulerConfig(config.max_batch, config.watermark_pages)
        )
        self.queue = RequestQueue()
        self._pending: List[RequestState] = []  # submitted, not yet arrived
        self._mesh, self._rules = mesh, rules
        self._step = jax.jit(
            make_paged_serve_step(
                model, mesh, rules, attn_impl=config.attn_impl,
                kv_spec=self.cache.kv_spec,
            ),
            donate_argnums=(1,),
        )
        self._prefill_fns: Dict[int, object] = {}  # padded_len -> jitted prefill
        self.results: Dict[int, RequestState] = {}
        # rid -> {n: logits row that produced generated[n]} (config.record_logits).
        # Keyed by generated-token index, not step, so preemption/recompute
        # overwrites deterministically and traces align across engines.
        self.logits_of: Dict[int, Dict[int, np.ndarray]] = {}
        self.step_times: List[float] = []
        self._n_decode_steps = 0

    # -- submission -------------------------------------------------------------
    def submit(self, request: Request) -> None:
        need = self.cache.pages_for(len(request.prompt) + request.max_new_tokens)
        if need > self.config.max_pages_per_seq:
            raise ValueError(
                f"request {request.rid} will need {need} pages "
                f"(prompt {len(request.prompt)} + up to {request.max_new_tokens} new) "
                f"> max_pages_per_seq {self.config.max_pages_per_seq}"
            )
        self._pending.append(RequestState(request))

    def submit_all(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    # -- prefill path -----------------------------------------------------------
    def _prefill_fn(self, padded_len: int):
        fn = self._prefill_fns.get(padded_len)
        if fn is None:
            fn = jax.jit(
                make_prefill(self.model, self._mesh, self._rules, max_len=padded_len)
            )
            self._prefill_fns[padded_len] = fn
        return fn

    def _admit_and_prefill(self, now: float) -> None:
        for slot, state in self.scheduler.admit(self.queue, now):
            ctx = state.context
            padded = self.cache.pages_for(len(ctx)) * self.cache.page_size
            # right-pad to the page bucket so ONE compile serves every context
            # length that rounds to it (preempted re-admissions arrive with
            # arbitrary lengths); logits read at the true last position, the
            # pad tail's KV lands in page slack that is masked or overwritten
            tokens = jnp.asarray([list(ctx) + [0] * (padded - len(ctx))], jnp.int32)
            logits, caches = self._prefill_fn(padded)(
                self.params, tokens, last_index=jnp.int32(len(ctx) - 1)
            )
            self.cache.write_prefill(slot, caches)
            self.cache.lens[slot] = len(ctx)
            row = np.asarray(logits[0, 0, : self.model.cfg.vocab], np.float32)
            tok = int(np.argmax(row))
            state.generated.append(tok)
            if self.config.record_logits:
                self.logits_of.setdefault(state.request.rid, {})[
                    len(state.generated) - 1
                ] = row
            if state.first_token_time is None:
                state.first_token_time = time.perf_counter() - self._t0

    # -- decode path ------------------------------------------------------------
    def _decode_once(self, now: float) -> None:
        running = self.scheduler.running
        b = self.config.max_batch
        tokens = np.zeros((b,), np.int32)
        for slot, state in running.items():
            tokens[slot] = state.generated[-1]
        t0 = time.perf_counter()
        logits, pools = self._step(
            self.params,
            self.cache.pools,
            jnp.asarray(tokens),
            jnp.asarray(self.cache.tables),
            jnp.asarray(self.cache.lens),
        )
        self.cache.pools = pools
        logits = np.asarray(logits[:, : self.model.cfg.vocab], np.float32)
        self.step_times.append(time.perf_counter() - t0)
        self._n_decode_steps += 1
        for slot, state in running.items():
            state.generated.append(int(np.argmax(logits[slot])))
            if self.config.record_logits:
                self.logits_of.setdefault(state.request.rid, {})[
                    len(state.generated) - 1
                ] = logits[slot].copy()
            self.cache.lens[slot] += 1

    def _sweep_finished(self) -> None:
        for slot in list(self.scheduler.running):
            state = self.scheduler.running[slot]
            if state.done:
                state.finish_time = time.perf_counter() - self._t0
                self.scheduler.finish(slot)
                self.results[state.request.rid] = state

    # -- main loop ----------------------------------------------------------------
    def run(self, requests: Optional[Sequence[Request]] = None) -> Dict[int, RequestState]:
        """Serve until every submitted request completes; returns rid -> state."""
        if requests is not None:
            self.submit_all(requests)
        self._pending.sort(key=lambda s: s.request.arrival_time)
        self._t0 = time.perf_counter()
        while self._pending or self.queue or self.scheduler.running:
            now = time.perf_counter() - self._t0
            while self._pending and self._pending[0].request.arrival_time <= now:
                self.queue.push(self._pending.pop(0))
            self._admit_and_prefill(now)
            self._sweep_finished()  # a request can complete at prefill time
            if self.scheduler.running:
                for slot in sorted(self.scheduler.running):
                    if slot in self.scheduler.running:
                        self.scheduler.ensure_decode_page(slot, self.queue)
                self._decode_once(now)
                self._sweep_finished()
            elif self._pending and not self.queue:
                time.sleep(
                    min(max(self._pending[0].request.arrival_time - now, 0.0), 0.01)
                )
            elif self.queue:
                # nothing running, nothing arriving, head request not admitted:
                # the whole (free) pool cannot hold its unshared pages — this
                # can never resolve (with nothing running, no donor pages will
                # ever join the prefix index)
                head = self.queue.peek()
                raise RuntimeError(
                    f"request {head.request.rid} needs "
                    f"{self.cache.new_pages_needed(head.context)} new pages but only "
                    f"{self.cache.num_free} exist — raise num_pages"
                )
        return self.results

    def reset_metrics(self) -> None:
        """Drop finished-request records and timing state (benchmarks rehearse a
        warmup trace on the same engine so jit caches stay hot, then reset)."""
        self.results = {}
        self.logits_of = {}
        self.step_times = []
        self._n_decode_steps = 0
        self.cache.reset_stats()

    # -- metrics ------------------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        states = list(self.results.values())
        if not states:
            return {}
        wall = max(s.finish_time for s in states)
        e2e = np.array([s.finish_time - s.request.arrival_time for s in states])
        ttft = np.array(
            [s.first_token_time - s.request.arrival_time for s in states]
        )
        n_tok = sum(len(s.generated) for s in states)
        return {
            "requests": len(states),
            "generated_tokens": n_tok,
            "wall_s": float(wall),
            "tokens_per_s": float(n_tok / wall) if wall > 0 else float("inf"),
            "decode_steps": self._n_decode_steps,
            "step_ms_p50": float(np.percentile(self.step_times, 50) * 1e3) if self.step_times else 0.0,
            "latency_s_p50": float(np.percentile(e2e, 50)),
            "latency_s_p99": float(np.percentile(e2e, 99)),
            "ttft_s_p50": float(np.percentile(ttft, 50)),
            "ttft_s_p99": float(np.percentile(ttft, 99)),
            "preemptions": sum(s.n_preemptions for s in states),
            **self.cache.stats(),
        }
