"""Serving telemetry: request lifecycle tracing + a streaming metrics registry.

The mdspan paper's thesis is that orthogonal concerns — layout, element
representation — become cheap when they are expressed as composable policies
instead of scattered special cases. Observability is the same kind of concern:
this module makes it a LAYER the engine threads through its existing event
points rather than timers sprinkled into the hot path.

Two halves:

**EngineTrace** — a bounded ring buffer of timestamped lifecycle events,
emitted at every engine transition (enqueue, admit, chunk landings, CoW,
preemption, fused-window start/end, EOS/finish/reject, slow steps). Emission
is host-only and event-driven: the decode hot path emits NOTHING per token, so
the zero-per-token-D2H property of the fused step is untouched, and when the
trace is off (``EngineConfig.trace=False`` -> ``engine.trace is None``) every
site is a single ``is not None`` check. ``to_chrome()`` exports Chrome
trace-event JSON — one track per batch slot plus a scheduler track — that
opens directly in Perfetto (https://ui.perfetto.dev) or chrome://tracing.

**MetricsRegistry** — counters, gauges, and fixed-log-bucket histograms that
replace the engine's unbounded per-step Python lists. A histogram holds one
int per bucket (a few hundred total), so p50/p95/p99 survive million-step runs
in O(1) memory; ``percentile()`` is exact to within one bucket's relative
width (~7.5% at the default 32 buckets/decade — the tolerance the tests pin).

``validate_chrome_trace`` is the schema checker CI and the tests share: every
event carries the required keys, timestamps are sorted, and B/E duration
events pair up stack-wise per track.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------------
# streaming metrics: counters / gauges / log-bucket histograms
# ---------------------------------------------------------------------------------
class Counter:
    """Monotonic event count. O(1) memory, survives any run length."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-written value (pool occupancy, queue depth, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed log-bucket histogram: percentiles from O(1) memory.

    Buckets are geometric: ``buckets_per_decade`` per power of ten between
    ``lo`` and ``hi`` (values outside clamp into under/overflow buckets, their
    exact min/max still tracked). ``observe`` is a log10 + one increment — no
    allocation, so a million-step run costs the same memory as a ten-step one.
    ``percentile`` linearly interpolates inside the covering bucket, so its
    relative error is bounded by the bucket width ratio (10^(1/32) - 1 ~ 7.5%
    at the default resolution); the unit tests check this bound against exact
    numpy percentiles on recorded traces.
    """

    __slots__ = ("lo", "hi", "bpd", "_n", "counts", "count", "total",
                 "min", "max")

    def __init__(self, lo: float = 1e-7, hi: float = 1e3,
                 buckets_per_decade: int = 32):
        if not (lo > 0 and hi > lo):
            raise ValueError("need 0 < lo < hi")
        self.lo, self.hi, self.bpd = lo, hi, buckets_per_decade
        decades = math.log10(hi / lo)
        self._n = int(math.ceil(decades * buckets_per_decade))
        self.reset()

    def reset(self) -> None:
        # [underflow] + n log buckets + [overflow]
        self.counts = [0] * (self._n + 2)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self._n + 1
        return 1 + int(math.log10(v / self.lo) * self.bpd)

    def _edges(self, b: int) -> Tuple[float, float]:
        """(lower, upper) value edges of log bucket ``b`` (1-based)."""
        lo = self.lo * 10.0 ** ((b - 1) / self.bpd)
        hi = self.lo * 10.0 ** (b / self.bpd)
        return lo, hi

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (0..100) — within one bucket width of
        the exact order statistic; clamped to the observed [min, max]."""
        if not self.count:
            return 0.0
        target = (q / 100.0) * self.count
        seen = 0.0
        for b, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                if b == 0:
                    return self.min
                if b == self._n + 1:
                    return self.max
                lo, hi = self._edges(b)
                frac = (target - seen) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }


class MetricsRegistry:
    """Named counters/gauges/histograms behind one create-or-get surface.

    The engine's ``metrics()`` is a ``snapshot()`` over this registry plus the
    allocator's stats — the flat dict the bench suite consumes is unchanged,
    but nothing underneath it grows with the number of steps.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(**kw)
        return h

    def reset(self) -> None:
        """Zero every instrument, keeping registrations (and histogram bucket
        geometry) intact — what ``ServeEngine.reset_metrics`` calls between a
        bench rehearsal and its measured pass."""
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for h in self._histograms.values():
            h.reset()

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._histograms.items():
            out[name] = h.snapshot()
        return out


# ---------------------------------------------------------------------------------
# request lifecycle tracing
# ---------------------------------------------------------------------------------
SCHED_TRACK = -1  # tid 0 in the export; slot s exports as tid s + 1


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One lifecycle event. ``track`` is a batch slot id or SCHED_TRACK; ``ph``
    is the Chrome phase ("B"/"E" duration pair, "i" instant)."""

    ts_us: float
    ph: str
    name: str
    track: int
    args: Optional[Dict[str, Any]] = None


class EngineTrace:
    """Bounded ring buffer of engine lifecycle events.

    All emission is host-side appends of already-host-resident scalars — no
    device sync, no per-token work. The buffer is a ``deque(maxlen=capacity)``:
    a long run wraps instead of growing, and ``to_chrome`` repairs the
    truncated track prefixes/suffixes so the export is always schema-valid.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._t0 = time.perf_counter()
        self.dropped = 0

    # -- emission (the engine-facing API) -----------------------------------------
    def _ts(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6  # Chrome ts is in us

    def _push(self, ev: TraceEvent) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def instant(self, name: str, track: int = SCHED_TRACK, **args) -> None:
        self._push(TraceEvent(self._ts(), "i", name, track, args or None))

    def begin(self, name: str, track: int, **args) -> None:
        self._push(TraceEvent(self._ts(), "B", name, track, args or None))

    def end(self, name: str, track: int, **args) -> None:
        self._push(TraceEvent(self._ts(), "E", name, track, args or None))

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0
        self._t0 = time.perf_counter()

    # -- inspection (tests treat this as the host-side log) ------------------------
    @property
    def events(self) -> List[TraceEvent]:
        return list(self._events)

    def count(self, name: str, ph: Optional[str] = None) -> int:
        return sum(
            1 for e in self._events
            if e.name == name and (ph is None or e.ph == ph)
        )

    def __len__(self) -> int:
        return len(self._events)

    # -- export --------------------------------------------------------------------
    def to_chrome(self, pid: int = 1) -> Dict[str, Any]:
        """Chrome trace-event JSON: one track (tid) per batch slot + a
        scheduler track, with thread-name metadata so Perfetto labels them.
        Ring-buffer wraps can orphan B/E pairs at the edges; the export drops
        unmatched "E"s and closes unmatched "B"s at the final timestamp, so
        the result always passes ``validate_chrome_trace``."""
        events = sorted(self._events, key=lambda e: e.ts_us)
        out: List[Dict[str, Any]] = []
        tracks = sorted({e.track for e in events})
        for track in tracks:
            tid = 0 if track == SCHED_TRACK else track + 1
            name = "scheduler" if track == SCHED_TRACK else f"slot {track}"
            out.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "ts": 0, "args": {"name": name},
            })
        open_stacks: Dict[int, List[Dict[str, Any]]] = {t: [] for t in tracks}
        last_ts = events[-1].ts_us if events else 0.0
        for e in events:
            tid = 0 if e.track == SCHED_TRACK else e.track + 1
            rec: Dict[str, Any] = {
                "ph": e.ph, "name": e.name, "pid": pid, "tid": tid,
                "ts": e.ts_us, "cat": "serving",
            }
            if e.args:
                rec["args"] = e.args
            if e.ph == "i":
                rec["s"] = "t"  # thread-scoped instant
            elif e.ph == "B":
                open_stacks[e.track].append(rec)
            elif e.ph == "E":
                if not open_stacks[e.track]:
                    continue  # wrap orphan: the matching B fell off the ring
                open_stacks[e.track].pop()
            out.append(rec)
        for track, stack in open_stacks.items():
            tid = 0 if track == SCHED_TRACK else track + 1
            for rec in reversed(stack):
                out.append({
                    "ph": "E", "name": rec["name"], "pid": pid, "tid": tid,
                    "ts": last_ts, "cat": "serving",
                })
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path) -> None:
        from pathlib import Path

        Path(path).write_text(json.dumps(self.to_chrome()))


def validate_chrome_trace(trace: Dict[str, Any]) -> None:
    """Schema-check an exported trace; raises ValueError on the first defect.

    Checks (what CI and the tests gate on):
      * top level is {"traceEvents": [...]} with every event a dict carrying
        ph/pid/tid/name, and ts for non-metadata phases;
      * timestamps are non-decreasing (the exporter sorts; Perfetto tolerates
        unsorted input, our schema does not);
      * per (pid, tid) track, "B" and "E" duration events pair up under stack
        discipline with matching names, and no track ends with an open "B".
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    last_ts = None
    stacks: Dict[Tuple[int, int], List[str]] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not a dict")
        for key in ("ph", "pid", "tid", "name"):
            if key not in e:
                raise ValueError(f"event {i} missing required key {key!r}")
        ph = e["ph"]
        if ph == "M":
            continue
        if "ts" not in e:
            raise ValueError(f"event {i} ({e['name']!r}) missing ts")
        ts = e["ts"]
        if last_ts is not None and ts < last_ts:
            raise ValueError(
                f"event {i} ({e['name']!r}): ts {ts} < previous {last_ts} — "
                "trace not sorted"
            )
        last_ts = ts
        track = (e["pid"], e["tid"])
        stack = stacks.setdefault(track, [])
        if ph == "B":
            stack.append(e["name"])
        elif ph == "E":
            if not stack:
                raise ValueError(
                    f"event {i}: 'E' for {e['name']!r} on track {track} "
                    "with no open 'B'"
                )
            opened = stack.pop()
            if opened != e["name"]:
                raise ValueError(
                    f"event {i}: 'E' for {e['name']!r} closes open "
                    f"'B' {opened!r} on track {track}"
                )
        elif ph not in ("i", "I", "C"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
    for track, stack in stacks.items():
        if stack:
            raise ValueError(f"track {track} ends with open 'B' events: {stack}")
