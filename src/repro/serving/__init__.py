from .grammar import (
    JSON_ARRAY_CHARS,
    MASK_OFF,
    TokenDFA,
    fixed_json_array_dfa,
    json_array_dfa,
)
from .params import (
    FINISH_EOS,
    FINISH_ERROR,
    FINISH_LENGTH,
    GenerationParams,
    RequestHandle,
    Sequence,
)
from .sampling import GREEDY, SamplingParams, stream_seed
from .step import (
    make_paged_serve_multistep,
    make_paged_serve_step,
    make_prefill,
    make_serve_step,
)

__all__ = [
    "FINISH_EOS",
    "FINISH_ERROR",
    "FINISH_LENGTH",
    "GREEDY",
    "GenerationParams",
    "JSON_ARRAY_CHARS",
    "MASK_OFF",
    "RequestHandle",
    "SamplingParams",
    "Sequence",
    "TokenDFA",
    "fixed_json_array_dfa",
    "json_array_dfa",
    "make_paged_serve_multistep",
    "make_paged_serve_step",
    "make_prefill",
    "make_serve_step",
    "stream_seed",
]
