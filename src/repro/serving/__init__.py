from .sampling import GREEDY, SamplingParams, stream_seed
from .step import (
    make_paged_serve_multistep,
    make_paged_serve_step,
    make_prefill,
    make_serve_step,
)

__all__ = [
    "GREEDY",
    "SamplingParams",
    "make_paged_serve_multistep",
    "make_paged_serve_step",
    "make_prefill",
    "make_serve_step",
    "stream_seed",
]
