from .step import make_prefill, make_serve_step

__all__ = ["make_prefill", "make_serve_step"]
