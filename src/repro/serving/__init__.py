from .step import make_paged_serve_step, make_prefill, make_serve_step

__all__ = ["make_paged_serve_step", "make_prefill", "make_serve_step"]
