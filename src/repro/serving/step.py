"""serve_step / prefill factories (batched decode against sharded KV caches)."""
from __future__ import annotations

from repro.models.layers import Sharder


def make_serve_step(model, mesh=None, rules=None):
    shard = Sharder(mesh, rules)

    def serve_step(params, caches, tokens, pos):
        """tokens: (B,) int32; pos: int32 scalar -> (logits (B, Vp), new caches)."""
        return model.decode_step(params, caches, tokens, pos, shard=shard)

    return serve_step


def make_prefill(model, mesh=None, rules=None, max_len=None):
    shard = Sharder(mesh, rules)

    def prefill(params, tokens, batch_inputs=None):
        return model.prefill(
            params, tokens, batch_inputs=batch_inputs, shard=shard, max_len=max_len
        )

    return prefill
