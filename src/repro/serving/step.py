"""serve_step / prefill factories (batched decode against sharded KV caches)."""
from __future__ import annotations

from repro.models.layers import Sharder


def make_serve_step(model, mesh=None, rules=None):
    shard = Sharder(mesh, rules)

    def serve_step(params, caches, tokens, pos):
        """tokens: (B,) int32; pos: int32 scalar -> (logits (B, Vp), new caches)."""
        return model.decode_step(params, caches, tokens, pos, shard=shard)

    return serve_step


def make_paged_serve_step(model, mesh=None, rules=None, attn_impl="auto", kv_spec=None):
    shard = Sharder(mesh, rules)

    def paged_serve_step(params, caches, tokens, block_tables, context_lens):
        """tokens: (B,) int32; block_tables: (B, max_pages) int32; context_lens:
        (B,) int32 per-sequence positions -> (logits (B, Vp), new page pools).

        Each row scatters its token's KV at page block_tables[b, lens[b]//ps],
        slot lens[b] % ps. The caller (Scheduler.ensure_decode_page) must have
        made every targeted page private (refcount 1) first: under prefix
        sharing a block-table entry may alias a page other sequences read, and
        this step writes unconditionally — copy-on-write happens on the host
        BEFORE the tables are handed to the device step. ``kv_spec``
        (PagedQuantSpec) selects quantized {q, scale} pools; the write then
        quantizes at scatter time and attention dequantizes in-kernel."""
        return model.decode_step_paged(
            params, caches, tokens, block_tables, context_lens,
            shard=shard, attn_impl=attn_impl, kv_spec=kv_spec,
        )

    return paged_serve_step


def make_chunked_prefill_step(model, mesh=None, rules=None, attn_impl="auto",
                              kv_spec=None):
    shard = Sharder(mesh, rules)

    def chunk_prefill_step(params, caches, tokens, block_tables, write_tables,
                           cursors, n_new, last_index):
        """The mixed step's prefill half: tokens (B, C) — one prefill chunk per
        row, C the engine's chunk bucket -> (logits (B, Vp) at last_index, new
        page pools). ``cursors`` (chunk start), ``n_new`` and ``last_index``
        are traced, so ONE compile serves every chunk position of every prompt
        length in the bucket — there is no per-prompt-length prefill compile in
        the chunked engine. ``block_tables`` is the read view of each row's
        pages (shared prefix included: the compute-skip path attends the
        donor's KV); ``write_tables`` nulls the non-writable entries so the
        chunk's scatter never lands in a page another sequence reads — the CoW
        obligation discharged by table surgery instead of a copy."""
        return model.decode_step_paged(
            params, caches, tokens, block_tables, cursors,
            shard=shard, attn_impl=attn_impl, kv_spec=kv_spec,
            write_tables=write_tables, n_new=n_new, last_index=last_index,
        )

    return chunk_prefill_step


def make_prefill(model, mesh=None, rules=None, max_len=None):
    shard = Sharder(mesh, rules)

    def prefill(params, tokens, batch_inputs=None, last_index=None):
        return model.prefill(
            params, tokens, batch_inputs=batch_inputs, shard=shard,
            max_len=max_len, last_index=last_index,
        )

    return prefill
