"""serve_step / prefill factories (batched decode against sharded KV caches).

The paged factories implement the DEVICE-RESIDENT decode hot path: token
selection (serving/sampling.py policies via ops.sample_tokens) is fused into
the step so logits never leave the device, the per-slot lengths advance on
device (the step returns ``context_lens + active`` for the engine to adopt as
its persistent mirror), and ``make_paged_serve_multistep`` runs K such
iterations in one on-device ``lax.scan`` — the sampled token feeds straight
back into the next embedding lookup, amortizing one dispatch and one (K, B)
ids transfer over K generated tokens.

The speculative sibling lives in serving/speculative.py:
``make_paged_serve_spec_multistep`` scans S draft->verify->accept WINDOWS
instead of S single-token steps, committing 1..K+1 tokens per window through
the same fused sampling and lens plumbing — an engine with ``spec_tokens>0``
swaps that factory in where this module's multistep would go, and everything
else here (prefill buckets, chunked prefill, the plain step it falls back to
under backoff) is shared between the two regimes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import Sharder


def make_serve_step(model, mesh=None, rules=None):
    shard = Sharder(mesh, rules)

    def serve_step(params, caches, tokens, pos):
        """tokens: (B,) int32; pos: int32 scalar -> (logits (B, Vp), new caches)."""
        return model.decode_step(params, caches, tokens, pos, shard=shard)

    return serve_step


def top_logprobs(logits, vocab: int, k: int):
    """(vals (B, k), ids (B, k)): the top-k log-probabilities of each row's
    next-token distribution, computed ON DEVICE from the same logits the
    sampler consumes (pad columns excluded). The (B, k) pair rides the same
    per-step D2H fetch as the sampled ids — no extra sync point."""
    lp = jax.nn.log_softmax(logits[:, :vocab].astype(jnp.float32), axis=-1)
    return jax.lax.top_k(lp, k)


def _fused_decode(model, shard, attn_impl, kv_spec, vocab, params, caches,
                  tokens, block_tables, context_lens, slot_f32, slot_i32,
                  grammar=None, block_pages=None):
    """One fused decode iteration: append -> attend -> sample, all on device.

    The per-slot policy rides in TWO packed vectors (device_put on this
    backend costs ~1ms per array regardless of size, so the engine uploads
    exactly two on a slot-composition change, never six):
      slot_f32 (2, B) f32: [temperature, top_p]
      slot_i32 (3, B) i32: [active, top_k, seed-bits (uint32 reinterpreted)]
    ``active`` is the phase bitmap (masked slots null-route on device — see
    decode_step_paged); the sampled position folds ``context_lens + 1``, the
    length of the context the new token extends, so sampling is invariant
    under preemption-recompute and batch recomposition.

    ``grammar`` (None or (gstate (B,) i32, gmask (S, vocab) f32, gtrans
    (S, vocab) i32)) is the constrained-decoding stage: each slot's mask row is
    gathered by its automaton state and ADDED to the logits inside the sampler,
    and the state advances by the token just sampled — the grammar walks
    entirely on device, preserving the decode loop's zero-D2H property. Row 0
    of the tables is the reserved unconstrained state (all-zero mask,
    self-loops), so ungated slots ride the same program.

    Returns (next_tokens (B,) i32, logits (B, Vp), new_lens (B,) i32, caches,
    chosen_lp (B,) f32[, new_gstate (B,) i32 when grammar]). ``chosen_lp`` is
    log P(next_token | prefix) under the UNMASKED distribution — the per-branch
    cumulative score best-of-n ranks by (a grammar constrains selection, not
    the score).
    """
    active = slot_i32[0]
    logits, caches = model.decode_step_paged(
        params, caches, tokens, block_tables, context_lens,
        shard=shard, attn_impl=attn_impl, kv_spec=kv_spec, active=active,
        block_pages=block_pages,
    )
    mask = None
    if grammar is not None:
        gstate, gmask, gtrans = grammar
        mask = gmask[gstate]  # (B, vocab) per-slot additive penalty rows
    nxt = ops.sample_tokens(
        logits, slot_f32[0], slot_i32[1], slot_f32[1],
        slot_i32[2].astype(jnp.uint32),  # i32 -> u32 wraps: bit-identical
        context_lens + 1, vocab=vocab, mask=mask,
    )
    new_lens = context_lens + jnp.where(active > 0, 1, 0).astype(context_lens.dtype)
    lp = jax.nn.log_softmax(logits[:, :vocab].astype(jnp.float32), axis=-1)
    chosen_lp = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
    if grammar is None:
        return nxt, logits, new_lens, caches, chosen_lp
    new_gstate = jnp.where(active > 0, gtrans[gstate, nxt], gstate)
    return nxt, logits, new_lens, caches, chosen_lp, new_gstate


def make_paged_serve_step(model, mesh=None, rules=None, attn_impl="auto",
                          kv_spec=None, vocab=None, logprobs_k=0,
                          grammar=False, block_pages=None):
    shard = Sharder(mesh, rules)

    if vocab is None:
        # legacy unfused step: logits come back to the host (kept for external
        # callers and as the reference semantics the fused path must reproduce)
        def paged_serve_step(params, caches, tokens, block_tables, context_lens):
            """tokens: (B,) int32; block_tables: (B, max_pages) int32;
            context_lens: (B,) int32 per-sequence positions -> (logits (B, Vp),
            new page pools). Each row scatters its token's KV at page
            block_tables[b, lens[b]//ps], slot lens[b] % ps; the caller must
            have made every targeted page private (CoW on the host) first."""
            return model.decode_step_paged(
                params, caches, tokens, block_tables, context_lens,
                shard=shard, attn_impl=attn_impl, kv_spec=kv_spec,
                block_pages=block_pages,
            )

        return paged_serve_step

    def fused_serve_step(params, caches, tokens, block_tables, context_lens,
                         slot_f32, slot_i32, *g):
        """The device-resident decode step: one batched token per active slot,
        SAMPLED on device (greedy/temperature/top-k/top-p per slot, packed in
        slot_f32/slot_i32 — see _fused_decode). The only per-token D2H traffic
        is the (B,) next_tokens output; logits are returned for the opt-in
        record_logits slow path and cost nothing when the host never fetches
        them. ``context_lens`` is the engine's device-resident lens mirror
        (donated); ``new_lens`` is its successor — the LayoutPaged
        index->offset state advances beside the pool it indexes, no host
        round-trip. ``chosen_lp`` always rides the output pytree (same fetch
        round as the ids; free when the host ignores it). With ``logprobs_k >
        0`` the step additionally returns the per-slot (vals, ids) top-k
        logprob pair (compile-time width). With ``grammar`` the factory adds
        three positional args — gstate (B,) i32 (donated, like the lens
        mirror), gmask (S, vocab) f32, gtrans (S, vocab) i32 — and returns the
        advanced gstate after chosen_lp."""
        out = _fused_decode(
            model, shard, attn_impl, kv_spec, vocab, params, caches,
            tokens, block_tables, context_lens, slot_f32, slot_i32,
            grammar=tuple(g) if grammar else None, block_pages=block_pages,
        )
        if not logprobs_k:
            return out
        return out + (top_logprobs(out[1], vocab, logprobs_k),)

    return fused_serve_step


def make_paged_serve_multistep(model, k_steps: int, mesh=None, rules=None,
                               attn_impl="auto", kv_spec=None, vocab=None,
                               logprobs_k=0, grammar=False, block_pages=None):
    """K fused decode iterations in one on-device loop (jax.lax.scan).

    Legal only over an event-free horizon (Scheduler.event_free_horizon): no
    admission, no page-boundary crossing past owned capacity, no CoW, no
    max-token finish within K — so the loop body never needs the host. Each
    iteration appends the current token's KV, attends, samples, and feeds the
    sampled token into the next iteration's embedding lookup; lengths advance
    on device. Returns (tokens_per_step (K, B) i32, last_tokens (B,),
    new_lens (B,), caches, chosen_lps (K, B) f32) — one dispatch and one
    (K, B) fetch round per K generated tokens. With ``grammar`` the per-slot
    automaton state rides the scan CARRY exactly like the lengths do (the K
    masks and transitions all happen inside the loop — constrained decoding
    costs zero extra host round-trips even fused), and the advanced gstate is
    returned after the chosen_lps. With ``logprobs_k > 0`` the scan
    additionally stacks the per-step top-k logprob pair ((K, B, k) vals +
    ids), fetched in the same round as the ids.
    """
    shard = Sharder(mesh, rules)

    def fused_multistep(params, caches, tokens, block_tables, context_lens,
                        slot_f32, slot_i32, *g):
        def body(carry, _):
            toks, lens, gs, cs = carry
            out = _fused_decode(
                model, shard, attn_impl, kv_spec, vocab, params, cs,
                toks, block_tables, lens, slot_f32, slot_i32,
                grammar=(gs, g[1], g[2]) if grammar else None,
                block_pages=block_pages,
            )
            nxt, logits, new_lens, cs, chosen_lp = out[:5]
            new_gs = out[5] if grammar else gs
            y = (nxt, chosen_lp) if not logprobs_k else (
                nxt, chosen_lp, top_logprobs(logits, vocab, logprobs_k)
            )
            return (nxt, new_lens, new_gs, cs), y

        gs0 = g[0] if grammar else jnp.zeros_like(context_lens)
        (last, new_lens, gs, caches), ys = jax.lax.scan(
            body, (tokens, context_lens, gs0, caches), None, length=k_steps
        )
        toks, lps = ys[0], ys[1]
        out = (toks, last, new_lens, caches, lps)
        if grammar:
            out = out + (gs,)
        if logprobs_k:
            out = out + (ys[2],)
        return out

    return fused_multistep


def make_chunked_prefill_step(model, mesh=None, rules=None, attn_impl="auto",
                              kv_spec=None):
    shard = Sharder(mesh, rules)

    def chunk_prefill_step(params, caches, tokens, block_tables, write_tables,
                           cursors, n_new, last_index):
        """The mixed step's prefill half: tokens (B, C) — one prefill chunk per
        row, C the engine's chunk bucket -> (logits (B, Vp) at last_index, new
        page pools). ``cursors`` (chunk start), ``n_new`` and ``last_index``
        are traced, so ONE compile serves every chunk position of every prompt
        length in the bucket — there is no per-prompt-length prefill compile in
        the chunked engine. ``block_tables`` is the read view of each row's
        pages (shared prefix included: the compute-skip path attends the
        donor's KV); ``write_tables`` nulls the non-writable entries so the
        chunk's scatter never lands in a page another sequence reads — the CoW
        obligation discharged by table surgery instead of a copy."""
        return model.decode_step_paged(
            params, caches, tokens, block_tables, cursors,
            shard=shard, attn_impl=attn_impl, kv_spec=kv_spec,
            write_tables=write_tables, n_new=n_new, last_index=last_index,
        )

    return chunk_prefill_step


def make_prefill(model, mesh=None, rules=None, max_len=None):
    shard = Sharder(mesh, rules)

    def prefill(params, tokens, batch_inputs=None, last_index=None):
        return model.prefill(
            params, tokens, batch_inputs=batch_inputs, shard=shard,
            max_len=max_len, last_index=last_index,
        )

    return prefill
