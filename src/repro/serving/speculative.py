"""Speculative decoding inside the fused window — drafts, one-call verify,
lens-rollback accept.

PRs 1–8 established that a layout (LayoutPaged) and accessor (PagedQuantSpec,
CountingAccessor) are customization points you EXTEND rather than special-case.
Speculation is the next extension, and it needs no new memory format at all —
only a new iteration scheme over the existing paged view:

  * **propose** — a device-resident n-gram hash table over each request's
    prompt+generated tokens proposes a K-token continuation (prompt-lookup
    decoding: repetitive and agentic workloads quote their own context
    constantly, so the cheapest possible draft model is the context itself).
    No second model, no extra forward pass — two gathers and a hash.
  * **verify** — ONE chunk-style attention call scores all K draft positions
    against the paged past: the verify pass is literally a prefill chunk whose
    "present" is [current token, draft] (core/submdspan.py §verification is a
    chunk). The target model runs once per window regardless of K.
  * **accept** — keep the longest draft prefix the target agrees with
    (argmax agreement when greedy — token-exact vs non-speculative decode by
    construction — or rejection sampling at temperature > 0), plus one
    correction/bonus token the target supplies for free.
  * **rollback** — the rejected suffix is pure layout arithmetic: positions
    >= the accepted length are simply not covered by the advanced ``lens``,
    and later appends overwrite them. No page frees, no copies — the
    scheduler pre-reserved the window's page budget
    (Scheduler.reserve_decode_tokens), so mid-window appends never touch the
    host either.

The whole propose->verify->accept loop runs inside the fused ``lax.scan``
(make_paged_serve_spec_multistep, the speculative sibling of
step.make_paged_serve_multistep): S windows per dispatch, hist/table riding
the carry next to the lens mirror, one (S, B, C) ids fetch per S windows —
the zero-D2H steady state of PR 5 is preserved while each target-model step
now commits up to K+1 tokens.

Draft-source abstraction: ``NGramProposer`` implements the ``DraftProposer``
protocol; ``ModelDraftProposer`` stubs the registry-draft-model variant behind
the same protocol for a later PR.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.layers import Sharder

from .step import top_logprobs

# FNV-1a over int32 token ids, in uint32 arithmetic — chosen because the exact
# same five lines express it in NumPy (host rebuild) and jnp (device insert),
# and device/host agreement is load-bearing: the table must be a pure function
# of the token context (preemption-recompute invariance).
_FNV_INIT = 2166136261
_FNV_MULT = 16777619


def ngram_keys_jnp(grams: jax.Array, table_size: int) -> jax.Array:
    """grams (..., g) int32 -> (...,) int32 bucket in [0, table_size)."""
    h = jnp.full(grams.shape[:-1], _FNV_INIT, jnp.uint32)
    for i in range(grams.shape[-1]):
        h = (h ^ grams[..., i].astype(jnp.uint32)) * jnp.uint32(_FNV_MULT)
    return (h & jnp.uint32(table_size - 1)).astype(jnp.int32)


def ngram_keys_np(grams: np.ndarray, table_size: int) -> np.ndarray:
    """NumPy twin of ngram_keys_jnp — bit-identical buckets (tests pin it)."""
    grams = np.asarray(grams, np.int32)
    h = np.full(grams.shape[:-1], _FNV_INIT, np.uint32)
    with np.errstate(over="ignore"):
        for i in range(grams.shape[-1]):
            h = (h ^ grams[..., i].astype(np.uint32)) * np.uint32(_FNV_MULT)
    return (h & np.uint32(table_size - 1)).astype(np.int32)


class DraftProposer:
    """Protocol for speculative draft sources.

    A proposer owns two persistent per-slot device arrays — ``hist`` (the
    token history, hist[b, i] = sequence token at position i) and ``table``
    (whatever index the proposer maintains over it) — that ride the fused
    scan's carry exactly like the lens mirror does. Methods:

      rebuild_row(context)         host: (hist_row, table_row) from a token
                                   list — the recompute path (admission,
                                   preemption, any host-side divergence)
      propose(hist, table, lens, active)          traced: -> draft (B, K)
      update(hist, table, lens, tokens_out,
             committed, active)                   traced: fold one verified
                                                  window back in
    """

    spec_tokens: int

    def rebuild_row(self, context) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def propose(self, hist, table, lens, active):
        raise NotImplementedError

    def update(self, hist, table, lens, tokens_out, committed, active):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class NGramProposer(DraftProposer):
    """Prompt-lookup drafting: propose the K tokens that followed the most
    recent earlier occurrence of the current ``ngram``-gram.

    ``table[b, key]`` holds the END position q of the latest n-gram hashing to
    ``key`` (0 = empty — position 0 can never legally end a gram since
    ngram >= 2; column ``table_size`` is a dump slot for masked writes, so
    inactive rows and rejected positions update THROUGH the same scatter with
    no branching). Insertion follows the SHIFTED rule: the gram ending at q is
    inserted only once token q+1 is known — a lookup therefore always finds a
    strictly EARLIER occurrence with a known continuation, never the suffix
    currently being extended (the self-match that would kill drafting on
    exactly the repetitive text speculation targets).

    Hash collisions and recycled buckets only ever produce a WRONG draft,
    never a wrong result — verify rejects it (the stored gram is re-checked
    against the key gram anyway, so collisions mostly cost nothing). Both
    hist and table are pure functions of the token context, so
    preemption-recompute rebuilds them exactly (rebuild_row == the device
    insertion history; tests pin this).
    """

    spec_tokens: int
    ngram: int = 2
    table_size: int = 512
    vocab: int = 32000
    hist_len: int = 0

    def __post_init__(self):
        if self.ngram < 2:
            raise ValueError("spec_ngram must be >= 2 (a 1-gram lookup would "
                             "match its own last token)")
        if self.table_size & (self.table_size - 1):
            raise ValueError("spec_table_size must be a power of two")
        if self.hist_len <= 0:
            raise ValueError("hist_len must cover max context + window")

    # ---- host (recompute path) --------------------------------------------
    def rebuild_row(self, context) -> Tuple[np.ndarray, np.ndarray]:
        """context: the request's prompt+generated tokens (the current token
        last). Replays the device insertion order: gram ending at q inserted
        for q = ngram-1 .. n-2 ascending (last write wins per bucket)."""
        toks = np.asarray(list(context), np.int32)
        n = len(toks)
        hist = np.zeros(self.hist_len, np.int32)
        hist[:n] = toks[:self.hist_len]
        table = np.zeros(self.table_size + 1, np.int32)
        g = self.ngram
        if n >= g + 1:
            ends = np.arange(g - 1, n - 1)
            grams = np.stack([toks[ends - (g - 1) + i] for i in range(g)], axis=-1)
            keys = ngram_keys_np(grams, self.table_size)
            for q, key in zip(ends, keys):
                table[int(key)] = int(q)
        return hist, table

    # ---- device (in-scan path) --------------------------------------------
    def propose(self, hist, table, lens, active):
        """-> draft (B, K) int32. lens[b] = current token's position (the last
        KNOWN index of hist); the key is the g-gram ending there."""
        b, hl = hist.shape
        g = self.ngram
        idx = lens[:, None] + jnp.arange(-g + 1, 1)[None, :]  # (B, g)
        grams = jnp.take_along_axis(hist, jnp.clip(idx, 0, hl - 1), axis=1)
        key = ngram_keys_jnp(grams, self.table_size)  # (B,)
        cand = table[jnp.arange(b), key]  # (B,) end position of the match
        cidx = cand[:, None] + jnp.arange(-g + 1, 1)[None, :]
        cgrams = jnp.take_along_axis(hist, jnp.clip(cidx, 0, hl - 1), axis=1)
        ok = (cand > 0) & (cand < lens) & (cand >= g - 1)
        ok = ok & jnp.all(cgrams == grams, axis=1) & (active > 0)
        didx = cand[:, None] + jnp.arange(1, self.spec_tokens + 1)[None, :]
        draft = jnp.take_along_axis(hist, jnp.clip(didx, 0, hl - 1), axis=1)
        draft = jnp.clip(draft, 0, self.vocab - 1)
        return jnp.where(ok[:, None], draft, 0)

    def update(self, hist, table, lens, tokens_out, committed, active):
        """Fold a verified window in: write the window's tokens at positions
        lens+1.. (rows past ``committed`` are garbage the NEXT window's write
        overwrites — it starts at the new lens+1), then insert the grams whose
        continuation just became known (ends q = lens+j, j < committed)."""
        b, hl = hist.shape
        c = tokens_out.shape[1]
        g = self.ngram
        start = jnp.where(active > 0, lens + 1, hl)  # inactive -> clamped tail
        hist = jax.vmap(
            lambda row, toks, s: jax.lax.dynamic_update_slice(row, toks, (s,))
        )(hist, tokens_out.astype(hist.dtype), start)
        rows = jnp.arange(b)
        for j in range(c):
            q = lens + j
            gidx = q[:, None] + jnp.arange(-g + 1, 1)[None, :]
            grams = jnp.take_along_axis(hist, jnp.clip(gidx, 0, hl - 1), axis=1)
            key = ngram_keys_jnp(grams, self.table_size)
            valid = (j < committed) & (active > 0) & (q >= g - 1)
            col = jnp.where(valid, key, self.table_size)  # masked -> dump col
            table = table.at[rows, col].set(q.astype(table.dtype))
        return hist, table


@dataclasses.dataclass(frozen=True)
class ModelDraftProposer(DraftProposer):
    """Registry-model drafting behind the same protocol — a LATER PR: a small
    draft model from the model registry runs its own fused decode for K cheap
    tokens, and verify/accept/rollback are unchanged (the protocol is the
    point: the engine never learns where drafts come from). Construction is
    allowed so configs can name it; use raises."""

    spec_tokens: int
    draft_model: str = ""

    def _todo(self):
        raise NotImplementedError(
            "registry-draft-model speculation is stubbed behind DraftProposer; "
            "use NGramProposer (EngineConfig.spec_tokens) for now"
        )

    def rebuild_row(self, context):
        self._todo()

    def propose(self, hist, table, lens, active):
        self._todo()

    def update(self, hist, table, lens, tokens_out, committed, active):
        self._todo()


def make_paged_serve_spec_multistep(model, windows: int, proposer, mesh=None,
                                    rules=None, attn_impl="auto", kv_spec=None,
                                    vocab=None, logprobs_k=0):
    """S speculative windows in one on-device ``lax.scan`` — the speculative
    sibling of step.make_paged_serve_multistep.

    Each window: propose K draft tokens from the n-gram table, run ONE verify
    pass (decode_step_paged(spec_verify=True) — a chunk whose present is
    [current, draft]), accept/resample via ops.verify_draft_tokens, advance
    ``lens`` by the committed count (rollback = the rejected suffix simply not
    being covered), and fold the committed tokens back into hist/table for the
    NEXT window's proposal. Legal only under the same event-free-horizon
    contract as the plain multistep, with tokens_per_step = K+1
    (Scheduler.event_free_horizon) and the page budget pre-reserved
    (Scheduler.reserve_decode_tokens) so no append ever crosses into
    unowned pages.

    Signature: (params, caches, tokens (B,), block_tables, context_lens,
    slot_f32 (2, B), slot_i32 (3, B), hist (B, L), table (B, H+1)).
    Returns (tokens (S, B, C) i32, committed (S, B) i32, last (B,) i32,
    new_lens (B,) i32, caches, chosen_lps (S, B, C) f32, hist, table
    [, (vals, ids) (S, B, C, k) when logprobs_k]): one dispatch and one
    (S, B, C) fetch per up-to-S*(K+1) generated tokens.
    """
    shard = Sharder(mesh, rules)
    c = proposer.spec_tokens + 1

    def spec_multistep(params, caches, tokens, block_tables, context_lens,
                       slot_f32, slot_i32, hist, table):
        active = slot_i32[0]

        def body(carry, _):
            toks, lens, hs, tb, cs = carry
            draft = proposer.propose(hs, tb, lens, active)  # (B, K)
            present = jnp.concatenate([toks[:, None], draft], axis=1)  # (B, C)
            logits, cs = model.decode_step_paged(
                params, cs, present, block_tables, lens, shard=shard,
                attn_impl=attn_impl, kv_spec=kv_spec, active=active,
                spec_verify=True,
            )  # (B, C, Vp)
            tok_out, committed, lp = ops.verify_draft_tokens(
                logits, draft, slot_f32[0], slot_i32[1], slot_f32[1],
                slot_i32[2].astype(jnp.uint32), lens + 1, active, vocab=vocab,
            )
            new_lens = lens + committed.astype(lens.dtype)
            b = tok_out.shape[0]
            last = tok_out[jnp.arange(b), jnp.maximum(committed - 1, 0)]
            nxt = jnp.where(active > 0, last, toks)
            hs, tb = proposer.update(hs, tb, lens, tok_out, committed, active)
            y = (tok_out, committed, lp)
            if logprobs_k:
                vals, ids = top_logprobs(logits.reshape(b * c, -1), vocab,
                                         logprobs_k)
                y = y + ((vals.reshape(b, c, -1), ids.reshape(b, c, -1)),)
            return (nxt, new_lens, hs, tb, cs), y

        (last, new_lens, hist, table, caches), ys = jax.lax.scan(
            body, (tokens, context_lens, hist, table, caches), None,
            length=windows,
        )
        out = (ys[0], ys[1], last, new_lens, caches, ys[2], hist, table)
        if logprobs_k:
            out = out + (ys[3],)
        return out

    return spec_multistep
