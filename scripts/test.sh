#!/usr/bin/env bash
# Tier-1 verify: the whole suite, fail-fast, against src/ (no install needed).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
