"""Benchmark timing helpers (compiled-code wall-clock on CPU)."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time (µs) of a jitted callable, blocking on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def hlo_ops(fn, *args) -> list:
    """Sorted op-kind histogram of the optimized HLO (structural comparison)."""
    import collections
    import re

    txt = jax.jit(fn).lower(*args).compile().as_text()
    counts = collections.Counter()
    for line in txt.splitlines():
        m = re.search(r"= \S+ ([a-z0-9-]+)\(", line)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items())
