"""Benchmark harness entrypoint: one function per paper table/figure + the
roofline reader. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run              # paper + roofline + serving
  PYTHONPATH=src python -m benchmarks.run --only paper
  PYTHONPATH=src python -m benchmarks.run --only roofline
  PYTHONPATH=src python -m benchmarks.run --only serving   # writes BENCH_serving.json
  PYTHONPATH=src python -m benchmarks.run --only perf-matrix  # writes BENCH_perf_matrix.json
  PYTHONPATH=src python -m benchmarks.run --oversubscribe  # host-tier section only
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default="all",
        choices=["all", "paper", "roofline", "serving", "perf-matrix"],
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized runs: serving = one sweep point, tiny model, few "
             "requests; perf-matrix = the reduced 8-cell grid",
    )
    ap.add_argument(
        "--no-ratchet", action="store_true",
        help="perf-matrix only: skip the per-cell comparison against the "
             "committed BENCH_perf_matrix.json (use when intentionally "
             "regenerating the baseline after a perf-moving change)",
    )
    ap.add_argument(
        "--kv-dtype", default="all", choices=["all", "f32", "int8", "int4"],
        help="KV page representations to compare in the serving suite's "
             "quantized section (f32 always runs as the baseline)",
    )
    ap.add_argument(
        "--oversubscribe", action="store_true",
        help="run ONLY the serving suite's hierarchical-KV host-tier "
             "section, smoke-sized (session resume vs recompute, sustained "
             "decode under pool oversubscription, enabled-but-idle "
             "overhead); prints the JSON report and never touches the "
             "committed BENCH_serving*.json",
    )
    args = ap.parse_args()
    if args.oversubscribe:
        import json

        from benchmarks import serving_suite

        report = serving_suite.run_hierarchical_kv(smoke=True)
        print(json.dumps(report, indent=2))
        return
    if args.only in ("all", "paper"):
        from benchmarks import paper_suite

        paper_suite.run_all()
    if args.only in ("all", "roofline"):
        from benchmarks import roofline

        if not list(Path("artifacts/dryrun").glob("*.json")):
            print("roofline,0,skipped (run repro.launch.dryrun first)")
        else:
            roofline.run()
    if args.only in ("all", "serving"):
        from benchmarks import serving_suite

        serving_suite.run(smoke=args.smoke, kv_dtype=args.kv_dtype)
    if args.only in ("all", "perf-matrix"):
        from benchmarks import perf_matrix

        perf_matrix.run(smoke=args.smoke, ratchet=not args.no_ratchet)


if __name__ == "__main__":
    main()
