"""Roofline-anchored performance matrix: the serving engine swept cell by cell.

Each cell of the (page_size x chunk_tokens x kv_dtype x max_batch x multi_step
x spec_tokens) grid runs a short steady-state decode workload (batch-full, fixed prompt and
tail lengths, rehearsal first so measurement times compiled code; every cell's
timing is the min over five measurement passes INTERLEAVED across the whole
grid — host interference arrives in multi-second bursts, and spreading a
cell's passes tens of seconds apart lets the min recover its capability) and
records:

  * step latency p50/p95 and decode tokens/s from the engine's own metrics;
  * MEASURED KV bytes per decode step — core.instrument's CountingAccessor
    driven over the cell's steady-state occupancy (same page_size / kv_dtype /
    context lengths the workload reaches mid-stream), through the flat
    accessor each representation really stores (BasicAccessor f32,
    QuantizedAccessor int8, Int4SplitHalfAccessor int4);
  * ANALYTIC bytes from ``roofline.paged_decode_analytic_bytes`` — the same
    number derived from the layout formula instead of counted accesses (the
    two must agree within 10%, recorded per cell);
  * roofline attainment: achieved GB/s divided by the STREAM-measured machine
    bandwidth (``roofline.measure_machine_bandwidth``, calibrated once per
    host and cached under artifacts/). Attainment above 1.0 is a measurement
    bug by construction and FAILS the run; attainment below the per-dtype
    floor is flagged in the report and the markdown table.

The matrix is a RATCHET: cells are keyed (``ps8_ck32_f32_b2_k1``, speculative
cells append ``_sp3``, host-tier cells append ``_hk``) and every
run compares itself against the committed ``BENCH_perf_matrix.json`` — any
cell whose step_ms_p50 regresses more than 20% vs its committed twin fails
the run (CI's perf-matrix-smoke job runs the reduced grid, whose keys are an
exact subset of the full grid, so smoke cells pair against full baselines).
Two defenses keep the 20% bound honest on noisy shared hosts: per-cell
ratios are normalized by the run's median paired ratio (uniform host drift —
thermal state, co-tenants, a slower CI runner — cancels; one cell regressing
against its peers still fails), and cells over the ratchet are re-measured
before the verdict stands (noise only adds time, so a retry at or under the
bound proves a burst; a real regression repeats).
Regenerate + commit the baseline when a PR intentionally moves decode perf:

  PYTHONPATH=src python -m benchmarks.run --only perf-matrix           # full, writes BENCH_perf_matrix.json
  PYTHONPATH=src python -m benchmarks.run --only perf-matrix --smoke   # CI grid -> artifacts/

The matrix also FEEDS the kernel autotuner (kernels/autotune.py): a closing
section builds one engine with ``EngineConfig.autotune=True`` (page_size=0 —
the tuner picks page size, decode block shape and chunk width from its
sweep-once cache) and one engine with fixed defaults, runs the same smoke
workload through both, and records that the autotuned engine is no slower —
plus the chosen config as surfaced by ``engine.metrics()``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks import roofline
from benchmarks.serving_suite import bench_config
from repro.core.accessors import BasicAccessor
from repro.core.instrument import CountingAccessor, counted_paged_decode
from repro.models import Model
from repro.serving import GenerationParams
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.engine.kvquant import KV_DTYPES

SCHEMA_VERSION = 3

OUT_PATH = Path("BENCH_perf_matrix.json")  # COMMITTED: the per-cell ratchet
# baseline. Smoke runs never clobber it; they pair their cells against it.
SMOKE_OUT_PATH = Path("artifacts/perf_matrix_smoke.json")
MD_PATH = Path("artifacts/perf_matrix.md")

# full grid: 2 x 2 x 3 x 2 x 2 = 48 plain + 4 speculative + 4 host-tier = 56
PAGE_SIZES = (8, 16)
CHUNKS = (32, 64)
KV_AXIS = ("f32", "int8", "int4")
BATCHES = (2, 4)
KS = (1, 4)

# speculative axis: plain cells run sp=0 (no draft/verify machinery in the
# dispatch); spec cells run sp=SPEC_SP draft tokens per window through the
# chunk-kernel verify path (serving/speculative.py). Spec cells pin
# chunk/batch/K to one plain combo so the _sp suffix is the ONLY difference
# from their sp=0 sibling — the pair prices the verify-window machinery
# itself. Backoff is disabled inside spec cells (spec_backoff=0): the random
# steady-state stream is incompressible, and the cell exists to time the
# window path, not the engine's decision to stop using it.
SPEC_SP = 3
SPEC_K = 4
SPEC_KV_AXIS = ("f32", "int8")

# host-tier axis: hk cells run the SAME steady workload through an engine
# whose HBM pool is deliberately too small for the batch (just roomy enough
# to admit two requests) plus a host pool sized for full demand — so every
# cell's measurement includes real preempt-demote / readmit-promote churn.
# The _hk suffix is the only difference from the sp=0 / k=1 sibling: the
# pair prices the swap machinery itself. K pinned to 1 because preemption
# events break the event-free horizons multi-step dispatch needs.
HK_KV_AXIS = ("f32", "int8")

# smoke grid: 2 x 2 x 2 = 8 plain + 2 speculative + 2 host-tier = 12, an
# EXACT SUBSET of the full grid (chunk and batch pinned to full-grid values)
# so every smoke cell has a committed twin
SMOKE_KV_AXIS = ("f32", "int8")
SMOKE_CHUNK = 32
SMOKE_BATCH = 2

# per-cell workload — identical in full and smoke runs, so smoke timings pair
# against full-run baselines apples-to-apples (smoke cuts CELLS, not work)
PROMPT_LEN = 16
NEW_TOKENS = 32

REGRESSION_X = 1.20  # any cell's step_ms_p50 beyond this vs baseline fails
_BUCKET_X = 10 ** (1 / 32)  # measurement-resolution allowance on top of
# REGRESSION_X: step_ms_p50 comes from the telemetry histogram's log-scale
# buckets (32 per decade), so the baseline and the current reading are each
# quantized to ~7.5% — a bucket-low baseline against a bucket-high current
# run shows a 1.16x "regression" with zero real change. The ratchet bounds
# TRUE latency at REGRESSION_X; the comparison of two quantized readings
# gets one bucket of slack so quantization alone can never trip it

# flag floors: fraction of measured machine bandwidth a healthy cell should
# clear. The bench model is tiny and dispatch-bound on CPU, so floors are
# sanity floors (~10x under the slowest healthy cell), not HBM targets;
# quantized pools sit lower than f32 because they move fewer bytes through
# the same dispatch overhead.
ATTAINMENT_FLOORS = {"f32": 5e-4, "int8": 1e-4, "int4": 5e-5}


def cell_key(ps: int, chunk: int, kv: str, batch: int, k: int,
             sp: int = 0, hk: int = 0) -> str:
    # sp=0 / hk=0 keys keep their earlier spelling so existing committed
    # baselines pair unchanged; only spec/host-tier cells grow a suffix
    base = f"ps{ps}_ck{chunk}_{kv}_b{batch}_k{k}"
    if sp:
        base = f"{base}_sp{sp}"
    return f"{base}_hk" if hk else base


def grid(smoke: bool):
    if smoke:
        plain = [
            (ps, SMOKE_CHUNK, kv, SMOKE_BATCH, k, 0, 0)
            for ps, kv, k in itertools.product(PAGE_SIZES, SMOKE_KV_AXIS, KS)
        ]
        spec = [
            (ps, SMOKE_CHUNK, "f32", SMOKE_BATCH, SPEC_K, SPEC_SP, 0)
            for ps in PAGE_SIZES
        ]
        hk = [
            (ps, SMOKE_CHUNK, "f32", SMOKE_BATCH, 1, 0, 1)
            for ps in PAGE_SIZES
        ]
        return plain + spec + hk
    plain = [
        (ps, chunk, kv, batch, k, 0, 0)
        for ps, chunk, kv, batch, k in itertools.product(
            PAGE_SIZES, CHUNKS, KV_AXIS, BATCHES, KS
        )
    ]
    spec = [
        (ps, SMOKE_CHUNK, kv, SMOKE_BATCH, SPEC_K, SPEC_SP, 0)
        for ps, kv in itertools.product(PAGE_SIZES, SPEC_KV_AXIS)
    ]
    hk = [
        (ps, SMOKE_CHUNK, kv, SMOKE_BATCH, 1, 0, 1)
        for ps, kv in itertools.product(PAGE_SIZES, HK_KV_AXIS)
    ]
    return plain + spec + hk


# -------------------------------------------------------------------------------
# measured vs analytic bytes for one cell's steady-state occupancy
# -------------------------------------------------------------------------------
def measured_step_bytes(cfg, *, page_size: int, kv_dtype: str, batch: int,
                        context_len: int, seed: int = 0) -> dict:
    """One decode step's KV traffic, measured AND derived, for the occupancy
    the cell's workload reaches mid-stream (every slot at ``context_len``).

    Measured: a pool at that occupancy (disjoint scattered physical pages,
    the allocator's regime) encoded by the cell dtype's flat accessor and
    read through a CountingAccessor by ``counted_paged_decode`` — the tally
    prices exactly the live pages the kernel schedules. Analytic: the same
    state through ``roofline.paged_decode_analytic_bytes``. Both scale by
    n_layers (every layer moves its own K and V pools)."""
    rng = np.random.default_rng(seed)
    hkv, d = cfg.n_kv_heads, cfg.head_dim
    hq = cfg.n_heads
    max_pages = -(-context_len // page_size)
    num_pages = batch * max_pages + 1
    q = rng.standard_normal((batch, hq, 1, d)).astype(np.float32)
    pool = rng.standard_normal((2, num_pages, hkv, page_size, d)).astype(np.float32)
    perm = rng.permutation(num_pages)[: batch * max_pages]
    tables = perm.reshape(batch, max_pages).astype(np.int32)
    lens = np.full((batch,), context_len, np.int32)
    spec = KV_DTYPES[kv_dtype]
    flat = BasicAccessor() if spec is None else spec.as_flat_accessor(page_size, d)
    acc = CountingAccessor(flat)
    kb = flat.from_codomain(pool[0].reshape(-1))
    vb = flat.from_codomain(pool[1].reshape(-1))
    _, tally = counted_paged_decode(
        q, kb, vb, acc, tables, lens,
        pool_shape=(num_pages, hkv, page_size, d),
    )
    analytic = roofline.paged_decode_analytic_bytes(
        lens, page_size=page_size, n_kv_heads=hkv, head_dim=d,
        kv_dtype=kv_dtype,
    )
    measured = tally.bytes_moved * cfg.n_layers
    analytic *= cfg.n_layers
    return {
        "measured_bytes_per_step": int(measured),
        "analytic_bytes_per_step": int(analytic),
        "measured_vs_analytic_rel": round(
            abs(measured - analytic) / max(analytic, 1), 4
        ),
    }


# -------------------------------------------------------------------------------
# one matrix cell: steady-state workload -> latency + bytes + attainment
# -------------------------------------------------------------------------------
def _steady_requests(vocab: int, batch: int):
    return [
        Request(
            rid=i,
            prompt=np.random.default_rng(70 + i).integers(
                0, vocab, size=PROMPT_LEN
            ).tolist(),
            params=GenerationParams(max_new_tokens=NEW_TOKENS),
        )
        for i in range(batch)
    ]


def run_cells(model, params, cfg, machine_bw: float, combos,
              passes: int = 5) -> list:
    """Measure every cell of the grid, INTERLEAVED: rehearse all engines
    first (compile + warm), then sweep the whole grid once per measurement
    pass and keep each cell's min step latency / max throughput across
    passes. Interleaving matters on a shared host: interference arrives in
    multi-second bursts, so three back-to-back passes of one cell can all
    land inside a burst — spreading a cell's passes across the full grid
    walk puts tens of seconds between them, and the min recovers the cell's
    capability (host noise only ever ADDS time)."""
    engines = []
    for ps, chunk, kv, batch, k, sp, hk in combos:
        conf = EngineConfig.sized_for(
            PROMPT_LEN + NEW_TOKENS + 1, page_size=ps, max_batch=batch,
            multi_step=k, kv_dtype=kv, chunked_prefill=True,
            chunk_tokens=chunk, spec_tokens=sp, spec_backoff=0,
        )
        if hk:
            # oversubscribe: HBM holds just enough to admit two requests
            # (pages_for(prompt+1) each, plus the scheduler's watermark
            # page), the host pool holds full demand — decode growth then
            # preempts and every pass swaps for real
            admit = -(-(PROMPT_LEN + 1) // ps)
            demand = batch * -(-(PROMPT_LEN + NEW_TOKENS) // ps)
            conf = dataclasses.replace(
                conf, num_pages=2 * admit + 2, host_pool_pages=demand,
            )
        eng = ServeEngine(model, params, conf)
        eng.run(_steady_requests(cfg.vocab, batch))  # rehearsal
        engines.append(eng)
    best = [None] * len(combos)
    for _ in range(passes):
        for i, eng in enumerate(engines):
            batch = combos[i][3]
            eng.reset_metrics()
            eng.run(_steady_requests(cfg.vocab, batch))
            m = eng.metrics()
            if best[i] is None:
                best[i] = dict(m)
            else:
                best[i]["step_ms_p50"] = min(best[i]["step_ms_p50"],
                                             m["step_ms_p50"])
                best[i]["step_ms_p95"] = min(best[i]["step_ms_p95"],
                                             m["step_ms_p95"])
                best[i]["tokens_per_s"] = max(best[i]["tokens_per_s"],
                                              m["tokens_per_s"])
    cells = []
    for (ps, chunk, kv, batch, k, sp, hk), m in zip(combos, best):
        # mid-stream occupancy: every slot half way through its decode tail
        traffic = measured_step_bytes(
            cfg, page_size=ps, kv_dtype=kv, batch=batch,
            context_len=PROMPT_LEN + NEW_TOKENS // 2,
        )
        step_s = m["step_ms_p50"] / 1e3  # metrics() reports milliseconds
        achieved = traffic["measured_bytes_per_step"] / max(step_s, 1e-12)
        att = roofline.attainment(
            traffic["measured_bytes_per_step"], step_s, machine_bw
        )
        floor = ATTAINMENT_FLOORS[kv]
        cells.append({
            "key": cell_key(ps, chunk, kv, batch, k, sp, hk),
            "page_size": ps,
            "chunk_tokens": chunk,
            "kv_dtype": kv,
            "max_batch": batch,
            "multi_step": k,
            "spec_tokens": sp,
            "host_tier": bool(hk),
            "step_ms_p50": m["step_ms_p50"],
            "step_ms_p95": m["step_ms_p95"],
            "tokens_per_s": m["tokens_per_s"],
            "decode_steps": m["decode_steps"],
            **traffic,
            "achieved_gb_s": round(achieved / 1e9, 6),
            "attainment": att,
            "attainment_floor": floor,
            "below_floor": att < floor,
            # hk cells carry their churn counters: a cell that stopped
            # swapping would silently be timing a different workload
            **({"preemptions": m["preemptions"],
                "swap_in_pages": m["swap_in_pages"]} if hk else {}),
        })
    return cells


# -------------------------------------------------------------------------------
# the autotuner consumer: matrix numbers -> engine init choices
# -------------------------------------------------------------------------------
def run_autotune_comparison(model, params, cfg) -> dict:
    """Same smoke workload through a fixed-default engine and an autotuned one
    (page_size=0: the tuner picks page size, decode block shape and chunk
    width from its sweep-once cache). Records both throughputs, the chosen
    config as ``engine.metrics()`` surfaces it, and the no-slower gate."""
    max_len = PROMPT_LEN + NEW_TOKENS + 1
    batch = 4
    default_conf = EngineConfig.sized_for(max_len, page_size=16, max_batch=batch)
    tuned_conf = EngineConfig.sized_for(
        max_len, page_size=0, max_batch=batch, autotune=True,
    )
    engines = {
        "default": ServeEngine(model, params, default_conf),
        "autotuned": ServeEngine(model, params, tuned_conf),
    }
    stats = {}
    for mode, eng in engines.items():
        eng.run(_steady_requests(cfg.vocab, batch))  # rehearsal
        stats[mode] = None
    # interleaved min-of-5, the same estimator the matrix cells use: the two
    # engines' passes alternate so an interference burst hits both equally
    for _ in range(5):
        for mode, eng in engines.items():
            eng.reset_metrics()
            eng.run(_steady_requests(cfg.vocab, batch))
            m = eng.metrics()
            if stats[mode] is None:
                stats[mode] = dict(m)
            else:
                stats[mode]["step_ms_p50"] = min(stats[mode]["step_ms_p50"],
                                                 m["step_ms_p50"])
                stats[mode]["tokens_per_s"] = max(stats[mode]["tokens_per_s"],
                                                  m["tokens_per_s"])
    tuned = stats["autotuned"]
    # the gate compares DECODE STEP latency — the quantity the tuner actually
    # optimizes (tokens_per_s folds in prefill + scheduler time the block
    # shapes don't touch, and is reported alongside). 1.15x slack absorbs
    # host-timing noise on a dispatch-bound smoke model; a slowdown beyond
    # that means the tuning table no longer reflects this host. When the
    # tuner lands on the default schedule the two engines are IDENTICAL
    # configs, so the gate holds by construction — it exists to catch a tuner
    # that picks a worse schedule, not to fail a coin flip between twins.
    same_schedule = (
        tuned["tuned_page_size"] == default_conf.page_size
        and tuned["tuned_block_pages"] <= 1
    )
    no_slower = same_schedule or (
        tuned["step_ms_p50"] <= 1.15 * stats["default"]["step_ms_p50"]
    )
    return {
        "workload": {"prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
                     "max_batch": batch},
        "selected": {
            key: tuned[key]
            for key in ("tuned_page_size", "tuned_block_pages",
                        "tuned_chunk_tokens", "tuned_source")
        },
        "tokens_per_s_default": stats["default"]["tokens_per_s"],
        "tokens_per_s_autotuned": tuned["tokens_per_s"],
        "step_ms_p50_default": stats["default"]["step_ms_p50"],
        "step_ms_p50_autotuned": tuned["step_ms_p50"],
        "no_slower_than_default": bool(no_slower),
    }


# -------------------------------------------------------------------------------
# ratchet + rendering
# -------------------------------------------------------------------------------
def _cell_failures(report: dict, baseline: dict | None) -> dict:
    """The matrix gate, keyed by cell: roofline-violating cells always fail;
    each cell with a committed twin (paired by key) fails on >20% step_ms_p50
    regression — after HOST-DRIFT NORMALIZATION: per-cell ratios are divided
    by the run's median paired ratio, so a uniform slowdown of every cell
    (host condition: thermal state, co-tenants, a slower CI runner) cancels,
    while one cell regressing against its peers — the signature of an actual
    code regression, which lands in the paths some cells use and others
    don't — still fails. The median needs a few paired cells to mean
    anything; below that the raw ratio is used."""
    failures = {}
    base = {
        c["key"]: c for c in (baseline or {}).get("cells", [])
    }
    ratios = {
        c["key"]: c["step_ms_p50"] / max(base[c["key"]]["step_ms_p50"], 1e-12)
        for c in report["cells"] if c["key"] in base
    }
    # clamped at 1.0: normalization only ever FORGIVES a uniform slowdown —
    # on a faster-than-baseline run raw ratios are already trustworthy, and
    # dividing by a <1 drift would fail cells that merely didn't improve
    drift = (
        max(1.0, float(np.median(list(ratios.values()))))
        if len(ratios) >= 4 else 1.0
    )
    for c in report["cells"]:
        if c["attainment"] > 1.0:
            failures[c["key"]] = (
                f"{c['key']}: attainment {c['attainment']:.3f} > 1.0 — "
                "achieved bandwidth exceeds the measured machine roof "
                "(a timing or byte-accounting bug, not a fast kernel)"
            )
            continue
        if c["key"] not in ratios:
            continue
        ratio = ratios[c["key"]] / max(drift, 1e-12)
        if ratio > REGRESSION_X * _BUCKET_X:
            failures[c["key"]] = (
                f"{c['key']}: step_ms_p50 {c['step_ms_p50']:.3f}ms is "
                f"{ratio:.2f}x the committed baseline "
                f"{base[c['key']]['step_ms_p50']:.3f}ms "
                f"(limit {REGRESSION_X}x + one histogram bucket, host drift "
                f"{drift:.2f}x factored out)"
            )
    return failures


def check_cells(report: dict, baseline: dict | None) -> list:
    return list(_cell_failures(report, baseline).values())


def render_markdown(report: dict) -> str:
    rows = [
        "| cell | ps | chunk | kv | batch | K | sp | hk | p50 ms | p95 ms "
        "| tok/s | measured B/step | vs analytic | GB/s | attainment | flag |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in report["cells"]:
        flag = "below-floor" if c["below_floor"] else ""
        rows.append(
            f"| {c['key']} | {c['page_size']} | {c['chunk_tokens']} "
            f"| {c['kv_dtype']} | {c['max_batch']} | {c['multi_step']} "
            f"| {c.get('spec_tokens', 0)} "
            f"| {'y' if c.get('host_tier') else ''} "
            f"| {c['step_ms_p50']:.3f} | {c['step_ms_p95']:.3f} "
            f"| {c['tokens_per_s']:.1f} | {c['measured_bytes_per_step']} "
            f"| {c['measured_vs_analytic_rel']:.1%} | {c['achieved_gb_s']:.4f} "
            f"| {c['attainment']:.2e} | {flag} |"
        )
    bw = report["machine_bandwidth_gb_s"]
    tune = report.get("autotune", {})
    lines = [
        f"# Serving perf matrix ({len(report['cells'])} cells)",
        "",
        f"Machine bandwidth (STREAM, cached per host): {bw:.1f} GB/s. "
        "Attainment = achieved GB/s / machine bandwidth; cells above 1.0 "
        "fail the run, cells below their per-dtype floor are flagged.",
        "",
        *rows,
    ]
    if tune:
        sel = tune["selected"]
        lines += [
            "",
            f"Autotuned engine: page_size={sel['tuned_page_size']} "
            f"block_pages={sel['tuned_block_pages']} "
            f"chunk_tokens={sel['tuned_chunk_tokens']} "
            f"({sel['tuned_source']}) — "
            f"{tune['tokens_per_s_autotuned']:.1f} tok/s vs "
            f"{tune['tokens_per_s_default']:.1f} default "
            f"(no_slower={tune['no_slower_than_default']}).",
        ]
    return "\n".join(lines) + "\n"


def run(smoke: bool = False, out_path: Path = None, ratchet: bool = True) -> dict:
    baseline = json.loads(OUT_PATH.read_text()) if OUT_PATH.exists() else None
    cfg = bench_config(smoke=True)  # the smoke model for BOTH modes: cells
    # must pair across full and smoke runs, so the model never changes
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    machine_bw = roofline.measure_machine_bandwidth()
    report = {
        "schema_version": SCHEMA_VERSION,
        "model": cfg.name,
        "smoke": smoke,
        "workload": {"prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS},
        "machine_bandwidth_gb_s": round(machine_bw / 1e9, 3),
        "cells": [],
    }
    combos = grid(smoke)
    report["cells"] = run_cells(model, params, cfg, machine_bw, combos)
    # ratchet retries: a cell failing its committed twin is re-measured (up to
    # twice) before the verdict stands. Host noise only ever ADDS time, so a
    # retry landing at or under the ratchet is PROOF the first reading was an
    # interference burst, not a regression — while a real regression repeats
    # on every retry and still fails. Only the failing cells re-run, so the
    # happy path pays nothing.
    if ratchet:
        by_key = {c["key"]: i for i, c in enumerate(report["cells"])}
        for _ in range(2):
            failing = set(_cell_failures(report, baseline)) & set(by_key)
            if not failing:
                break
            retry = [c for c in combos if cell_key(*c) in failing]
            print(f"perf_matrix/retrying {len(retry)} cells over the ratchet")
            for cell in run_cells(model, params, cfg, machine_bw, retry):
                i = by_key[cell["key"]]
                if cell["step_ms_p50"] < report["cells"][i]["step_ms_p50"]:
                    report["cells"][i] = cell
    for cell in report["cells"]:
        print(
            f"perf_matrix/{cell['key']},{cell['step_ms_p50'] * 1e3:.2f},"
            f"tokens_per_s={cell['tokens_per_s']:.1f} "
            f"bytes={cell['measured_bytes_per_step']} "
            f"(analytic {cell['measured_vs_analytic_rel']:.1%} off) "
            f"att={cell['attainment']:.2e}"
            + (" BELOW-FLOOR" if cell["below_floor"] else "")
        )
    report["autotune"] = run_autotune_comparison(model, params, cfg)
    tune = report["autotune"]
    print(
        f"perf_matrix/autotune,{tune['step_ms_p50_autotuned'] * 1e3:.2f},"
        f"selected={tune['selected']} "
        f"tokens_per_s={tune['tokens_per_s_autotuned']:.1f} vs "
        f"{tune['tokens_per_s_default']:.1f} default "
        f"no_slower={tune['no_slower_than_default']}"
    )
    out = out_path or (SMOKE_OUT_PATH if smoke else OUT_PATH)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    MD_PATH.parent.mkdir(parents=True, exist_ok=True)
    MD_PATH.write_text(render_markdown(report))
    print(f"perf matrix written to {out} (table: {MD_PATH})")
    failures = check_cells(report, baseline) if ratchet else []
    if not tune["no_slower_than_default"]:
        failures.append(
            "autotune: tuned engine step_ms_p50 "
            f"{tune['step_ms_p50_autotuned']:.3f}ms exceeds 1.15x the default "
            f"engine's {tune['step_ms_p50_default']:.3f}ms — the tuning table "
            "no longer reflects this host (clear artifacts/autotune_cache.json "
            "and re-run)"
        )
    for f in failures:
        print(f"perf_matrix/RATCHET-FAIL: {f}")
    if failures:
        raise SystemExit(1)
    return report


if __name__ == "__main__":
    run()
