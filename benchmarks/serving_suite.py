"""Serving benchmark: the continuous-batching engine under Poisson arrivals.

Sweeps (max_batch, page_size) points on a tiny dense model, replaying the same
seeded request trace (prompt lengths from fixed buckets so prefill compiles a
bounded set of shapes; exponential inter-arrival gaps) and reports engine
throughput (tokens/sec) and request latency (p50/p99 end-to-end, p50/p99
time-to-first-token). Each point warms the jit cache with a short rehearsal run
so the measured pass times compiled code, then writes every point to
``BENCH_serving.json`` so the perf trajectory accumulates across PRs.

A second section replays a shared-prefix trace (every prompt opens with the
same system-prompt-style block) twice — prefix sharing on vs. off — and records
the peak pages-in-use of each plus the token-exactness of the shared run: the
copy-on-write paged cache should serve the burst from far fewer physical pages
(capacity O(unique tokens), not O(total tokens)).

A third section replays the same shared-prefix burst once per KV page
representation (f32 / int8 / int4 — EngineConfig.kv_dtype, the QuantizedAccessor
axis composed with LayoutPaged) and records peak pages, decode throughput, pool
bytes (the capacity_x_vs_f32 ratio is the pages-per-byte gain), greedy token
agreement, and the max |logit - logit_f32| over aligned steps — the
accuracy/capacity trade the CI smoke job gates on.

  PYTHONPATH=src python -m benchmarks.run --only serving
  PYTHONPATH=src python -m benchmarks.run --only serving --smoke   # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only serving --smoke --kv-dtype int8
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import numpy as np

from repro.models import ModelConfig, Model
from repro.serving.engine import (
    EngineConfig, Request, ServeEngine, aligned_max_logit_err,
)

OUT_PATH = Path("BENCH_serving.json")
SMOKE_OUT_PATH = Path("BENCH_serving_smoke.json")  # untracked: CI-sized numbers
# must never clobber the tracked cross-PR trajectory in BENCH_serving.json

POINTS = [  # (max_batch, page_size)
    (2, 8),
    (4, 8),
    (4, 16),
]

PROMPT_BUCKETS = (8, 16, 24)
N_REQUESTS = 10
MAX_NEW_TOKENS = 8
MEAN_ARRIVAL_GAP_S = 0.02

# shared-prefix section: a common block + short unique tails, arriving in a
# burst. The prefix is NOT page-aligned and the 0 tail bucket repeats it
# verbatim, so some requests share even the partial last page and the first
# decode append exercises copy-on-write.
SHARED_PREFIX_LEN = 34
SHARED_TAIL_BUCKETS = (0, 4, 8)
SHARED_N_REQUESTS = 8
SHARED_MAX_BATCH = 4
SHARED_PAGE_SIZE = 8


def bench_config(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="bench-tiny-dense-smoke", family="dense", n_layers=1, d_model=32,
            vocab=256, n_heads=2, n_kv_heads=2, d_ff=64, dtype="float32",
        )
    return ModelConfig(
        name="bench-tiny-dense", family="dense", n_layers=2, d_model=64,
        vocab=512, n_heads=4, n_kv_heads=2, d_ff=128, dtype="float32",
    )


def make_requests(rng: np.random.Generator, vocab: int, n: int) -> list:
    gaps = rng.exponential(scale=MEAN_ARRIVAL_GAP_S, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        length = int(rng.choice(PROMPT_BUCKETS))
        prompt = rng.integers(0, vocab, size=length).tolist()
        reqs.append(
            Request(rid=i, prompt=prompt, max_new_tokens=MAX_NEW_TOKENS,
                    arrival_time=float(arrivals[i]))
        )
    return reqs


def make_shared_prefix_requests(rng: np.random.Generator, vocab: int, n: int,
                                max_new: int) -> list:
    prefix = rng.integers(0, vocab, size=SHARED_PREFIX_LEN).tolist()
    # round-robin tail lengths so every bucket appears: the 0-tail requests are
    # verbatim prompt repeats (maximal sharing + forced CoW), the rest diverge
    tails = [SHARED_TAIL_BUCKETS[i % len(SHARED_TAIL_BUCKETS)] for i in range(n)]
    return [
        Request(
            rid=i,
            prompt=prefix + rng.integers(0, vocab, size=tails[i]).tolist(),
            max_new_tokens=max_new,
            arrival_time=0.0,  # burst: the whole batch contends for pages at once
        )
        for i in range(n)
    ]


def engine_for(model, params, max_batch: int, page_size: int,
               max_new: int, **kw) -> ServeEngine:
    max_len = max(PROMPT_BUCKETS) + max_new + 1
    return ServeEngine(
        model, params,
        EngineConfig.sized_for(max_len, page_size=page_size, max_batch=max_batch, **kw),
    )


def run_shared_prefix(model, params, vocab: int, n_requests: int,
                      max_new: int) -> dict:
    """The same burst through a sharing and a non-sharing engine; returns peak
    pages-in-use for both, the savings, and whether outputs were token-exact."""
    max_len = SHARED_PREFIX_LEN + max(SHARED_TAIL_BUCKETS) + max_new + 1
    conf = EngineConfig.sized_for(
        max_len, page_size=SHARED_PAGE_SIZE, max_batch=SHARED_MAX_BATCH,
    )
    outputs = {}
    stats = {}
    for mode, sharing in (("sharing_on", True), ("sharing_off", False)):
        eng = ServeEngine(
            model, params, dataclasses.replace(conf, prefix_sharing=sharing)
        )
        # rehearsal (same trace) compiles every prefill bucket + the decode
        # step, then reset: measured throughput times compiled code, and the
        # rehearsal's pages all freed so the index/peak start clean
        eng.run(make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                            n_requests, max_new))
        eng.reset_metrics()
        rng = np.random.default_rng(7)
        results = eng.run(make_shared_prefix_requests(rng, vocab, n_requests, max_new))
        outputs[mode] = {rid: s.generated for rid, s in results.items()}
        m = eng.metrics()
        stats[mode] = m
    on, off = stats["sharing_on"], stats["sharing_off"]
    savings = 1.0 - on["peak_pages_in_use"] / max(off["peak_pages_in_use"], 1)
    return {
        "n_requests": n_requests,
        "prefix_len": SHARED_PREFIX_LEN,
        "page_size": SHARED_PAGE_SIZE,
        "max_batch": SHARED_MAX_BATCH,
        "peak_pages_sharing_on": on["peak_pages_in_use"],
        "peak_pages_sharing_off": off["peak_pages_in_use"],
        "peak_pages_saved_pct": round(100.0 * savings, 1),
        "pages_shared": on["pages_shared"],
        "cow_copies": on["cow_copies"],
        "tokens_per_s_sharing_on": on["tokens_per_s"],
        "tokens_per_s_sharing_off": off["tokens_per_s"],
        "tokens_exact": outputs["sharing_on"] == outputs["sharing_off"],
    }


def run_quantized(model, params, vocab: int, n_requests: int, max_new: int,
                  kv_dtypes) -> dict:
    """The same shared-prefix burst through one engine per KV representation;
    f32 is the accuracy/capacity baseline the others are scored against."""
    max_len = SHARED_PREFIX_LEN + max(SHARED_TAIL_BUCKETS) + max_new + 1
    conf = EngineConfig.sized_for(
        max_len, page_size=SHARED_PAGE_SIZE, max_batch=SHARED_MAX_BATCH,
        record_logits=True,
    )
    engines, results, metrics = {}, {}, {}
    for kv in kv_dtypes:
        eng = ServeEngine(model, params, dataclasses.replace(conf, kv_dtype=kv))
        # rehearsal compiles prefill buckets + this dtype's decode step, then
        # reset so the measured pass times compiled code on a clean pool
        eng.run(make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                            n_requests, max_new))
        eng.reset_metrics()
        results[kv] = eng.run(
            make_shared_prefix_requests(np.random.default_rng(7), vocab,
                                        n_requests, max_new)
        )
        engines[kv], metrics[kv] = eng, eng.metrics()
    f32 = metrics["f32"]
    section = {
        "n_requests": n_requests,
        "prefix_len": SHARED_PREFIX_LEN,
        "page_size": SHARED_PAGE_SIZE,
        "max_new_tokens": max_new,
        "dtypes": {},
    }
    for kv in kv_dtypes:
        m = metrics[kv]
        entry = {
            "peak_pages_in_use": m["peak_pages_in_use"],
            "pages_shared": m["pages_shared"],
            "tokens_per_s": m["tokens_per_s"],
            "step_ms_p50": m["step_ms_p50"],
            "kv_pool_bytes": m["kv_pool_bytes"],
        }
        if kv != "f32":
            entry["capacity_x_vs_f32"] = round(
                f32["kv_pool_bytes"] / m["kv_pool_bytes"], 2
            )
            entry["max_logit_err_vs_f32"] = aligned_max_logit_err(
                engines["f32"], engines[kv], results["f32"], results[kv]
            )
            entry["tokens_exact_vs_f32"] = all(
                results[kv][r].generated == results["f32"][r].generated
                for r in results["f32"]
            )
        section["dtypes"][kv] = entry
    return section


def run(out_path: Path = None, smoke: bool = False, kv_dtype: str = "all") -> dict:
    if out_path is None:
        out_path = SMOKE_OUT_PATH if smoke else OUT_PATH
    cfg = bench_config(smoke)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    points = POINTS[:1] if smoke else POINTS
    n_requests = 4 if smoke else N_REQUESTS
    max_new = 4 if smoke else MAX_NEW_TOKENS
    shared_n = 4 if smoke else SHARED_N_REQUESTS
    report = {"model": cfg.name, "smoke": smoke, "points": []}
    for max_batch, page_size in points:
        # rehearsal on the same engine: compile every prefill bucket + the decode
        # step for these shapes (jit caches are per-engine), then reset and
        # measure. Rehearsal prompts use DISJOINT token ranges: page-aligned
        # prefixes of each other would hit the prefix index and compile only the
        # sliced (shared-tail) pack shapes, leaving the full-write shapes of the
        # measured no-share trace to compile inside the timed region
        eng = engine_for(model, params, max_batch, page_size, max_new)
        eng.run([
            Request(rid=i, prompt=list(range(1 + 100 * i, 1 + 100 * i + L)),
                    max_new_tokens=2)
            for i, L in enumerate(PROMPT_BUCKETS)
        ])
        eng.reset_metrics()
        rng = np.random.default_rng(0)
        reqs = make_requests(rng, cfg.vocab, n_requests)
        for r in reqs:
            r.max_new_tokens = max_new
        eng.run(reqs)
        m = eng.metrics()
        point = {"max_batch": max_batch, "page_size": page_size, **m}
        report["points"].append(point)
        print(
            f"serving/b{max_batch}_ps{page_size},{m['step_ms_p50']*1e3:.2f},"
            f"tokens_per_s={m['tokens_per_s']:.1f} p50={m['latency_s_p50']*1e3:.0f}ms "
            f"p99={m['latency_s_p99']*1e3:.0f}ms ttft_p99={m['ttft_s_p99']*1e3:.0f}ms "
            f"preempt={m['preemptions']}"
        )
    sp = run_shared_prefix(model, params, cfg.vocab, shared_n, max_new)
    report["shared_prefix"] = sp
    print(
        f"serving/shared_prefix,peak_pages {sp['peak_pages_sharing_on']} vs "
        f"{sp['peak_pages_sharing_off']} (-{sp['peak_pages_saved_pct']}%), "
        f"shared={sp['pages_shared']} cow={sp['cow_copies']} "
        f"exact={sp['tokens_exact']}"
    )
    kv_dtypes = (
        ("f32", "int8", "int4") if kv_dtype == "all"
        else tuple(dict.fromkeys(("f32", kv_dtype)))  # f32 baseline always runs
    )
    qs = run_quantized(model, params, cfg.vocab, shared_n, max_new, kv_dtypes)
    report["quantized"] = qs
    for kv, e in qs["dtypes"].items():
        extra = (
            f" capacity_x={e['capacity_x_vs_f32']} "
            f"max_logit_err={e['max_logit_err_vs_f32']:.4f} "
            f"exact={e['tokens_exact_vs_f32']}"
            if kv != "f32" else ""
        )
        print(
            f"serving/quantized_{kv},{e['step_ms_p50']*1e3:.2f},"
            f"peak_pages={e['peak_pages_in_use']} "
            f"tokens_per_s={e['tokens_per_s']:.1f} "
            f"pool_bytes={e['kv_pool_bytes']}{extra}"
        )
    out_path.write_text(json.dumps(report, indent=2))
    print(f"serving suite written to {out_path}")
    return report


if __name__ == "__main__":
    run()
