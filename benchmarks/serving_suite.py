"""Serving benchmark: the continuous-batching engine under Poisson arrivals.

Sweeps (max_batch, page_size) points on a tiny dense model, replaying the same
seeded request trace (prompt lengths from fixed buckets so prefill compiles a
bounded set of shapes; exponential inter-arrival gaps) and reports engine
throughput (tokens/sec) and request latency (p50/p99 end-to-end, p50/p99
time-to-first-token). Each point warms the jit cache with a short rehearsal run
so the measured pass times compiled code, then writes every point to
``BENCH_serving.json`` so the perf trajectory accumulates across PRs.

  PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.models import ModelConfig, Model
from repro.serving.engine import EngineConfig, Request, ServeEngine

OUT_PATH = Path("BENCH_serving.json")

POINTS = [  # (max_batch, page_size)
    (2, 8),
    (4, 8),
    (4, 16),
]

PROMPT_BUCKETS = (8, 16, 24)
N_REQUESTS = 10
MAX_NEW_TOKENS = 8
MEAN_ARRIVAL_GAP_S = 0.02


def bench_config() -> ModelConfig:
    return ModelConfig(
        name="bench-tiny-dense", family="dense", n_layers=2, d_model=64,
        vocab=512, n_heads=4, n_kv_heads=2, d_ff=128, dtype="float32",
    )


def make_requests(rng: np.random.Generator, vocab: int, n: int) -> list:
    gaps = rng.exponential(scale=MEAN_ARRIVAL_GAP_S, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        length = int(rng.choice(PROMPT_BUCKETS))
        prompt = rng.integers(0, vocab, size=length).tolist()
        reqs.append(
            Request(rid=i, prompt=prompt, max_new_tokens=MAX_NEW_TOKENS,
                    arrival_time=float(arrivals[i]))
        )
    return reqs


def engine_for(model, params, max_batch: int, page_size: int) -> ServeEngine:
    max_len = max(PROMPT_BUCKETS) + MAX_NEW_TOKENS + 1
    return ServeEngine(
        model, params,
        EngineConfig.sized_for(max_len, page_size=page_size, max_batch=max_batch),
    )


def run(out_path: Path = OUT_PATH) -> dict:
    cfg = bench_config()
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    report = {"model": cfg.name, "points": []}
    for max_batch, page_size in POINTS:
        # rehearsal on the same engine: compile every prefill bucket + the decode
        # step for these shapes (jit caches are per-engine), then reset and measure
        eng = engine_for(model, params, max_batch, page_size)
        eng.run([
            Request(rid=i, prompt=list(range(1, L + 1)), max_new_tokens=2)
            for i, L in enumerate(PROMPT_BUCKETS)
        ])
        eng.reset_metrics()
        rng = np.random.default_rng(0)
        eng.run(make_requests(rng, cfg.vocab, N_REQUESTS))
        m = eng.metrics()
        point = {"max_batch": max_batch, "page_size": page_size, **m}
        report["points"].append(point)
        print(
            f"serving/b{max_batch}_ps{page_size},{m['step_ms_p50']*1e3:.2f},"
            f"tokens_per_s={m['tokens_per_s']:.1f} p50={m['latency_s_p50']*1e3:.0f}ms "
            f"p99={m['latency_s_p99']*1e3:.0f}ms ttft_p99={m['ttft_s_p99']*1e3:.0f}ms "
            f"preempt={m['preemptions']}"
        )
    out_path.write_text(json.dumps(report, indent=2))
    print(f"serving suite written to {out_path}")
    return report


if __name__ == "__main__":
    run()
